"""ISP embedding demo: "send indexes, not data" on a sharded vocab table.

Shows the two execution plans for the same lookup —
  baseline: all-gather the table to the data (the XLA default / the paper's
            host-only configuration), vs
  ISP:      route indexes to the owning shard, gather locally, psum rows —
with the transfer ledger quantifying the link-byte reduction, and verifies
they produce identical embeddings (single-process: shards emulated by
slicing; the production shard_map path is exercised in tests/dryrun).

Run:  PYTHONPATH=src python examples/isp_embedding_demo.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.transfer import embedding_plans
from repro.kernels import ref

V, D, TP = 65_536, 512, 16
N_LOOKUPS = 8_192

rng = np.random.default_rng(0)
table = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)
idx = jnp.asarray(rng.integers(0, V, (N_LOOKUPS,)), jnp.int32)

# dense reference (what a single giant node would do)
want = jnp.take(table, idx, axis=0)

# ISP: each shard owns V/TP rows; masked local gathers; psum completes it
vloc = V // TP
parts = [ref.isp_gather(table[i * vloc:(i + 1) * vloc], idx,
                        shard_offset=i * vloc) for i in range(TP)]
got = sum(parts)
assert np.allclose(got, want, atol=1e-6)
print(f"[isp] {N_LOOKUPS} lookups over {TP} shards: exact match with dense")

base, isp = embedding_plans(N_LOOKUPS, V, D, tp=TP)
print(f"[transfer] baseline (ship table): {base.total_moved/1e6:.1f} MB on the link")
print(f"[transfer] ISP (ship indexes):    {isp.total_moved/1e6:.1f} MB on the link")
print(f"[transfer] reduction: {isp.reduction_vs(base):.0%} — the paper's "
      f"'data never leaves the drive', applied to a 65k-row table")

# RecSSD-style fused pooling shrinks the result bytes further
seg = jnp.asarray(rng.integers(0, 256, (N_LOOKUPS,)), jnp.int32)
pooled = sum(ref.isp_gather_pool(table[i * vloc:(i + 1) * vloc], idx, seg, 256,
                                 shard_offset=i * vloc) for i in range(TP))
dense_pool = jnp.zeros((256, D)).at[seg].add(want)
assert np.allclose(pooled, dense_pool, atol=1e-4)
print(f"[pool] fused gather+pool returns {256*D*4/1e6:.1f} MB instead of "
      f"{N_LOOKUPS*D*4/1e6:.1f} MB of rows — RecSSD offload, on-shard")
