"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the xLSTM-125m architecture at HALF width (≈ 100M params incl.
embeddings) on synthetic data, with checkpointing and resume — kill it
mid-run and rerun to see restart-exact resumption.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses

from repro.config import get_config
from repro.data import DataConfig
from repro.models import model as M
from repro.train.train_loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = get_config("xlstm-125m")
    cfg = dataclasses.replace(
        base, name="xlstm-60m-demo", d_model=384, num_heads=4,
        num_layers=8, block_pattern=("mlstm", "slstm"), scan_group=0,
        remat="none")
    print(f"[example] {cfg.name}: {M.count_params(cfg):,} params")

    data_cfg = DataConfig(seq_len=128, global_batch=8,
                          vocab_size=cfg.vocab_size)
    tcfg = TrainConfig(steps=args.steps, log_every=20, ckpt_every=100,
                       ckpt_dir=args.ckpt_dir, lr=1e-3, warmup=30)
    state = train(cfg, data_cfg, tcfg)
    print(f"[example] finished at step {state.step}")


if __name__ == "__main__":
    main()
