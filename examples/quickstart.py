"""Quickstart: the framework's public API in ~60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config, reduced_config, get_shape, list_configs
from repro.models import model as M

print("Registered architectures:", ", ".join(list_configs()))

# 1. Pick an architecture.  Full configs are the assigned production sizes;
#    reduced_config gives the same wiring at CPU scale.
cfg = reduced_config("gemma3-12b")
print(f"\n{cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
      f"pattern={cfg.block_pattern} params={M.count_params(cfg):,}")

# 2. Initialize and run a training step.
params = M.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
batch = {
    "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32),
    "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32),
}
loss, metrics = M.loss_fn(params, batch, cfg)
print(f"initial loss: {float(loss):.3f}")

# 3. Serve: prefill a prompt, then decode greedily with resident KV caches.
nxt, _ = M.prefill_fn(params, {"tokens": batch["tokens"]}, cfg)
caches = M.init_caches(cfg, batch=2, max_len=48)
tok = batch["tokens"][:, :1]
for t in range(5):
    nxt, caches = M.decode_fn(params, caches, tok, jnp.int32(t), cfg)
    tok = nxt[:, None].astype(jnp.int32)
print("greedy tokens:", [int(x) for x in np.asarray(nxt)])

# 4. The production mesh is one function away (requires 256/512 devices —
#    see python -m repro.launch.dryrun for the full multi-pod dry-run):
shape = get_shape("train_4k")
full = get_config("gemma3-12b")
print(f"\nproduction cell: {full.name} × {shape.name} = "
      f"{shape.tokens:,} tokens/step, {M.count_params(full):,} params")
print("dry-run: PYTHONPATH=src python -m repro.launch.dryrun "
      "--arch gemma3-12b --shape train_4k --mesh multipod")
