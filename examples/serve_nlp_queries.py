"""The paper's serving scenario end-to-end: a heterogeneous cluster
(1 fast host + N slow near-data workers) answers NLP queries through the
pull scheduler, with real JAX compute per batch and the paper's
energy/transfer accounting.

Run:  PYTHONPATH=src python examples/serve_nlp_queries.py [--csds 36]
"""
import argparse
import math
import pathlib
import sys
import time

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.apps import APPS, recommender_query_batch, sentiment_query_batch
from repro.core.energy import energy_per_query_mj
from repro.core.scheduler import PullScheduler, make_cluster, optimal_batch_ratio
from repro.core.transfer import host_only_ledger, workload_split_ledger


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--csds", type=int, default=36)
    ap.add_argument("--app", default="recommender", choices=sorted(APPS))
    ap.add_argument("--no-engine", action="store_true",
                    help="skip the LM continuous-batching engine demo")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page size (tokens) for the paged serve engine")
    ap.add_argument("--cluster-drives", type=int, default=2,
                    help="replica drives in the LM cluster-engine demo")
    args = ap.parse_args()
    app = APPS[args.app]

    # 1. real compute: run one query batch of the app's kernel locally
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    if args.app == "recommender":
        ids = recommender_query_batch(rng, n_queries=64)
        print(f"[compute] top-10 for 64 queries in {time.perf_counter()-t0:.2f}s; "
              f"query0 -> movies {ids[0][:5]}...")
    else:
        preds = sentiment_query_batch(rng, n_queries=256)
        print(f"[compute] 256 sentiment predictions in {time.perf_counter()-t0:.2f}s; "
              f"positive frac {preds.mean():.2f}")

    # 2. cluster scale-out via the pull scheduler (paper Fig. 5)
    ratio = optimal_batch_ratio(app.host_rate, app.csd_rate)
    nodes = make_cluster(app.host_rate, app.csd_rate, args.csds,
                         host_overhead=0.05, csd_overhead=0.02)
    sched = PullScheduler(nodes, app.batch_size, ratio, poll_interval=0.05)
    r = sched.run(app.total_items)
    base = PullScheduler(make_cluster(app.host_rate, app.csd_rate, 0,
                                      host_overhead=0.05, csd_overhead=0.02),
                         app.batch_size, ratio, 0.05).run(app.total_items)
    print(f"[cluster] host-only {base.throughput:.0f} items/s -> "
          f"{args.csds} CSDs {r.throughput:.0f} items/s "
          f"({r.throughput / base.throughput:.2f}x; paper "
          f"{app.paper_with_36 / app.paper_host_only:.2f}x)")
    print(f"[cluster] {r.csd_fraction:.0%} of items processed in storage "
          f"(paper {app.paper_csd_fraction:.0%})")

    # 3. energy + transfer accounting (paper Table I / Fig. 7)
    e0 = energy_per_query_mj(base.throughput, 0)
    e1 = energy_per_query_mj(r.throughput, args.csds)
    led = workload_split_ledger(app.dataset_bytes, r.csd_fraction,
                                app.output_bytes)
    ref = host_only_ledger(app.dataset_bytes, app.output_bytes)
    print(f"[energy] {e0:.0f} mJ/query -> {e1:.0f} mJ/query "
          f"({1 - e1 / e0:.0%} saving; paper {app.paper_energy_host_mj:.0f} "
          f"-> {app.paper_energy_csd_mj:.0f})")
    print(f"[transfer] link traffic cut {led.reduction_vs(ref):.0%} "
          f"({led.link_bytes / 1e9:.2f} GB vs {ref.link_bytes / 1e9:.2f} GB)")

    # 4. the same pipeline with a real LM: mixed-length queries through the
    #    continuous-batching engine — scheduler-driven admission, host/ISP
    #    plan routing, live link-byte ledger (shared with the fig5 bench).
    #    KV lives in a *paged* pool (the in-storage layout lesson applied to
    #    serving): prefill allocates ceil(prompt/page_size) pages, each
    #    decode step appends at most one page, EOS frees the slot's pages
    #    the same step — so peak KV memory tracks live tokens, not
    #    num_slots * max_len.  --page-size trades footprint granularity
    #    (smaller pages hug live tokens tighter) against per-page walk
    #    overhead (larger pages mean fewer, bigger kernel blocks).
    if not args.no_engine:
        from benchmarks.fig5_throughput import run_engine

        _, stats, kv, _ = run_engine(emit=lambda _: None,
                                     page_size=args.page_size)
        for line in stats.summary().splitlines():
            print(f"[engine] {line}")
        print(f"[engine] paged KV: peak {kv['peak_kv_bytes'] / 1e6:.3f} MB "
              f"of a {kv['dense_kv_bytes'] / 1e6:.3f} MB dense worst case "
              f"(page_size={kv['page_size']})")

    # 5. the cluster tier: the same LM served by multiple replica drives
    #    behind ONE queue (the paper's 36-CSD storage server, scaled down).
    #    Requests carry shard ids; data_local routing pins each to the
    #    drive holding its shard, and the merged ClusterStats put the live
    #    energy-per-query (Table I's wall-power / throughput) next to the
    #    link/KV reductions — per drive AND aggregate.
    if not args.no_engine:
        import dataclasses

        import jax

        from repro.config import reduced_config
        from repro.models import model as M
        from repro.train.cluster_loop import ClusterEngine

        cfg = dataclasses.replace(reduced_config("yi-9b"), dtype="float32")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        n_drives = min(max(args.cluster_drives, 1), 4)
        clu = ClusterEngine(cfg, params, n_drives=n_drives,
                            routing="data_local", max_len=64, num_slots=2)
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size,
                                rng.integers(4, 17)).tolist()
                   for _ in range(4 * n_drives)]
        shard_ids = rng.integers(0, n_drives, len(prompts)).tolist()
        clu.generate(prompts, max_new=6, shard_ids=shard_ids)
        for line in clu.summary().splitlines():
            print(f"[cluster-engine] {line}")

        # 6. heterogeneous drives: model the last drive 2x slower
        #    (speed_factor) and let the cluster pull scheduler learn the
        #    skew — rate_aware routing then sheds load onto the fast
        #    drives (the paper's §IV-A batch-ratio rule, live)
        if n_drives > 1:
            speeds = [1.0] * (n_drives - 1) + [0.5]
            het = ClusterEngine(cfg, params, n_drives=n_drives,
                                routing="rate_aware", max_len=64,
                                num_slots=2, speed_factor=speeds,
                                jit_donor=clu.drives[0].engine)
            het.generate(prompts, max_new=6)
            for line in het.summary().splitlines():
                print(f"[hetero-engine] {line}")

        # 7. open-loop SLO serving: the storage server's real traffic is
        #    bursty arrivals with per-class TTFT deadlines, not a drained
        #    batch.  Generate a reproducible bursty trace, replay it on the
        #    engine's serving clock with EDF admission + shedding of
        #    already-expired requests, and read the tail: p99 TTFT,
        #    goodput-under-SLO (deadline-met completions per second) and
        #    what the shed work cost.
        from repro.data.workload import (WorkloadConfig, generate_trace,
                                         replay_open_loop)
        from repro.train.serve_loop import ServeEngine

        slo = ServeEngine(cfg, params, max_len=64, num_slots=2,
                          chunk_prefill=8, admission_order="edf",
                          jit_donor=clu.drives[0].engine)
        wl = WorkloadConfig(n_requests=24, vocab_size=cfg.vocab_size,
                            arrival="bursty", rate=40.0, seed=0)
        report = replay_open_loop(slo, generate_trace(wl))
        lat = slo.stats.latency
        print(f"[slo] bursty open loop: {report.submitted} submitted, "
              f"{report.completed} ok / {report.shed} shed in "
              f"{report.wall_s:.2f}s serving clock")
        print(f"[slo] {lat.summary()}")
        print(f"[slo] goodput under SLO: "
              f"{lat.goodput_qps(report.wall_s):.1f} qps "
              f"(attainment {lat.slo_attainment:.0%}; "
              f"{slo.stats.shed_wasted_s * 1e3:.1f} ms serving time shed)")

        # 8. fault injection + recovery: at a 36-drive storage server,
        #    drive stalls and failures are the steady state.  Inject an
        #    explicit schedule — a hidden crash of drive 1, then a
        #    transient stall on drive 0 — and watch the cluster-visible
        #    side: the detector suspects the silent drives, quarantines
        #    them from quotas, declares the crashed one DEAD, auto-fail()s
        #    it, and the retry budget replays its in-flight work on the
        #    survivor.  Greedy decode makes every recovered request
        #    token-identical to a fault-free run.  (Ticks are engine
        #    steps; with the default fused k_block a short drain is only a
        #    handful of ticks, so the schedule lands early.)
        from repro.core.faults import FailureDetector, FaultSchedule

        faults = FaultSchedule.from_spec([
            {"drive_id": 1, "kind": "crash", "at_tick": 1},
            {"drive_id": 0, "kind": "stall", "at_tick": 2, "duration": 2},
        ])
        det = FailureDetector(2, suspect_ticks=2, dead_ticks=4,
                              suspect_after_s=math.inf)
        chaos = ClusterEngine(cfg, params, n_drives=2,
                              routing="round_robin", max_len=64,
                              num_slots=2, faults=faults, detector=det,
                              max_retries=3,
                              jit_donor=clu.drives[0].engine)
        for p in prompts[:6]:
            chaos.submit(p, max_new=6)
        results = chaos.run_until_complete()
        ok = sum(1 for r in results if r.status == "ok")
        failed = sum(1 for r in results if r.status == "failed")
        st = chaos.stats
        print(f"[faults] injected {st.faults_injected} faults; health now "
              f"{st.health} ({st.auto_failed_drives} auto-failed)")
        print(f"[faults] {ok} ok / {failed} failed of {len(results)}; "
              f"{st.retries} retries spent recovering in-flight work")
        for line in st.summary().splitlines():
            print(f"[faults] {line}")

        # 9. observability: re-run the chaos scenario with the telemetry
        #    hub attached and read the story back out of the trace —
        #    request spans (submit -> route -> admit -> decode -> retry ->
        #    finish), the crash's detection latency per health authority,
        #    and a Chrome-trace timeline loadable in Perfetto
        #    (chrome://tracing).  Tracing is opt-in and changes no token;
        #    each track rides its own clock (per-drive virtual clocks vs
        #    the cluster wall — compare within a track, not across).
        from repro.core.telemetry import TelemetryHub

        hub = TelemetryHub()
        traced = ClusterEngine(cfg, params, n_drives=2,
                               routing="round_robin", max_len=64,
                               num_slots=2,
                               faults=FaultSchedule.from_spec([
                                   {"drive_id": 1, "kind": "crash",
                                    "at_tick": 1}]),
                               detector=FailureDetector(
                                   2, suspect_ticks=2, dead_ticks=4,
                                   suspect_after_s=math.inf),
                               max_retries=3, telemetry=hub,
                               jit_donor=clu.drives[0].engine)
        for p in prompts[:6]:
            traced.submit(p, max_new=6)
        traced.run_until_complete()
        m = hub.metrics()
        spans = {k: v for k, v in m["counters"].items()
                 if k.startswith("spans.")}
        print(f"[telemetry] {len(hub.events())} events, span outcomes "
              f"{spans}, open spans {m['open_spans']}")
        for key, lat in m["detection_latency"].items():
            print(f"[telemetry] detection {key}: kind={lat['kind']} "
                  f"suspect after {lat.get('suspect_s', math.nan):.3f}s, "
                  f"dead after {lat.get('dead_s', math.nan):.3f}s")
        trace_path = pathlib.Path("serve_trace.json")
        hub.write_chrome_trace(str(trace_path))
        print(f"[telemetry] wrote {trace_path} — load it in Perfetto/"
              f"chrome://tracing, or: "
              f"python scripts/trace_report.py {trace_path}")


if __name__ == "__main__":
    main()
