"""Elastic supervisor: run → crash → restore → continue.

Production story (scaled down to one box): the supervisor launches the
training driver as a subprocess; on a non-zero exit (node failure, OOM,
preemption) it relaunches, and the driver resumes from the latest
*committed* checkpoint.  Elasticity: the relaunch may use a different host
count / mesh — ``restore_checkpoint(shardings=...)`` reshards every leaf to
the new topology, and the data pipeline resumes from the stored step with
freshly rebalanced shares (paper batch-ratio rule).

``FailureInjector`` is the test hook: it kills the child at a configured
step to prove restart-exactness (see tests/test_elastic.py).
"""
from __future__ import annotations

import os
import signal
import subprocess
import sys
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence


@dataclass
class SupervisorResult:
    restarts: int
    returncode: int
    log: List[str]


def supervise(cmd: Sequence[str], *, max_restarts: int = 3,
              env: Optional[dict] = None, backoff_s: float = 0.5,
              timeout_s: float = 600.0) -> SupervisorResult:
    """Relaunch ``cmd`` until clean exit or the restart budget is spent."""
    restarts = 0
    log: List[str] = []
    full_env = dict(os.environ)
    if env:
        full_env.update(env)
    while True:
        t0 = time.perf_counter()
        proc = subprocess.run(list(cmd), env=full_env, timeout=timeout_s)
        log.append(f"attempt={restarts} rc={proc.returncode} "
                   f"dur={time.perf_counter() - t0:.1f}s")
        if proc.returncode == 0:
            return SupervisorResult(restarts, 0, log)
        restarts += 1
        if restarts > max_restarts:
            return SupervisorResult(restarts - 1, proc.returncode, log)
        time.sleep(backoff_s * restarts)


class FailureInjector:
    """Deterministic failure hook for tests: dies at a given step, once.

    ``REPRO_FAIL_MARKER`` (a path) makes the injection one-shot across
    supervised restarts — the relaunched process sees the marker and runs
    through, which is exactly a transient node failure."""

    def __init__(self, fail_at_step: Optional[int]):
        self.fail_at = fail_at_step
        env = os.environ.get("REPRO_FAIL_AT_STEP")
        if self.fail_at is None and env:
            self.fail_at = int(env)
        self.marker = os.environ.get("REPRO_FAIL_MARKER")

    def maybe_fail(self, step: int) -> None:
        if self.fail_at is None or step != self.fail_at:
            return
        if self.marker:
            if os.path.exists(self.marker):
                return                      # already fired once
            with open(self.marker, "w") as f:
                f.write(str(step))
        print(f"[elastic] injected failure at step {step}", flush=True)
        os._exit(42)
