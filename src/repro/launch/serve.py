"""Serving driver: load (or init) a model and serve batched requests."""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import get_config, reduced_config
from repro.models import model as M
from repro.train.serve_loop import ServeEngine


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.smoke else get_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).tolist()
    t0 = time.time()
    results = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    n_tok = sum(len(r.tokens) for r in results)
    print(f"[serve] {args.arch}: {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s); first: {results[0].tokens[:8]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
