"""Serving driver: load (or init) a model and serve requests through the
continuous-batching engine.

Request sources (first match wins):
  --arrival M    open-loop SLO mode: generate a reproducible arrival trace
                 (poisson / bursty / diurnal at --rate req/s, mixed
                 priority classes with TTFT deadlines; --slo-ms overrides
                 every class budget) and replay it on the engine's serving
                 clock — requests arrive when the trace says, not when the
                 engine is ready.  --sched edf turns on deadline-aware
                 admission; expired requests are shed.  Prints the tail
                 latency + goodput-under-SLO summary;
  --trace FILE   one request per line: whitespace-separated token ids,
                 optionally ``ids... | max_new`` to override --max-new;
  --requests N   N random prompts with lengths uniform in
                 [--min-prompt, --prompt-len];
  (neither)      the legacy fixed batch: --batch equal-length prompts.

Always prints the engine's per-tier throughput and the ledger's link-byte
reduction (the paper's "data that never left the drive" counter).

With ``--replicas N`` (N > 1) the requests are served by a multi-drive
cluster instead: N replica engines behind one queue, routed per
``--routing`` (round_robin / least_loaded / data_local / rate_aware);
``--shards K`` tags request i with shard ``i % K`` so data_local has
locality to exploit, ``--speed-factor 1.0,0.5`` models heterogeneous
drives (the pull scheduler learns the skew, rate_aware routing exploits
it), and shard re-placement on drain/fail is on unless
``--no-shard-replacement``.  The cluster prints per-drive AND aggregate
stats — learned rates included — plus the live energy-per-query integral
(paper Table I).

Fault injection (implies the cluster path, even at --replicas 1):
``--mttf S`` draws a seeded fault schedule (stalls / slowdowns / pool
clamps / crashes) from exponential MTTF/MTTR distributions
(``--mttr S``, ``--fault-seed N``), or ``--fault-trace FILE`` replays a
saved schedule (``FaultSchedule.save`` jsonl, or the legacy JSON event
list of the ``from_spec`` form).  The
failure detector auto-fails drives it declares DEAD; restarted requests
spend their ``--max-retries`` budget and ``--hedge`` duplicates
SUSPECT-stranded requests onto healthy drives.  The summary then carries
the recovery story: faults injected, drive health, retries granted,
requests failed terminally, hedge wins/losses and the serving time the
lost copies burned.

``--concurrent`` swaps the cluster's serial drive loop for the worker
runtime: one thread per drive fed over command queues, tick time is the
measured wall-clock overlap, and DEAD verdicts come from the heartbeat
watchdog (missed beats + real dispatch timeouts) rather than virtual
clock thresholds — so a crashed or hung worker is detected by its
silence on the monitor channel, exactly as it would be in production.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import get_config, reduced_config
from repro.core.cluster import ROUTING_POLICIES
from repro.data.workload import (ARRIVAL_MODES, DEFAULT_CLASSES,
                                 PriorityClass, WorkloadConfig,
                                 generate_trace, replay_open_loop)
from repro.models import model as M
from repro.train.cluster_loop import ClusterEngine
from repro.train.serve_loop import AdmissionController, ServeEngine


def _load_trace(path: str, default_max_new: int):
    reqs = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            ids, _, tail = line.partition("|")
            prompt = [int(t) for t in ids.split()]
            max_new = int(tail) if tail.strip() else default_max_new
            reqs.append((prompt, max_new))
    return reqs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--min-prompt", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--num-slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=0,
                    help="serve N random variable-length requests")
    ap.add_argument("--trace", type=str, default=None,
                    help="file of token-id prompts, one request per line")
    ap.add_argument("--host-rate", type=float, default=20.0)
    ap.add_argument("--csd-rate", type=float, default=1.0)
    ap.add_argument("--csds", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv-layout", choices=("paged", "strip"),
                    default="paged",
                    help="paged: fixed-size KV pages, memory tracks live "
                         "tokens; strip: dense max_len strip per slot")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (smaller = tighter memory, "
                         "larger = fewer/bigger kernel blocks)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="KV pool size in pages (0 = dense worst case); "
                         "smaller pools backpressure admission")
    ap.add_argument("--k-block", type=int, default=8,
                    help="decode steps fused into one device-resident "
                         "dispatch per tick (1 = per-step host loop)")
    ap.add_argument("--chunk-prefill", type=int, default=0,
                    help="split prompts longer than this into per-tick "
                         "prefill chunks (0 = one-shot prefill)")
    ap.add_argument("--prewarm", action="store_true",
                    help="compile decode + prefill buckets before serving "
                         "(first-request latency excludes compile time)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="replica drives; >1 serves through the cluster "
                         "engine (one queue, routed dispatch)")
    ap.add_argument("--routing", choices=ROUTING_POLICIES,
                    default="least_loaded",
                    help="cluster dispatch policy (with --replicas > 1)")
    ap.add_argument("--shards", type=int, default=0,
                    help="tag request i with shard i %% K for data_local "
                         "routing (0 = unsharded requests)")
    ap.add_argument("--speed-factor", type=str, default=None,
                    help="comma-separated per-drive speed factors (e.g. "
                         "'1.0,0.5' models one drive 2x slower); the "
                         "cluster pull scheduler learns the skew live and "
                         "rate_aware routing exploits it")
    ap.add_argument("--arrival", choices=ARRIVAL_MODES, default=None,
                    help="open-loop SLO mode: generate + replay an arrival "
                         "trace of --requests requests at --rate req/s")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="mean arrival rate (req/s) for --arrival")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="override every class's TTFT SLO budget (ms); "
                         "0 keeps the per-class defaults")
    ap.add_argument("--sched", choices=("fifo", "edf"), default="fifo",
                    help="admission order under --arrival (edf = earliest "
                         "deadline first + shedding of expired requests)")
    ap.add_argument("--chunk-budget", type=int, default=1,
                    help="prefill chunks one tick may run (with "
                         "--chunk-prefill); 1 protects decode TTFT")
    ap.add_argument("--no-shard-replacement", action="store_true",
                    help="keep static shard placement on drain/fail "
                         "(every re-routed request re-pays the shard's "
                         "link bytes instead of one migration charge)")
    ap.add_argument("--mttf", type=float, default=0.0,
                    help="mean seconds between injected faults per drive "
                         "(0 = no fault injection); faults are drawn "
                         "seeded from exponential MTTF/MTTR distributions")
    ap.add_argument("--mttr", type=float, default=0.5,
                    help="mean repair window (s) of injected transient "
                         "faults (stall / slowdown / pool clamp)")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="seed for the drawn fault schedule "
                         "(default: --seed)")
    ap.add_argument("--fault-trace", type=str, default=None,
                    help="fault event file: jsonl from FaultSchedule.save "
                         "or a legacy JSON event list "
                         "(FaultSchedule.from_spec form); overrides --mttf")
    ap.add_argument("--max-retries", type=int, default=3,
                    help="restarts a request may spend on drive failures "
                         "before finishing status='failed'")
    ap.add_argument("--hedge", action="store_true",
                    help="duplicate SUSPECT-stranded requests onto healthy "
                         "drives (first finisher wins; the loser's serving "
                         "time is booked as hedge waste)")
    ap.add_argument("--concurrent", action="store_true",
                    help="run drives on real worker threads (one per "
                         "drive); tick time is measured wall-clock overlap "
                         "and failures are detected from missed heartbeats "
                         "(implies the cluster path, even at --replicas 1)")
    ap.add_argument("--dispatch-timeout", type=float, default=0.25,
                    help="seconds the concurrent coordinator waits on the "
                         "heartbeat channel per join before charging the "
                         "silent drives a missed beat")
    ap.add_argument("--min-tick-ms", type=float, default=0.0,
                    help="per-drive service-time floor (ms) so tiny smoke "
                         "models still show real tick overlap under "
                         "--concurrent (applied in serial mode too, "
                         "keeping the two comparable)")
    ap.add_argument("--trace-out", type=str, default=None,
                    help="write a Chrome-trace/Perfetto JSON timeline of "
                         "the run (one track per drive worker + the "
                         "coordinator + queue-depth counters); enables "
                         "the telemetry hub")
    ap.add_argument("--metrics-out", type=str, default=None,
                    help="write the telemetry metrics registry (counters/"
                         "gauges/histograms + detection latency + the "
                         "stats snapshots the summary prints from) as "
                         "JSON; enables the telemetry hub")
    ap.add_argument("--events-out", type=str, default=None,
                    help="write the raw telemetry event ring as jsonl; "
                         "enables the telemetry hub")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.smoke else get_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    engine_kw = dict(max_len=args.max_len, num_slots=args.num_slots,
                     kv_layout=args.kv_layout, page_size=args.page_size,
                     num_pages=args.num_pages or None, k_block=args.k_block,
                     chunk_prefill=args.chunk_prefill or None,
                     prewarm=args.prewarm)
    # the cluster binds admission_order at its shared queue (ClusterEngine
    # kwarg); the single engine at its own; chunk_budget always reaches the
    # ServeEngine(s)
    engine_kw["admission_order"] = args.sched
    engine_kw["chunk_budget"] = args.chunk_budget
    def admission():
        return AdmissionController(args.num_slots, host_rate=args.host_rate,
                                   csd_rate=args.csd_rate, n_csds=args.csds)

    hub = None
    if args.trace_out or args.metrics_out or args.events_out:
        from repro.core.telemetry import TelemetryHub
        hub = TelemetryHub()

    faults = None
    if args.fault_trace:
        from repro.core.faults import FaultSchedule
        faults = FaultSchedule.load(args.fault_trace)
    elif args.mttf > 0:
        from repro.core.faults import FaultSchedule
        fault_seed = args.seed if args.fault_seed is None else args.fault_seed
        faults = FaultSchedule.from_rates(args.replicas, mttf_s=args.mttf,
                                          mttr_s=args.mttr, seed=fault_seed)

    if args.replicas > 1 or faults is not None or args.concurrent:
        # fault injection and the worker runtime need the cluster's
        # detector/retry machinery, so both route through ClusterEngine
        # even at --replicas 1
        speed = None
        if args.speed_factor:
            speed = [float(s) for s in args.speed_factor.split(",")]
        engine = ClusterEngine(cfg, params, n_drives=args.replicas,
                               routing=args.routing,
                               admission_factory=admission,
                               speed_factor=speed,
                               shard_replacement=not args.no_shard_replacement,
                               faults=faults, max_retries=args.max_retries,
                               hedge=args.hedge,
                               concurrent=args.concurrent,
                               dispatch_timeout_s=args.dispatch_timeout,
                               min_tick_s=args.min_tick_ms / 1e3,
                               telemetry=hub,
                               **engine_kw)
    else:
        engine = ServeEngine(cfg, params, admission=admission(),
                             telemetry=hub, **engine_kw)
    is_cluster = isinstance(engine, ClusterEngine)

    def export_telemetry(wall_s=None) -> None:
        """Dump the hub after the run: Perfetto trace, metrics JSON (with
        the same stats snapshots the summary printed from), raw events."""
        if hub is None:
            return
        stats_m = engine.stats.metrics()
        hub.publish("cluster" if is_cluster else "engine", stats_m)
        hub.publish("latency", engine.stats.latency.metrics(wall_s=wall_s))
        if args.trace_out:
            hub.write_chrome_trace(args.trace_out)
            print(f"[serve] trace written to {args.trace_out}")
        if args.metrics_out:
            hub.write_metrics(args.metrics_out)
            print(f"[serve] metrics written to {args.metrics_out}")
        if args.events_out:
            hub.write_jsonl(args.events_out)
            print(f"[serve] events written to {args.events_out}")

    if args.arrival:
        classes = DEFAULT_CLASSES
        if args.slo_ms > 0:
            classes = tuple(PriorityClass(
                c.name, priority=c.priority, weight=c.weight,
                slo_s=args.slo_ms / 1e3, prompt_range=c.prompt_range,
                max_new_range=c.max_new_range) for c in DEFAULT_CLASSES)
        wl = WorkloadConfig(n_requests=args.requests or 32,
                            vocab_size=cfg.vocab_size, arrival=args.arrival,
                            rate=args.rate, classes=classes, seed=args.seed)
        t0 = time.perf_counter()
        report = replay_open_loop(engine, generate_trace(wl))
        dt = time.perf_counter() - t0
        lat = engine.stats.latency
        # one source of truth: the goodput/attainment the export carries
        # are the SAME dict entries printed here (no inline recompute)
        lm = lat.metrics(wall_s=report.wall_s)
        n_tok = sum(len(r.tokens) for r in report.results)
        print(f"[serve] {args.arch}: open-loop {args.arrival}@{args.rate}/s "
              f"({args.sched}): {report.submitted} requests, {n_tok} tokens "
              f"in {dt:.2f}s wall / {report.wall_s:.2f}s serving clock")
        print(f"[serve] {lat.summary()}")
        print(f"[serve] goodput under SLO: "
              f"{lm['goodput_qps']:.2f} qps "
              f"(attainment {lm['slo_attainment']:.0%}, "
              f"{report.shed} shed)")
        summary = engine.summary() if is_cluster \
            else engine.stats.summary()
        for line in summary.splitlines():
            print(f"[serve] {line}")
        export_telemetry(wall_s=report.wall_s)
        if is_cluster:
            engine.close()      # joins worker threads (no-op if serial)
        return 0

    rng = np.random.default_rng(args.seed)
    if args.trace:
        requests = _load_trace(args.trace, args.max_new)
    elif args.requests:
        requests = [
            (rng.integers(0, cfg.vocab_size,
                          rng.integers(args.min_prompt,
                                       args.prompt_len + 1)).tolist(),
             args.max_new)
            for _ in range(args.requests)]
    else:
        requests = [(rng.integers(0, cfg.vocab_size,
                                  args.prompt_len).tolist(), args.max_new)
                    for _ in range(args.batch)]

    if not requests:
        print("[serve] no requests (empty --trace file?)")
        return 1

    t0 = time.perf_counter()
    for i, (prompt, max_new) in enumerate(requests):
        if is_cluster:
            shard = i % args.shards if args.shards else None
            engine.submit(prompt, max_new=max_new, shard_id=shard)
        else:
            engine.submit(prompt, max_new=max_new)
    results = engine.run_until_complete()
    dt = time.perf_counter() - t0

    # token count from the stats registry (the same number the metrics
    # export carries), not recomputed from the result list
    n_tok = engine.stats.metrics()["tokens"]
    print(f"[serve] {args.arch}: {len(results)} requests, {n_tok} tokens in "
          f"{dt:.2f}s ({n_tok / max(dt, 1e-9):.1f} tok/s); "
          f"first: {results[0].tokens[:8]}")
    summary = engine.summary() if is_cluster \
        else engine.stats.summary()
    for line in summary.splitlines():
        print(f"[serve] {line}")
    export_telemetry(wall_s=dt)
    kvs = engine.kv_stats()                 # cluster: one entry per drive
    for kv in kvs if isinstance(kvs, list) else [kvs]:
        print(f"[serve] KV[{kv['layout']}]: peak "
              f"{kv['peak_kv_bytes'] / 1e6:.3f} MB vs dense "
              f"{kv['dense_kv_bytes'] / 1e6:.3f} MB "
              f"(page_size={kv['page_size']})")
    if is_cluster:
        engine.close()          # joins worker threads (no-op if serial)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
