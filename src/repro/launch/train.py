"""End-to-end training driver.

Examples:
  # ~100M-param LM for a few hundred steps on CPU (examples/train_lm.py
  # wraps this with a ready-made config):
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --smoke \
      --steps 200 --global-batch 8 --seq-len 256 --ckpt-dir /tmp/ckpt

  # production shapes lower through the same builder the dry-run uses.
"""
from __future__ import annotations

import argparse

from repro.config import get_config, reduced_config
from repro.data import DataConfig
from repro.launch.elastic import FailureInjector
from repro.train.train_loop import TrainConfig, train


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.smoke else get_config(args.arch)
    data_cfg = DataConfig(seq_len=args.seq_len, global_batch=args.global_batch,
                          vocab_size=cfg.vocab_size, seed=args.seed)
    tcfg = TrainConfig(steps=args.steps, lr=args.lr, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every, log_every=args.log_every,
                       seed=args.seed)

    injector = FailureInjector(None)

    def cb(step, metrics):
        injector.maybe_fail(step)

    state = train(cfg, data_cfg, tcfg, metrics_cb=cb)
    print(f"[train] done at step {state.step}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
