"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS *before* any jax
initialization and only then calls ``make_production_mesh``.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 two-pod (512 chips).

    Axis roles: "pod" — slow inter-pod link, pure DP (+ optional compressed
    grad sync / pipeline stages); "data" — DP + FSDP storage sharding;
    "model" — TP / EP / SP.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU multi-device tests (requires forced device count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
