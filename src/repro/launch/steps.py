"""Step builders: production train / prefill / decode steps with full
sharding specs — shared by the dry-run, the training loop, and the server.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ShapeConfig
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.sharding import ParallelPlan, ShardingRecipe, make_plan, make_recipe, param_specs


# ---------------------------------------------------------------------------
# Sharding spec pytrees
# ---------------------------------------------------------------------------


def params_sharding(recipe: ShardingRecipe, cfg: ModelConfig):
    shapes = M.abstract_params(cfg)
    return param_specs(recipe.plan, shapes)


def opt_sharding(recipe: ShardingRecipe, cfg: ModelConfig):
    ps = params_sharding(recipe, cfg)
    return {"m": ps, "v": ps, "step": P()}


def batch_sharding(recipe: ShardingRecipe, cfg: ModelConfig, shape: ShapeConfig):
    b = recipe.batch_axes or None
    out: Dict[str, P] = {}
    specs = M.input_specs(cfg, shape)
    for k, v in specs.items():
        if k == "caches":
            out[k] = cache_sharding(recipe, cfg, v)
        elif k == "pos":
            out[k] = P()
        elif k == "embeddings":
            out[k] = P(b, None, None)
        else:
            out[k] = P(b, None)
    return out


def _cache_leaf_spec(recipe: ShardingRecipe, names, shape) -> P:
    """Cache leaves are stacked: (num_groups, ...)."""
    b = recipe.batch_axes or None
    s = recipe.seq_axes or None
    tp = recipe.model_axis
    name = names[-1]
    plan = recipe.plan

    def fits(dim, axes):
        if axes is None:
            return False
        sz = 1
        for a in (axes if isinstance(axes, tuple) else (axes,)):
            sz *= plan.axis_size(a)
        return shape[dim] % sz == 0 and sz > 1

    if name in ("k", "v"):            # (ng, B, S, Hkv, dh)
        return P(None, b if fits(1, b) else None, s if fits(2, s) else None)
    if name in ("ckv", "krope"):      # (ng, B, S, R)
        return P(None, b if fits(1, b) else None, s if fits(2, s) else None)
    if name == "kpos":                # (ng, S)
        return P(None, s if fits(1, s) else None)
    if name == "conv":                # (ng, B, W-1, d_in)
        return P(None, b if fits(1, b) else None, None,
                 tp if fits(3, tp) else None)
    if name == "ssm":                 # (ng, B, d_in, N)
        return P(None, b if fits(1, b) else None, tp if fits(2, tp) else None)
    # mlstm C/n/m, slstm c/n/m/h: batch only
    return P(None, b if (len(shape) > 1 and fits(1, b)) else None)


def cache_sharding(recipe: ShardingRecipe, cfg: ModelConfig, cache_shapes):
    def spec(path, leaf):
        names = [str(p.key) for p in path if hasattr(p, "key")]
        return _cache_leaf_spec(recipe, names, leaf.shape)

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)


def to_named(recipe: ShardingRecipe, spec_tree):
    if recipe.mesh is None:
        return None
    return jax.tree.map(lambda s: NamedSharding(recipe.mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, recipe: ShardingRecipe,
                     opt_cfg: Optional[AdamWConfig] = None,
                     schedule_kwargs: Optional[dict] = None,
                     accum: Optional[int] = None):
    opt_cfg = opt_cfg or AdamWConfig(state_dtype=cfg.optimizer_state_dtype)
    sk = schedule_kwargs or {}
    accum = accum if accum is not None else cfg.grad_accum

    def _constrain_micro(mb):
        if recipe.mesh is None:
            return mb
        b = recipe.batch_axes or None

        def c(x):
            spec = P(b) if x.ndim == 2 else P(b, None, None)
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(recipe.mesh, spec))

        return jax.tree.map(c, mb)

    def train_step(params, opt_state, batch):
        if accum > 1:
            # microbatch gradient accumulation: activation footprint / accum;
            # the per-micro collectives overlap with the next micro's compute
            # (XLA async) — the paper's batch-ratio idea applied to time.
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)

            def mb_body(carry, mbatch):
                loss_acc, aux_acc, grads_acc = carry
                mbatch = _constrain_micro(mbatch)
                (loss, metrics), grads = jax.value_and_grad(
                    M.loss_fn, has_aux=True)(params, mbatch, cfg, recipe)
                grads_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), grads_acc, grads)
                return (loss_acc + loss, aux_acc + metrics["aux"], grads_acc), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, aux, grads), _ = jax.lax.scan(
                mb_body, (jnp.float32(0.0), jnp.float32(0.0), zeros), micro)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
            metrics = {"xent": loss, "aux": aux / accum,
                       "tokens": jnp.float32(batch["labels"].size)}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                M.loss_fn, has_aux=True)(params, batch, cfg, recipe)
        lr_scale = cosine_schedule(opt_state["step"], **sk)
        params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg,
                                             lr_scale)
        return params, opt_state, {**metrics, **om, "loss": loss}

    return train_step, opt_cfg


def build_prefill_step(cfg: ModelConfig, recipe: ShardingRecipe):
    def prefill_step(params, batch):
        return M.prefill_fn(params, batch, cfg, recipe)

    return prefill_step


def build_decode_step(cfg: ModelConfig, recipe: ShardingRecipe):
    def serve_step(params, caches, token, pos):
        return M.decode_fn(params, caches, token, pos, cfg, recipe)

    return serve_step


# ---------------------------------------------------------------------------
# Jit wiring per (arch, shape, mesh)
# ---------------------------------------------------------------------------


def jitted_step_for(cfg: ModelConfig, shape: ShapeConfig, recipe: ShardingRecipe):
    """Returns (jitted_fn, example_args (ShapeDtypeStructs)) for the cell."""
    specs = M.input_specs(cfg, shape)
    pspec = params_sharding(recipe, cfg)
    pshape = M.abstract_params(cfg)

    if shape.kind == "train":
        step, opt_cfg = build_train_step(cfg, recipe)
        ospec = opt_sharding(recipe, cfg)
        oshape = jax.eval_shape(functools.partial(adamw_init, cfg=opt_cfg), pshape)
        bspec = batch_sharding(recipe, cfg, shape)
        fn = jax.jit(step,
                     in_shardings=to_named(recipe, (pspec, ospec, bspec)),
                     out_shardings=to_named(recipe, (pspec, ospec,
                                                     jax.tree.map(lambda _: P(),
                                                                  {"xent": 0, "aux": 0, "tokens": 0,
                                                                   "grad_norm": 0, "loss": 0}))),
                     donate_argnums=(0, 1))
        return fn, (pshape, oshape, specs)

    if shape.kind == "prefill":
        step = build_prefill_step(cfg, recipe)
        bspec = batch_sharding(recipe, cfg, shape)
        cache_shapes = M.abstract_caches(cfg, shape.global_batch, shape.seq_len)
        cspec = cache_sharding(recipe, cfg, cache_shapes)
        b = recipe.batch_axes or None
        fn = jax.jit(step,
                     in_shardings=to_named(recipe, (pspec, bspec)),
                     out_shardings=to_named(recipe, (P(b), cspec)))
        return fn, (pshape, specs)

    # decode
    step = build_decode_step(cfg, recipe)
    bspec = batch_sharding(recipe, cfg, shape)
    b = recipe.batch_axes or None
    fn = jax.jit(step,
                 in_shardings=to_named(recipe, (pspec, bspec["caches"],
                                                P(b, None), P())),
                 out_shardings=to_named(recipe, (P(b), bspec["caches"])),
                 donate_argnums=(1,))
    return fn, (pshape, specs["caches"], specs["token"], specs["pos"])
