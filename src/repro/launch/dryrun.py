import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Must be run as a dedicated process (the two lines above force 512 host
devices *before* jax initializes — never set this in conftest/pyproject).

Usage:
  python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh pod          # every live cell
  python -m repro.launch.dryrun --all --mesh multipod     # 2-pod, 512 chips

Writes results/dryrun/<arch>__<shape>__<mesh>.json with memory analysis,
cost analysis, collective stats, and the three roofline terms.
"""
import argparse
import json
import pathlib
import subprocess
import sys
import time
import traceback

import jax

from repro.config import get_config, get_shape, shape_applicable, SHAPES
from repro.configs import ASSIGNED

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: pathlib.Path,
             fsdp=None, verbose=True, save_hlo=True, tag=""):
    from repro.analysis.roofline import from_compiled, model_flops_for
    from repro.launch.mesh import make_production_mesh
    from repro.launch import steps as S
    from repro.sharding import make_plan, make_recipe

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    chips = mesh.size
    plan = make_plan(mesh, cfg, fsdp=fsdp)
    recipe = make_recipe(plan, cfg, shape)

    t0 = time.perf_counter()
    fn, args = S.jitted_step_for(cfg, shape, recipe)
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        hlo = compiled.as_text()
        rf = from_compiled(compiled, chips, model_flops_for(cfg, shape),
                           hlo_text=hlo)

    mem_d = {k: float(getattr(mem, k)) for k in
             ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes")
             if hasattr(mem, k)}
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "status": "ok",
        "chips": chips,
        "fsdp": plan.fsdp,
        "batch_axes": recipe.batch_axes, "seq_axes": recipe.seq_axes,
        "memory_analysis": mem_d,
        "bytes_per_device": sum(mem_d.get(k, 0.0) for k in
                                ("argument_size_in_bytes", "temp_size_in_bytes")),
        "cost_flops": float(cost.get("flops", 0.0)),
        "cost_bytes": float(cost.get("bytes accessed", 0.0)),
        "roofline": rf.as_dict(),
        "lower_s": t_lower, "compile_s": t_compile,
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {mesh_kind}: "
              f"compute={rf.compute_s:.4f}s memory={rf.memory_s:.4f}s "
              f"collective={rf.collective_s:.4f}s dominant={rf.dominant} "
              f"MFU={rf.mfu:.1%} (lower {t_lower:.0f}s, compile {t_compile:.0f}s)")
        print(f"[dryrun]   memory_analysis: {mem_d}")
    out_dir.mkdir(parents=True, exist_ok=True)
    stem = f"{arch}__{shape_name}__{mesh_kind}" + (f"__{tag}" if tag else "")
    (out_dir / f"{stem}.json").write_text(json.dumps(result, indent=2, default=str))
    if save_hlo:
        import zstandard
        (out_dir / f"{stem}.hlo.zst").write_bytes(
            zstandard.ZstdCompressor(level=6).compress(hlo.encode()))
    return result


def all_cells():
    for arch in ASSIGNED:
        for shape_name in SHAPES:
            yield arch, shape_name


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--subprocess", action="store_true",
                    help="run each cell in a fresh process (isolates RAM)")
    ap.add_argument("--out", default=str(RESULTS))
    ap.add_argument("--fsdp", default=None, choices=[None, "on", "off"])
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    fsdp = None if args.fsdp is None else args.fsdp == "on"

    if args.all:
        failures = []
        for arch, shape_name in all_cells():
            target = out_dir / f"{arch}__{shape_name}__{args.mesh}.json"
            if target.exists():
                print(f"[dryrun] skip existing {target.name}")
                continue
            if args.subprocess:
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape_name,
                       "--mesh", args.mesh, "--out", str(out_dir)]
                r = subprocess.run(cmd)
                if r.returncode:
                    failures.append((arch, shape_name))
            else:
                try:
                    run_cell(arch, shape_name, args.mesh, out_dir, fsdp=fsdp)
                except Exception:
                    traceback.print_exc()
                    failures.append((arch, shape_name))
        if failures:
            print("[dryrun] FAILURES:", failures)
            return 1
        print("[dryrun] all cells passed")
        return 0

    run_cell(args.arch, args.shape, args.mesh, out_dir, fsdp=fsdp)
    return 0


if __name__ == "__main__":
    sys.exit(main())
