"""Version compatibility shims.

``shard_map`` moved from ``jax.experimental.shard_map`` (jax <= 0.4.x) to
``jax.shard_map`` (jax >= 0.6), and its replication-check kwarg was renamed
``check_rep`` -> ``check_vma`` along the way; Pallas renamed
``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams``.  All repro code
imports the wrappers below, which accept either spelling and forward
whatever the installed jax understands.
"""
from __future__ import annotations

import functools
import inspect

try:                                    # jax >= 0.6
    from jax import shard_map as _shard_map
except ImportError:                     # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = inspect.signature(_shard_map).parameters
_CHECK_KW = "check_vma" if "check_vma" in _PARAMS else (
    "check_rep" if "check_rep" in _PARAMS else None)


@functools.wraps(_shard_map)
def shard_map(f, *, check_vma=None, check_rep=None, **kwargs):
    check = check_vma if check_vma is not None else check_rep
    if check is not None and _CHECK_KW is not None:
        kwargs[_CHECK_KW] = check
    return _shard_map(f, **kwargs)


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (new name) / ``TPUCompilerParams`` (old)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
