"""Pallas TPU kernel: single-query flash-decoding partial over a KV span.

This is the per-shard compute unit of ISP decode attention (DESIGN.md §2):
the shard owns a KV span resident in HBM; the query is tiny.  We stream KV
blocks through VMEM, maintain an online-softmax state, and emit the
(acc, l, m) partial that the cross-shard combine psums.

  grid = (B, Hkv, num_kv_blocks)
  q block (G, dh); k/v block (kc, dh); kpos block (kc,)
  scratch: acc (G, dh) f32, m (G, 1), l (G, 1)

Ring buffers are handled by the explicit ``kpos`` slot-position array —
masking is data-driven, so the same kernel serves full, sliding-window and
ring-buffer caches.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _kernel(cur_ref, q_ref, k_ref, v_ref, kpos_ref,
            acc_ref, l_ref, m_ref, acc_s, m_s, l_s, *,
            scale: float, window: Optional[int], nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_s[...] = jnp.zeros_like(acc_s)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0, 0].astype(jnp.float32)                 # (G, dh)
    k = k_ref[0, 0].astype(jnp.float32)                 # (kc, dh)
    v = v_ref[0, 0].astype(jnp.float32)
    kpos = kpos_ref[...]                                # (kc,)
    cur = cur_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = (kpos >= 0) & (kpos <= cur)
    if window is not None:
        valid &= kpos > cur - window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(valid[None, :], p, 0.0)
    l_s[...] = l_s[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        acc_ref[0, 0] = acc_s[...]
        l_ref[0, 0] = l_s[..., 0]
        m_ref[0, 0] = m_s[..., 0]


def decode_partial(q, k, v, kpos, cur_pos, *, window: Optional[int] = None,
                   scale: Optional[float] = None, kv_block: int = 128,
                   interpret: bool = False):
    """q: (B,H,dh); k/v: (B,S,Hkv,dh); kpos: (S,); cur_pos: scalar int32.

    Returns (acc (B,H,dh) f32, l (B,H) f32, m (B,H) f32).
    """
    B, H, dh = q.shape
    _, S, Hkv, _ = k.shape
    g = H // Hkv
    scale = dh ** -0.5 if scale is None else scale
    kc = min(kv_block, S)
    pad = (-S) % kc
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad), constant_values=-1)
    nk = (S + pad) // kc

    q3 = q.reshape(B, Hkv, g, dh)
    k4 = k.transpose(0, 2, 1, 3)                        # (B, Hkv, S, dh)
    v4 = v.transpose(0, 2, 1, 3)
    cur = jnp.asarray(cur_pos, jnp.int32).reshape(1)

    kernel = functools.partial(_kernel, scale=scale, window=window, nk=nk)
    acc, l, m = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, dh), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, kc, dh), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, kc, dh), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((kc,), lambda b, h, ki: (ki,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, g), lambda b, h, ki: (b, h, 0)),
            pl.BlockSpec((1, 1, g), lambda b, h, ki: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, g, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, g), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, g), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, dh), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(cur, q3, k4, v4, kpos)
    return (acc.reshape(B, H, dh), l.reshape(B, H), m.reshape(B, H))
