"""Pallas TPU flash attention (forward).

Blocked causal/windowed attention with online softmax, tiled for VMEM:
  grid = (B, Hkv, G, num_q_blocks, num_kv_blocks)
  q block  (qc, dh)   VMEM        k/v block (kc, dh)  VMEM
  scratch: acc (qc, dh) f32, m (qc, 1) f32, l (qc, 1) f32 — persisted
  across the kv grid dimension ("arbitrary" semantics, innermost).

GQA is handled in the index maps (kv head = grid h, q head = (h, g)) so the
KV tiles are fetched once per kv head, not per q head.  MXU alignment: pick
qc/kc multiples of 128 at scale; tests sweep small interpret-mode shapes.

The backward pass reuses the reference flash backward (custom_vjp) — the
forward kernel is the serving hot spot; training uses the jnp chunked path
whose math is identical.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: Optional[int],
            q_offset: int, kv_valid: int, kc_total: int):
    qi = pl.program_id(3)
    ki = pl.program_id(4)
    qc = q_ref.shape[-2]
    kc = k_ref.shape[-2]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, 0].astype(jnp.float32)             # (qc, dh)
    k = k_ref[0, 0].astype(jnp.float32)                # (kc, dh)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = q_offset + qi * qc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 0)
    kpos = ki * kc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)
    mask = kpos < kv_valid
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                # (qc, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == kc_total - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0, 1.0, l)
        o_ref[0, 0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, scale: Optional[float] = None,
                    q_block: int = 128, kv_block: int = 128,
                    interpret: bool = False):
    """q: (B, Sq, H, dh); k/v: (B, Skv, Hkv, dh).  Forward only."""
    B, Sq, H, dh = q.shape
    _, Skv, Hkv, dhv = v.shape
    assert dh == k.shape[-1] and dhv == dh, "pallas kernel: uniform head dims"
    g = H // Hkv
    scale = dh ** -0.5 if scale is None else scale
    qc = min(q_block, Sq)
    kc = min(kv_block, Skv)

    def pad_to(x, mult, axis):
        pad = (-x.shape[axis]) % mult
        if pad:
            widths = [(0, 0)] * x.ndim
            widths[axis] = (0, pad)
            x = jnp.pad(x, widths)
        return x

    qp = pad_to(q, qc, 1)
    kp = pad_to(k, kc, 1)
    vp = pad_to(v, kc, 1)
    nq = qp.shape[1] // qc
    nk = kp.shape[1] // kc

    # (B, S, H, dh) -> (B, Hkv, G, S, dh) / (B, Hkv, S, dh) for blocked access
    q5 = qp.reshape(B, nq * qc, Hkv, g, dh).transpose(0, 2, 3, 1, 4)
    k4 = kp.transpose(0, 2, 1, 3)
    v4 = vp.transpose(0, 2, 1, 3)

    grid = (B, Hkv, g, nq, nk)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, kv_valid=Skv, kc_total=nk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, qc, dh), lambda b, h, gg, qi, ki: (b, h, gg, qi, 0)),
            pl.BlockSpec((1, 1, kc, dh), lambda b, h, gg, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, kc, dh), lambda b, h, gg, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, qc, dh),
                               lambda b, h, gg, qi, ki: (b, h, gg, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, nq * qc, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qc, dh), jnp.float32),
            pltpu.VMEM((qc, 1), jnp.float32),
            pltpu.VMEM((qc, 1), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "parallel", "arbitrary")),
        interpret=interpret,
    )(q5, k4, v4)

    out = out.transpose(0, 3, 1, 2, 4).reshape(B, nq * qc, H, dh)
    return out[:, :Sq]
