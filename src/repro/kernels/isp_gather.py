"""Pallas TPU kernel: ISP embedding gather (+ fused pooling).

The per-shard unit of the paper's "send indexes, not data": the local table
shard stays in place; for a block of global indices we fetch only the rows
this shard owns (zeros elsewhere — the cross-shard psum completes the
lookup).

Tiling: grid = (num_index_blocks, num_d_blocks).  The table is tiled along
D so each kernel instance holds a (V_local, dblk) panel in VMEM (e.g.
16384 × 128 × 2B = 4 MB for gemma3's 262k vocab over 16 shards) and rows
are fetched with dynamic VMEM addressing — the TPU-native analogue of the
CSD's flash-to-ISP path.

``isp_gather_pool`` fuses RecSSD-style segment-sum aggregation: pooled
embedding-bag outputs leave the kernel instead of raw rows, cutting the
result bytes by the pooling factor (the paper's data-transfer reduction).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _gather_kernel(off_ref, idx_ref, table_ref, w_ref, out_ref, *, ib: int,
                   weighted: bool):
    v_loc = table_ref.shape[0]
    off = off_ref[0]

    def body(i, _):
        idx = idx_ref[i] - off
        ok = (idx >= 0) & (idx < v_loc)
        safe = jnp.clip(idx, 0, v_loc - 1)
        row = table_ref[safe, :].astype(jnp.float32)
        scale = jnp.where(ok, 1.0, 0.0)
        if weighted:
            scale = scale * w_ref[i]
        out_ref[i, :] = (row * scale).astype(out_ref.dtype)
        return 0

    jax.lax.fori_loop(0, ib, body, 0)


def isp_gather(table, indices, *, shard_offset=0, weights=None,
               idx_block: int = 256, d_block: int = 512,
               interpret: bool = False):
    """table: (V_local, D); indices: (...,) int32 global ids.

    Returns (..., D) rows (zero outside [shard_offset, shard_offset+V_local)).
    """
    shape = indices.shape
    idx = indices.reshape(-1)
    n = idx.shape[0]
    v_loc, d = table.shape
    ib = min(idx_block, max(n, 1))
    db = min(d_block, d)
    pad_n = (-n) % ib
    if pad_n:
        idx = jnp.pad(idx, (0, pad_n), constant_values=-1)
    w = weights.reshape(-1).astype(jnp.float32) if weights is not None else \
        jnp.ones((1,), jnp.float32)
    if weights is not None and pad_n:
        w = jnp.pad(w, (0, pad_n))
    pad_d = (-d) % db
    if pad_d:
        table = jnp.pad(table, ((0, 0), (0, pad_d)))
    ni = idx.shape[0] // ib
    nd = table.shape[1] // db
    off = jnp.asarray(shard_offset, jnp.int32).reshape(1)

    kernel = functools.partial(_gather_kernel, ib=ib,
                               weighted=weights is not None)
    out = pl.pallas_call(
        kernel,
        grid=(ni, nd),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((ib,), lambda i, j: (i,)),
            pl.BlockSpec((v_loc, db), lambda i, j: (0, j)),
            pl.BlockSpec((ib,), lambda i, j: (i,)) if weights is not None
            else pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((ib, db), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((idx.shape[0], table.shape[1]), table.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(off, idx, table, w)
    return out[:n, :d].reshape(shape + (d,))


def _gather_pool_kernel(off_ref, idx_ref, seg_ref, table_ref, w_ref, out_ref, *,
                        ib: int, weighted: bool, n_seg: int):
    v_loc = table_ref.shape[0]
    off = off_ref[0]
    i_blk = pl.program_id(0)

    @pl.when(i_blk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    def body(i, _):
        idx = idx_ref[i] - off
        seg = seg_ref[i]
        ok = (idx >= 0) & (idx < v_loc) & (seg >= 0) & (seg < n_seg)
        safe = jnp.clip(idx, 0, v_loc - 1)
        seg_safe = jnp.clip(seg, 0, n_seg - 1)
        row = table_ref[safe, :].astype(jnp.float32)
        scale = jnp.where(ok, 1.0, 0.0)
        if weighted:
            scale = scale * w_ref[i]
        out_ref[seg_safe, :] = out_ref[seg_safe, :] + row * scale
        return 0

    jax.lax.fori_loop(0, ib, body, 0)


def isp_gather_pool(table, indices, segment_ids, num_segments: int, *,
                    shard_offset=0, weights=None, idx_block: int = 256,
                    d_block: int = 512, interpret: bool = False):
    """Fused gather + segment-sum (RecSSD embedding-bag offload).

    indices/segment_ids: (N,).  Returns (num_segments, D) fp32.
    Grid iterates index blocks sequentially (accumulation), D in parallel.
    """
    idx = indices.reshape(-1)
    seg = segment_ids.reshape(-1)
    n = idx.shape[0]
    v_loc, d = table.shape
    ib = min(idx_block, max(n, 1))
    db = min(d_block, d)
    pad_n = (-n) % ib
    if pad_n:
        idx = jnp.pad(idx, (0, pad_n), constant_values=-1)
        seg = jnp.pad(seg, (0, pad_n), constant_values=-1)
    w = weights.reshape(-1).astype(jnp.float32) if weights is not None else \
        jnp.ones((1,), jnp.float32)
    if weights is not None and pad_n:
        w = jnp.pad(w, (0, pad_n))
    pad_d = (-d) % db
    if pad_d:
        table = jnp.pad(table, ((0, 0), (0, pad_d)))
    ni = idx.shape[0] // ib
    nd = table.shape[1] // db
    off = jnp.asarray(shard_offset, jnp.int32).reshape(1)

    kernel = functools.partial(_gather_pool_kernel, ib=ib,
                               weighted=weights is not None, n_seg=num_segments)
    out = pl.pallas_call(
        kernel,
        grid=(ni, nd),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((ib,), lambda i, j: (i,)),
            pl.BlockSpec((ib,), lambda i, j: (i,)),
            pl.BlockSpec((v_loc, db), lambda i, j: (0, j)),
            pl.BlockSpec((ib,), lambda i, j: (i,)) if weights is not None
            else pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((num_segments, db), lambda i, j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((num_segments, table.shape[1]), jnp.float32),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary", "parallel")),
        interpret=interpret,
    )(off, idx, seg, table, w)
    return out[:, :d]
