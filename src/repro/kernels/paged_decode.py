"""Pallas TPU kernel: fused ragged decode attention over a paged KV pool.

The continuous-batching serve engine keeps KV in fixed-size pages
(``core.kv_pages``): each batch slot owns a page table mapping logical
pages to physical pool pages, and slots sit at different positions.  This
kernel walks the page table — the physical page id is read from a
scalar-prefetch argument inside the BlockSpec index map, so only the pages
a slot actually owns are streamed through VMEM — and computes each slot's
masked attention in one pass:

  grid = (B, Hkv, max_logical_pages)
  scalar prefetch: pages (B, maxp) int32, cur (B,) int32
  q block (G, dh); k/v block (page_size, dh) — one physical page
  scratch: acc (G, dh) f32, m (G, 1), l (G, 1)

Unallocated logical pages (table entry -1) are clamped to physical page 0
for the DMA and masked out by position validity, so the grid shape stays
static while the *useful* work tracks live tokens.  The jnp reference
(``paged_decode_partial_ref``) materializes the gathered view and reuses
``ref.decode_partial_masked`` — the oracle the per-slot strip path also
uses, which is what makes paged decode token-identical to strip decode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.core import kv_pages
from repro.kernels import ref

NEG_INF = -1e30


def paged_decode_partial_ref(q, kpool, vpool, pages, cur_pos, *,
                             window: Optional[int] = None,
                             scale: Optional[float] = None):
    """Pure-jnp oracle: gather the paged pool into the per-slot strip view
    and run the strip-path reference partial on it.

    q: (B, H, dh); kpool/vpool: (P(+scratch), ps, Hkv, dh);
    pages: (B, maxp) int32; cur_pos: (B,) or scalar int32.
    Returns (acc (B,H,dhv) f32, l (B,H) f32, m (B,H) f32).
    """
    ps = kpool.shape[1]
    k, v, kpos = kv_pages.pages_to_strips((kpool, vpool), pages, ps)
    cur = jnp.asarray(cur_pos, jnp.int32)
    if cur.ndim == 0:
        cur = jnp.broadcast_to(cur, (q.shape[0],))
    return ref.decode_partial_masked(q, k, v, kpos, cur, window=window,
                                     scale=scale)


def _kernel(pages_ref, cur_ref, q_ref, k_ref, v_ref,
            acc_ref, l_ref, m_ref, acc_s, m_s, l_s, *,
            scale: float, window: Optional[int], ps: int, nk: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_s[...] = jnp.zeros_like(acc_s)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0, 0].astype(jnp.float32)                 # (G, dh)
    k = k_ref[0, 0].astype(jnp.float32)                 # (ps, dh)
    v = v_ref[0, 0].astype(jnp.float32)
    page = pages_ref[b, ki]
    cur = cur_ref[b]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = ki * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
    valid = (page >= 0) & (pos <= cur)
    if window is not None:
        valid &= pos > cur - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(valid, p, 0.0)
    l_s[...] = l_s[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        acc_ref[0, 0] = acc_s[...]
        l_ref[0, 0] = l_s[..., 0]
        m_ref[0, 0] = m_s[..., 0]


def paged_decode_partial(q, kpool, vpool, pages, cur_pos, *,
                         window: Optional[int] = None,
                         scale: Optional[float] = None,
                         interpret: bool = False):
    """q: (B,H,dh); kpool/vpool: (P(+scratch), ps, Hkv, dh); pages: (B,maxp)
    int32 physical page ids (-1 = unallocated); cur_pos: (B,) int32 per-slot
    current positions (scalar broadcasts).

    Returns (acc (B,H,dh) f32, l (B,H) f32, m (B,H) f32) — the same
    combinable partials as ``isp_decode.decode_partial``.
    """
    B, H, dh = q.shape
    P, ps, Hkv, _ = kpool.shape
    maxp = pages.shape[1]
    g = H // Hkv
    scale = dh ** -0.5 if scale is None else scale

    q3 = q.reshape(B, Hkv, g, dh)
    k4 = kpool.transpose(2, 0, 1, 3)                    # (Hkv, P, ps, dh)
    v4 = vpool.transpose(2, 0, 1, 3)
    pages = pages.astype(jnp.int32)
    cur = jnp.asarray(cur_pos, jnp.int32)
    if cur.ndim == 0:
        cur = jnp.broadcast_to(cur, (B,))

    def page_idx(b, h, ki, pages_ref, cur_ref):
        # unallocated -> page 0 (masked in-kernel); keeps the DMA in range
        return (h, jnp.maximum(pages_ref[b, ki], 0), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, maxp),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh),
                         lambda b, h, ki, pages_ref, cur_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, ps, dh), page_idx),
            pl.BlockSpec((1, 1, ps, dh), page_idx),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, dh),
                         lambda b, h, ki, pages_ref, cur_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, g),
                         lambda b, h, ki, pages_ref, cur_ref: (b, h, 0)),
            pl.BlockSpec((1, 1, g),
                         lambda b, h, ki, pages_ref, cur_ref: (b, h, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, dh), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel, scale=scale, window=window,
                               ps=ps, nk=maxp)
    acc, l, m = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, g, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, g), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, g), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pages, cur, q3, k4, v4)
    return (acc.reshape(B, H, dh), l.reshape(B, H), m.reshape(B, H))
