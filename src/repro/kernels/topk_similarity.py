"""Pallas TPU kernel: cosine-similarity top-k over a sharded corpus.

The recommender benchmark's hot spot (paper §IV-B2): the similarity corpus
lives on the shard ("drive"); a query block streams corpus tiles through
VMEM, maintaining a running top-k in scratch.  Only (k scores, k ids) per
query leave the kernel — the 58k-movie matrix never does.

  grid = (num_q_blocks, num_corpus_tiles)    corpus innermost (arbitrary)
  scratch: top_s (qb, k) f32, top_i (qb, k) i32

Inputs are expected L2-normalized (ops.py normalizes) so the tile compute
is a pure MXU matmul; merging is k iterations of max-extract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _kernel(q_ref, c_ref, s_out, i_out, top_s, top_i, *, k: int, nt: int,
            n_corpus: int):
    ti = pl.program_id(1)
    qb = q_ref.shape[0]
    ct = c_ref.shape[0]

    @pl.when(ti == 0)
    def _init():
        top_s[...] = jnp.full_like(top_s, NEG_INF)
        top_i[...] = jnp.full_like(top_i, -1)

    q = q_ref[...].astype(jnp.float32)                  # (qb, D)
    c = c_ref[...].astype(jnp.float32)                  # (ct, D)
    sims = jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    ids = ti * ct + jax.lax.broadcasted_iota(jnp.int32, (qb, ct), 1)
    sims = jnp.where(ids < n_corpus, sims, NEG_INF)

    # merge tile into running top-k: k rounds of max-extract over the union
    merged_s = jnp.concatenate([top_s[...], sims], axis=1)       # (qb, k+ct)
    merged_i = jnp.concatenate([top_i[...], ids], axis=1)

    def extract(j, carry):
        ms, mi, outs, outi = carry
        best = ms.max(axis=1, keepdims=True)                     # (qb,1)
        am = jnp.argmax(ms, axis=1)                              # (qb,)
        bi = jnp.take_along_axis(mi, am[:, None], axis=1)        # (qb,1)
        outs = jax.lax.dynamic_update_slice(outs, best, (0, j))
        outi = jax.lax.dynamic_update_slice(outi, bi, (0, j))
        # knock out the winner
        hit = jax.lax.broadcasted_iota(jnp.int32, ms.shape, 1) == am[:, None]
        ms = jnp.where(hit, NEG_INF, ms)
        return ms, mi, outs, outi

    outs0 = jnp.zeros((qb, k), jnp.float32)
    outi0 = jnp.zeros((qb, k), jnp.int32)
    _, _, outs, outi = jax.lax.fori_loop(
        0, k, extract, (merged_s, merged_i, outs0, outi0))
    top_s[...] = outs
    top_i[...] = outi

    @pl.when(ti == nt - 1)
    def _finish():
        s_out[...] = top_s[...]
        i_out[...] = top_i[...]


def topk_similarity(queries, corpus, k: int, *, q_block: int = 128,
                    corpus_tile: int = 512, interpret: bool = False):
    """queries: (Q, D); corpus: (N, D).  Returns (scores (Q,k), ids (Q,k))."""
    qn = queries.astype(jnp.float32)
    qn = qn / jnp.maximum(jnp.linalg.norm(qn, axis=-1, keepdims=True), 1e-9)
    cn = corpus.astype(jnp.float32)
    cn = cn / jnp.maximum(jnp.linalg.norm(cn, axis=-1, keepdims=True), 1e-9)

    Q, D = qn.shape
    N, _ = cn.shape
    qb = min(q_block, Q)
    ct = min(corpus_tile, N)
    pad_q = (-Q) % qb
    pad_n = (-N) % ct
    if pad_q:
        qn = jnp.pad(qn, ((0, pad_q), (0, 0)))
    if pad_n:
        cn = jnp.pad(cn, ((0, pad_n), (0, 0)))
    nq = qn.shape[0] // qb
    nt = cn.shape[0] // ct

    kernel = functools.partial(_kernel, k=k, nt=nt, n_corpus=N)
    scores, ids = pl.pallas_call(
        kernel,
        grid=(nq, nt),
        in_specs=[
            pl.BlockSpec((qb, D), lambda qi, ti: (qi, 0)),
            pl.BlockSpec((ct, D), lambda qi, ti: (ti, 0)),
        ],
        out_specs=[
            pl.BlockSpec((qb, k), lambda qi, ti: (qi, 0)),
            pl.BlockSpec((qb, k), lambda qi, ti: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn.shape[0], k), jnp.float32),
            jax.ShapeDtypeStruct((qn.shape[0], k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((qb, k), jnp.float32),
            pltpu.VMEM((qb, k), jnp.int32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qn, cn)
    return scores[:Q], ids[:Q]
