"""Pure-jnp reference oracles for every Pallas kernel, plus the scalable
chunked (online-softmax) attention used as the portable execution path.

Layout conventions:
  q:      (B, Sq, H,   Dh)
  k, v:   (B, Skv, Hkv, Dh)       H % Hkv == 0 (GQA)
  tables: (V, D)
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Naive attention oracle (small shapes only — tests)
# ---------------------------------------------------------------------------


def naive_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, scale: Optional[float] = None) -> jax.Array:
    """Full-materialization attention.  Oracle for flash/chunked paths."""
    B, Sq, H, dh = q.shape
    _, Skv, Hkv, _ = k.shape
    g = H // Hkv
    scale = dh ** -0.5 if scale is None else scale
    qg = q.reshape(B, Sq, Hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k.astype(jnp.float32)) * scale
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# Chunked flash attention (scan-based online softmax, custom VJP)
# ---------------------------------------------------------------------------


class _AttnCfg(NamedTuple):
    causal: bool
    window: Optional[int]
    q_offset: int
    scale: float
    q_chunk: int
    kv_chunk: int


def _pad_axis(x, multiple, axis):
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _kv_chunk_starts(cfg: _AttnCfg, nq_idx, skv_padded: int):
    """Static count + dynamic starts of kv chunks visited by q chunk nq_idx."""
    kc = cfg.kv_chunk
    if cfg.window is None:
        # full (causal) range: every kv chunk, masked.
        n_chunks = skv_padded // kc
        starts = jnp.arange(n_chunks) * kc
    else:
        # windowed: only chunks overlapping [q_lo - window + 1, q_hi]
        span = cfg.window + cfg.q_chunk + kc
        n_chunks = -(-span // kc)
        q_hi = cfg.q_offset + (nq_idx + 1) * cfg.q_chunk   # exclusive
        base = q_hi - n_chunks * kc
        base = jnp.clip(base, 0, max(skv_padded - n_chunks * kc, 0))
        base = (base // kc) * kc
        starts = base + jnp.arange(n_chunks) * kc
    return n_chunks, starts


def _attend_block(qblk, kblk, vblk, qpos, kpos, skv_valid, cfg, m, l, acc):
    """One online-softmax update.  qblk: (B,qc,Hkv,G,dh), kblk/vblk: (B,kc,Hkv,dh)."""
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qblk.astype(jnp.float32),
                   kblk.astype(jnp.float32)) * cfg.scale
    mask = kpos[None, :] < skv_valid
    if cfg.causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if cfg.window is not None:
        mask &= kpos[None, :] > qpos[:, None] - cfg.window
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)   # (1,qc,1,1,kc)
    m_new = jnp.maximum(m, s.max(axis=-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_new = l * alpha + p.sum(axis=-1)
    acc_new = acc * alpha[..., None] + jnp.einsum(
        "bqhgk,bkhd->bqhgd", p, vblk.astype(jnp.float32))
    return m_new, l_new, acc_new


def _flash_fwd_impl(q, k, v, cfg: _AttnCfg) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (B,Sq,H,dh), lse (B,Sq,H) fp32)."""
    B, Sq, H, dh = q.shape
    dhv = v.shape[-1]
    _, Skv, Hkv, _ = k.shape
    g = H // Hkv
    qc = min(cfg.q_chunk, Sq)
    kc = min(cfg.kv_chunk, Skv)
    cfg = cfg._replace(q_chunk=qc, kv_chunk=kc)
    qp = _pad_axis(q, qc, 1)
    kp = _pad_axis(k, kc, 1)
    vp = _pad_axis(v, kc, 1)
    if cfg.window is not None:
        # windowed path slices a fixed number of kv chunks; guarantee the kv
        # buffer is at least that long so starts stay distinct and in range.
        need = (-(-(cfg.window + qc + kc) // kc)) * kc
        if kp.shape[1] < need:
            kp = _pad_axis(kp, need, 1)
            vp = _pad_axis(vp, need, 1)
    sq_p, skv_p = qp.shape[1], kp.shape[1]
    nq = sq_p // qc

    q_chunks = qp.reshape(B, nq, qc, Hkv, g, dh).transpose(1, 0, 2, 3, 4, 5)

    def q_body(_, inputs):
        qi, qblk = inputs

        def kv_body(carry, start):
            m, l, acc = carry
            kblk = jax.lax.dynamic_slice_in_dim(kp, start, kc, axis=1)
            vblk = jax.lax.dynamic_slice_in_dim(vp, start, kc, axis=1)
            qpos = cfg.q_offset + qi * qc + jnp.arange(qc)
            kpos = start + jnp.arange(kc)
            return _attend_block(qblk, kblk, vblk, qpos, kpos, Skv, cfg, m, l, acc), None

        n_chunks, starts = _kv_chunk_starts(cfg, qi, skv_p)
        m0 = jnp.full((B, qc, Hkv, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, qc, Hkv, g), jnp.float32)
        a0 = jnp.zeros((B, qc, Hkv, g, dhv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), starts)
        l_safe = jnp.where(l == 0, 1.0, l)
        out = (acc / l_safe[..., None]).astype(q.dtype)
        lse = m + jnp.log(l_safe)
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_body, None, (jnp.arange(nq), q_chunks))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, sq_p, H, dhv)[:, :Sq]
    lse = lses.transpose(1, 0, 2, 3, 4).reshape(B, sq_p, H)[:, :Sq]
    return out, lse


def _flash_bwd_impl(q, k, v, out, lse, dout, cfg: _AttnCfg):
    """Flash-attention backward: recompute scores chunkwise."""
    B, Sq, H, dh = q.shape
    dhv = v.shape[-1]
    _, Skv, Hkv, _ = k.shape
    g = H // Hkv
    qc = min(cfg.q_chunk, Sq)
    kc = min(cfg.kv_chunk, kv := Skv)
    cfg = cfg._replace(q_chunk=qc, kv_chunk=kc)
    qp = _pad_axis(q, qc, 1)
    kp = _pad_axis(k, kc, 1)
    vp = _pad_axis(v, kc, 1)
    if cfg.window is not None:
        need = (-(-(cfg.window + qc + kc) // kc)) * kc
        if kp.shape[1] < need:
            kp = _pad_axis(kp, need, 1)
            vp = _pad_axis(vp, need, 1)
    op = _pad_axis(out, qc, 1)
    dop = _pad_axis(dout, qc, 1)
    lsep = _pad_axis(lse, qc, 1)
    sq_p, skv_p = qp.shape[1], kp.shape[1]
    nq = sq_p // qc

    # D_i = rowsum(dout_i * out_i)  (B, Sq, H)
    delta = jnp.sum(dop.astype(jnp.float32) * op.astype(jnp.float32), axis=-1)

    def rs(x, n, c, last):  # (B, n*c, ...) -> (n, B, c, ...)
        return x.reshape((B, n, c) + last).transpose((1, 0, 2) + tuple(range(3, 3 + len(last))))

    q_chunks = rs(qp.reshape(B, sq_p, Hkv, g, dh), nq, qc, (Hkv, g, dh))
    do_chunks = rs(dop.reshape(B, sq_p, Hkv, g, dhv), nq, qc, (Hkv, g, dhv))
    lse_chunks = rs(lsep.reshape(B, sq_p, Hkv, g), nq, qc, (Hkv, g))
    dl_chunks = rs(delta.reshape(B, sq_p, Hkv, g), nq, qc, (Hkv, g))

    dk0 = jnp.zeros((B, skv_p, Hkv, dh), jnp.float32)
    dv0 = jnp.zeros((B, skv_p, Hkv, dhv), jnp.float32)

    def q_body(carry, inputs):
        dk, dv = carry
        qi, qblk, doblk, lseblk, dlblk = inputs

        def kv_body(inner, start):
            dq_acc, dk, dv = inner
            kblk = jax.lax.dynamic_slice_in_dim(kp, start, kc, axis=1)
            vblk = jax.lax.dynamic_slice_in_dim(vp, start, kc, axis=1)
            qpos = cfg.q_offset + qi * qc + jnp.arange(qc)
            kpos = start + jnp.arange(kc)
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qblk.astype(jnp.float32),
                           kblk.astype(jnp.float32)) * cfg.scale
            mask = kpos[None, :] < Skv
            if cfg.causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if cfg.window is not None:
                mask &= kpos[None, :] > qpos[:, None] - cfg.window
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            p = jnp.exp(s - lseblk[..., None])                        # (B,qc,Hkv,g,kc)
            dp = jnp.einsum("bqhgd,bkhd->bqhgk", doblk.astype(jnp.float32),
                            vblk.astype(jnp.float32))
            ds = p * (dp - dlblk[..., None]) * cfg.scale
            dq_acc = dq_acc + jnp.einsum("bqhgk,bkhd->bqhgd", ds, kblk.astype(jnp.float32))
            dk_blk = jnp.einsum("bqhgk,bqhgd->bkhd", ds, qblk.astype(jnp.float32))
            dv_blk = jnp.einsum("bqhgk,bqhgd->bkhd", p, doblk.astype(jnp.float32))
            upd = jax.lax.dynamic_slice_in_dim(dk, start, kc, axis=1) + dk_blk
            dk = jax.lax.dynamic_update_slice_in_dim(dk, upd, start, axis=1)
            upd = jax.lax.dynamic_slice_in_dim(dv, start, kc, axis=1) + dv_blk
            dv = jax.lax.dynamic_update_slice_in_dim(dv, upd, start, axis=1)
            return (dq_acc, dk, dv), None

        n_chunks, starts = _kv_chunk_starts(cfg, qi, skv_p)
        dq0 = jnp.zeros((B, qc, Hkv, g, dh), jnp.float32)
        (dq, dk, dv), _ = jax.lax.scan(kv_body, (dq0, dk, dv), starts)
        return (dk, dv), dq

    (dk, dv), dqs = jax.lax.scan(
        q_body, (dk0, dv0), (jnp.arange(nq), q_chunks, do_chunks, lse_chunks, dl_chunks))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(B, sq_p, H, dh)[:, :Sq]
    return dq.astype(q.dtype), dk[:, :Skv].astype(k.dtype), dv[:, :Skv].astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _flash(q, k, v, cfg: _AttnCfg):
    out, _ = _flash_fwd_impl(q, k, v, cfg)
    return out


def _flash_vjp_fwd(q, k, v, cfg):
    out, lse = _flash_fwd_impl(q, k, v, cfg)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(cfg, res, dout):
    q, k, v, out, lse = res
    return _flash_bwd_impl(q, k, v, out, lse, dout, cfg)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def chunked_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                      q_offset: int = 0, scale: Optional[float] = None,
                      q_chunk: int = 512, kv_chunk: int = 512,
                      return_lse: bool = False):
    """Memory-efficient attention; differentiable (flash-style custom VJP)."""
    scale = q.shape[-1] ** -0.5 if scale is None else scale
    cfg = _AttnCfg(causal, window, q_offset, scale, q_chunk, kv_chunk)
    if return_lse:
        return _flash_fwd_impl(q, k, v, cfg)
    return _flash(q, k, v, cfg)


# ---------------------------------------------------------------------------
# Decode attention partial (ISP flash-decoding) — reference
# ---------------------------------------------------------------------------


def decode_partial(q, k, v, kv_valid, *, kv_offset=0, scale: Optional[float] = None):
    """Single-step attention partial over a KV span (the per-shard ISP unit).

    q: (B, H, dh); k, v: (B, S_span, Hkv, dh); kv_valid: number of valid kv
    positions *globally*; kv_offset: global position of this span's first key.
    Returns (acc (B,H,dh) fp32, l (B,H) fp32, m (B,H) fp32) — combinable partials.
    """
    B, H, dh = q.shape
    _, S, Hkv, _ = k.shape
    g = H // Hkv
    scale = dh ** -0.5 if scale is None else scale
    qg = q.reshape(B, Hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k.astype(jnp.float32)) * scale
    kpos = kv_offset + jnp.arange(S)
    s = jnp.where((kpos < kv_valid)[None, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    acc = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return (acc.reshape(B, H, dh), l.reshape(B, H), m.reshape(B, H))


def _decode_valid_mask(kpos, cur_pos, window=None):
    """Validity mask for decode attention, broadcast to (B*, S).

    kpos: (S,) shared cache positions or (B, S) per-slot positions (the
    continuous-batching engine tracks a position per batch slot); cur_pos:
    scalar shared decode position or (B,) per-slot positions.
    """
    kposb = kpos if kpos.ndim == 2 else kpos[None, :]            # (B*, S)
    cur = jnp.asarray(cur_pos)
    curb = cur[:, None] if cur.ndim == 1 else cur                # (B,1) | ()
    valid = (kposb >= 0) & (kposb <= curb)
    if window is not None:
        valid &= kposb > curb - window
    return valid


def decode_partial_masked(q, k, v, kpos, cur_pos, *, window=None, scale=None):
    """Decode partial with explicit per-slot global positions.

    kpos: (S,) int32 global position of each cache slot (-1 = empty), or
    (B, S) when each batch slot tracks its own timeline; cur_pos: scalar
    current decode position, or (B,) per-slot.  Supports ring buffers.
    Returns (acc (B,H,dhv) fp32, l (B,H), m (B,H)).
    """
    B, H, dh = q.shape
    _, S, Hkv, dhv = v.shape
    g = H // Hkv
    scale = dh ** -0.5 if scale is None else scale
    qg = q.reshape(B, Hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k.astype(jnp.float32)) * scale
    valid = _decode_valid_mask(kpos, cur_pos, window)[:, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid, p, 0.0)
    l = p.sum(axis=-1)
    acc = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return (acc.reshape(B, H, dhv), l.reshape(B, H), m.reshape(B, H))


def chunk_attention_masked(q, k, v, kpos, qpos, *, scale=None):
    """Prefill-continuation attention: a chunk of queries at explicit
    positions against a cached span with explicit key positions.

    q: (B, C, H, dh); k/v: (B, S, Hkv, dh[v]); kpos: (B, S) int32 global
    position of each cache row (-1 = empty); qpos: (B, C) int32 query
    positions (-1 = pad row).  Key j is visible to query i iff
    ``kpos[j] >= 0 and kpos[j] <= qpos[i]`` — the chunk's own rows are in
    the cache already, so this is causal attention over prefix + chunk.
    Returns (B, C, H, dhv) in q.dtype (pad rows are finite garbage).
    """
    B, C, H, dh = q.shape
    Hkv, dhv = v.shape[2], v.shape[3]
    g = H // Hkv
    scale = dh ** -0.5 if scale is None else scale
    qg = q.reshape(B, C, Hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bchgd,bkhd->bchgk", qg, k.astype(jnp.float32)) * scale
    valid = (kpos[:, None, :] >= 0) & (qpos[:, :, None] >= 0) \
        & (kpos[:, None, :] <= qpos[:, :, None])
    valid = valid[:, :, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(valid, p, 0.0)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bchgk,bkhd->bchgd", p, v.astype(jnp.float32))
    out = out / jnp.where(l == 0, 1.0, l)
    return out.reshape(B, C, H, dhv).astype(q.dtype)


def mla_decode_scores_partial(q_eff, q_rope, ckv, krope, kpos, cur_pos, *, scale):
    """MLA absorbed decode partial over a compressed-KV span.

    q_eff: (B,H,R) — q_nope already absorbed through wk_b; q_rope: (B,H,r);
    ckv: (B,S,R); krope: (B,S,r).  Returns (acc (B,H,R), l, m) partials where
    acc is the probability-weighted sum of ckv rows.
    """
    B, H, R = q_eff.shape
    s = jnp.einsum("bhr,bsr->bhs", q_eff.astype(jnp.float32), ckv.astype(jnp.float32))
    s = s + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32),
                       krope.astype(jnp.float32))
    s = s * scale
    valid = _decode_valid_mask(kpos, cur_pos)[:, None, :]
    s = jnp.where(valid, s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid, p, 0.0)
    l = p.sum(axis=-1)
    acc = jnp.einsum("bhs,bsr->bhr", p, ckv.astype(jnp.float32))
    return acc, l, m


def combine_partials(acc, l, m, axis=0):
    """Merge flash-decoding partials along ``axis`` (stacked shards)."""
    m_glob = jnp.max(m, axis=axis, keepdims=True)
    w = jnp.exp(m - m_glob)
    acc = jnp.sum(acc * w[..., None], axis=axis)
    l = jnp.sum(l * w, axis=axis)
    l = jnp.where(l == 0, 1.0, l)
    return acc / l[..., None]


def decode_attention(q, k, v, kv_valid, *, scale=None):
    """Full single-step decode attention (oracle = one partial over everything)."""
    acc, l, m = decode_partial(q, k, v, kv_valid, scale=scale)
    return combine_partials(acc[None], l[None], m[None], axis=0).astype(q.dtype)


# ---------------------------------------------------------------------------
# ISP gather (+pool) — reference
# ---------------------------------------------------------------------------


def isp_gather(table, indices, shard_offset: int = 0, shard_rows: Optional[int] = None,
               weights=None):
    """Gather rows of a (local) table shard for global ``indices``.

    Rows outside [shard_offset, shard_offset + shard_rows) contribute zeros —
    summing across shards (psum) reconstructs the full gather.  This is the
    paper's "send indexes, not data": indices travel, table rows do not.

    table: (V_local, D); indices: (...,) int32; weights: optional (...,) scale.
    Returns (..., D) in table dtype.
    """
    v_local = table.shape[0] if shard_rows is None else shard_rows
    local = indices - shard_offset
    in_range = (local >= 0) & (local < v_local)
    safe = jnp.clip(local, 0, v_local - 1)
    rows = jnp.take(table, safe, axis=0)
    rows = jnp.where(in_range[..., None], rows, jnp.zeros((), table.dtype))
    if weights is not None:
        rows = rows * weights[..., None].astype(rows.dtype)
    return rows


def isp_gather_pool(table, indices, segment_ids, num_segments: int,
                    shard_offset: int = 0, weights=None):
    """RecSSD-style fused gather + segment-sum pooling (on-shard aggregation).

    indices/segment_ids: (N,).  Returns (num_segments, D) fp32.
    """
    rows = isp_gather(table, indices, shard_offset, weights=weights).astype(jnp.float32)
    return jax.ops.segment_sum(rows, segment_ids, num_segments=num_segments)


# ---------------------------------------------------------------------------
# Cosine-similarity top-k (recommender) — reference
# ---------------------------------------------------------------------------


def topk_similarity(queries, corpus, k: int):
    """queries: (Q, D); corpus: (N, D).  Returns (scores (Q,k), idx (Q,k)).

    Cosine similarity via normalized dot products, fp32.
    """
    qn = queries.astype(jnp.float32)
    qn = qn / jnp.maximum(jnp.linalg.norm(qn, axis=-1, keepdims=True), 1e-9)
    cn = corpus.astype(jnp.float32)
    cn = cn / jnp.maximum(jnp.linalg.norm(cn, axis=-1, keepdims=True), 1e-9)
    sims = qn @ cn.T
    return jax.lax.top_k(sims, k)
