"""jit-friendly dispatch wrappers around the Pallas kernels.

Every op has three implementations:
  "pallas"  — the TPU kernel (``pl.pallas_call`` + BlockSpec).  On CPU it runs
              in interpret mode (tests); on TPU it compiles natively.
  "jnp"     — the scalable pure-jnp path (chunked scans) from ``ref.py``;
              identical math, used for CPU dry-runs and as the XLA fallback.
  "auto"    — "pallas" on TPU backends, "jnp" elsewhere.

The FLOP/byte structure of the jnp path matches the kernel tiling, so
roofline terms derived from the dry-run HLO are representative of the TPU
execution (see DESIGN.md §7).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref

_FORCE_IMPL: Optional[str] = None


def set_default_impl(impl: Optional[str]) -> None:
    """Force an implementation globally (tests / benchmarks)."""
    global _FORCE_IMPL
    _FORCE_IMPL = impl


def _resolve(impl: str) -> str:
    if _FORCE_IMPL is not None:
        return _FORCE_IMPL
    if impl != "auto":
        return impl
    platform = jax.default_backend()
    return "pallas" if platform == "tpu" else "jnp"


def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, scale: Optional[float] = None,
                    q_chunk: int = 512, kv_chunk: int = 512, impl: str = "auto"):
    """Chunked causal attention.  q: (B,Sq,H,dh); k/v: (B,Skv,Hkv,dh[v])."""
    which = _resolve(impl)
    if which == "pallas":
        from repro.kernels import flash_attention as fa
        return fa.flash_attention(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset, scale=scale,
                                  interpret=jax.default_backend() != "tpu")
    return ref.chunked_attention(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset, scale=scale,
                                 q_chunk=q_chunk, kv_chunk=kv_chunk)


def decode_partial(q, k, v, kpos, cur_pos, *, window: Optional[int] = None,
                   scale: Optional[float] = None, impl: str = "auto"):
    """Per-shard flash-decoding partial.  q: (B,H,dh); k/v: (B,S,Hkv,dh).

    kpos: (S,) global positions of cache slots (-1 = empty); cur_pos: scalar.
    Per-slot layouts — kpos (B,S) with cur_pos (B,) from the continuous-
    batching engine — run the jnp path (the Pallas kernel keeps the uniform
    single-position layout).
    Returns (acc fp32 (B,H,dhv), l (B,H), m (B,H)).
    """
    which = _resolve(impl)
    if kpos.ndim == 2 or jnp.ndim(cur_pos) == 1:
        which = "jnp"
    if which == "pallas":
        from repro.kernels import isp_decode
        return isp_decode.decode_partial(q, k, v, kpos, cur_pos, window=window,
                                         scale=scale,
                                         interpret=jax.default_backend() != "tpu")
    return ref.decode_partial_masked(q, k, v, kpos, cur_pos, window=window, scale=scale)


def paged_decode_partial(q, kpool, vpool, pages, cur_pos, *,
                         window: Optional[int] = None,
                         scale: Optional[float] = None, impl: str = "auto"):
    """Ragged decode partial over a paged KV pool (continuous batching).

    q: (B,H,dh); kpool/vpool: (P(+scratch), page_size, Hkv, dh); pages:
    (B,maxp) int32 per-slot page tables (-1 = unallocated); cur_pos: (B,)
    per-slot positions.  Unlike ``decode_partial``, the per-slot layout IS
    the Pallas layout here — the kernel walks the page table via scalar
    prefetch, so the serve engine's ragged batches get the fused path.
    Returns (acc fp32 (B,H,dh), l (B,H), m (B,H)).
    """
    from repro.kernels import paged_decode
    which = _resolve(impl)
    if which == "pallas":
        return paged_decode.paged_decode_partial(
            q, kpool, vpool, pages, cur_pos, window=window, scale=scale,
            interpret=jax.default_backend() != "tpu")
    return paged_decode.paged_decode_partial_ref(
        q, kpool, vpool, pages, cur_pos, window=window, scale=scale)


def chunk_prefill_attention(q, k, v, kpos, qpos, *,
                            scale: Optional[float] = None, impl: str = "auto"):
    """Chunked-prefill attention: chunk queries at explicit positions over a
    cached span (the serve engine's incremental prefill continuation).

    q: (B,C,H,dh); k/v: (B,S,Hkv,dh[v]); kpos: (B,S) (-1 = empty row);
    qpos: (B,C) (-1 = pad row).  One chunk runs per engine tick (admission-
    path work, not the per-token hot loop), so every backend takes the jnp
    oracle — the dispatch hook exists so a fused kernel can slot in without
    touching callers.
    """
    del impl  # no fused kernel yet; the oracle is the only implementation
    return ref.chunk_attention_masked(q, k, v, kpos, qpos, scale=scale)


def isp_gather(table, indices, *, shard_offset=0, shard_rows=None, weights=None,
               impl: str = "auto"):
    """Masked local gather of table rows for global indices (ISP primitive)."""
    which = _resolve(impl)
    if which == "pallas":
        from repro.kernels import isp_gather as ig
        return ig.isp_gather(table, indices, shard_offset=shard_offset,
                             weights=weights,
                             interpret=jax.default_backend() != "tpu")
    return ref.isp_gather(table, indices, shard_offset=shard_offset,
                          shard_rows=shard_rows, weights=weights)


def isp_gather_pool(table, indices, segment_ids, num_segments, *,
                    shard_offset=0, weights=None, impl: str = "auto"):
    which = _resolve(impl)
    if which == "pallas":
        from repro.kernels import isp_gather as ig
        return ig.isp_gather_pool(table, indices, segment_ids, num_segments,
                                  shard_offset=shard_offset, weights=weights,
                                  interpret=jax.default_backend() != "tpu")
    return ref.isp_gather_pool(table, indices, segment_ids, num_segments,
                               shard_offset=shard_offset, weights=weights)


def topk_similarity(queries, corpus, k: int, *, impl: str = "auto"):
    which = _resolve(impl)
    if which == "pallas":
        from repro.kernels import topk_similarity as tk
        return tk.topk_similarity(queries, corpus, k,
                                  interpret=jax.default_backend() != "tpu")
    return ref.topk_similarity(queries, corpus, k)
