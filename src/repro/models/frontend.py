"""Modality frontend STUBS (per assignment: ``[audio]``/``[vlm]`` entries
specify the transformer backbone only; ``input_specs()`` provides
precomputed frame/patch embeddings).

These stubs stand in for EnCodec (musicgen) and the VQ-VAE image tokenizer
(chameleon): deterministic featurizers that map raw-ish inputs to
(B, S, d_model) embeddings / discrete codes so examples and tests can
exercise the full path without the (out-of-scope) codec weights.
"""
from __future__ import annotations

import numpy as np

from repro.config import ModelConfig


class AudioFrontendStub:
    """EnCodec-like: raw waveform -> frame embeddings + codebook tokens."""

    def __init__(self, cfg: ModelConfig, frame_rate: int = 50, sr: int = 16_000):
        self.cfg = cfg
        self.hop = sr // frame_rate

    def encode(self, waveform: np.ndarray, seed: int = 0):
        """waveform: (B, T) float.  Returns (embeddings (B,S,D), tokens (B,S))."""
        b, t = waveform.shape
        s = max(1, t // self.hop)
        frames = waveform[:, : s * self.hop].reshape(b, s, self.hop)
        # deterministic featurizer: fixed random projection of frame stats
        rng = np.random.default_rng(seed)
        proj = rng.standard_normal((3, self.cfg.d_model)).astype(np.float32)
        feats = np.stack([frames.mean(-1), frames.std(-1),
                          np.abs(frames).max(-1)], axis=-1)
        emb = feats.astype(np.float32) @ proj
        tokens = (np.abs(frames).mean(-1) * 1e3).astype(np.int64) % self.cfg.vocab_size
        return emb, tokens.astype(np.int32)


class VQFrontendStub:
    """VQ-VAE-like: image -> patch embeddings + discrete codes (early fusion)."""

    def __init__(self, cfg: ModelConfig, patch: int = 16):
        self.cfg = cfg
        self.patch = patch

    def encode(self, images: np.ndarray, seed: int = 0):
        """images: (B, H, W, C) float.  Returns (embeddings (B,S,D), codes (B,S))."""
        b, h, w, c = images.shape
        p = self.patch
        gh, gw = h // p, w // p
        patches = images[:, : gh * p, : gw * p].reshape(b, gh, p, gw, p, c)
        feats = patches.mean(axis=(2, 4)).reshape(b, gh * gw, c)
        rng = np.random.default_rng(seed)
        proj = rng.standard_normal((c, self.cfg.d_model)).astype(np.float32)
        emb = feats.astype(np.float32) @ proj
        codes = (feats.sum(-1) * 1e3).astype(np.int64) % self.cfg.vocab_size
        return emb, codes.astype(np.int32)
