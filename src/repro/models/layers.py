"""Shared neural-net layers: RMSNorm, RoPE, gated MLP, init helpers."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with fp32 accumulation, output in input dtype."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def rope_frequencies(head_dim: int, base: float) -> jax.Array:
    """Inverse frequencies for rotary embedding; head_dim must be even."""
    half = head_dim // 2
    return 1.0 / (base ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, base: float) -> jax.Array:
    """Rotary position embedding.

    x: (..., S, H, Dh) with Dh even; positions: broadcastable to (..., S).
    Uses the "rotate half" convention.
    """
    dh = x.shape[-1]
    inv_freq = rope_frequencies(dh, base)                       # (dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * inv_freq  # (..., S, dh/2)
    cos = jnp.cos(angles)[..., :, None, :]                      # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    """Gated MLP: silu(x W_g) * (x W_u) W_d.  Weights: (D,F),(D,F),(F,D)."""
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


# ---------------------------------------------------------------------------
# Parameter initialization helpers
# ---------------------------------------------------------------------------


def dense_init(key: jax.Array, shape, dtype=jnp.bfloat16, scale: Optional[float] = None) -> jax.Array:
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def zeros_init(_key, shape, dtype=jnp.bfloat16, **_kw) -> jax.Array:
    return jnp.zeros(shape, dtype)


@dataclasses.dataclass
class KeyGen:
    """Deterministic stream of PRNG keys for sequential param init."""

    key: jax.Array

    def __call__(self) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sub
