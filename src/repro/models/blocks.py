"""Per-family transformer block assembly.

Block kinds (see ModelConfig.block_pattern):
  attn     full-attention + swiglu MLP          (yi, starcoder2, llama3, ...)
  local    sliding-window attention + MLP       (gemma3 local layers, hymba)
  moe      attention + routed MoE (+ shared)    (llama4-scout)
  mla_moe  MLA attention + routed MoE (+shared) (deepseek-v2)
  hybrid   parallel attention & mamba heads     (hymba)
  mlstm / slstm                                 (xlstm)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.config import ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import KeyGen, dense_init, rms_norm, swiglu


def mlp_params(cfg: ModelConfig, kg: KeyGen, dtype, d_ff: Optional[int] = None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    return {
        "w_gate": dense_init(kg(), (d, f), dtype),
        "w_up": dense_init(kg(), (d, f), dtype),
        "w_down": dense_init(kg(), (f, d), dtype),
    }


def block_params(cfg: ModelConfig, kind: str, key, dtype) -> Dict[str, Any]:
    kg = KeyGen(key)
    d = cfg.d_model
    p: Dict[str, Any] = {"ln1": jnp.zeros((d,), dtype)}
    if kind in ("attn", "local"):
        p["attn"] = attn_mod.gqa_params(cfg, kg, dtype)
        p["ln2"] = jnp.zeros((d,), dtype)
        p["mlp"] = mlp_params(cfg, kg, dtype)
    elif kind == "moe":
        p["attn"] = attn_mod.gqa_params(cfg, kg, dtype)
        p["ln2"] = jnp.zeros((d,), dtype)
        p["moe"] = moe_mod.moe_params(cfg, kg, dtype)
    elif kind == "mla_moe":
        p["attn"] = attn_mod.mla_params(cfg, kg, dtype)
        p["ln2"] = jnp.zeros((d,), dtype)
        p["moe"] = moe_mod.moe_params(cfg, kg, dtype)
    elif kind == "hybrid":
        p["attn"] = attn_mod.gqa_params(cfg, kg, dtype)
        p["ssm"] = ssm_mod.mamba_params(cfg, kg, dtype)
        p["ln2"] = jnp.zeros((d,), dtype)
        p["mlp"] = mlp_params(cfg, kg, dtype)
    elif kind == "mlstm":
        p["core"] = ssm_mod.mlstm_params(cfg, kg, dtype)
    elif kind == "slstm":
        p["core"] = ssm_mod.slstm_params(cfg, kg, dtype)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return p


# ---------------------------------------------------------------------------
# MoE wiring (EP shard_map when available)
# ---------------------------------------------------------------------------


def _moe_specs(plan, fs):
    tp = plan.model_axis
    return {
        "router": P(),
        "we_gate": P(tp, fs, None),
        "we_up": P(tp, fs, None),
        "we_down": P(tp, fs, None),
    }


def apply_moe(params, x, cfg: ModelConfig, plan, mode: str):
    """Routed experts (+ shared experts).  Returns (y, aux_loss)."""
    m = cfg.moe
    routed = {k: params[k] for k in ("router", "we_gate", "we_up", "we_down")}
    tp_size = plan.plan.axis_size(plan.model_axis) if plan.mesh is not None else 1
    use_ep = (plan.mesh is not None and plan.ep and plan.model_axis is not None
              and m.num_experts % tp_size == 0 and tp_size > 1)

    B, S, D = x.shape
    if not use_ep:
        y, aux = moe_mod.dense_moe(routed, x, cfg)
    elif mode == "decode" or S % tp_size:
        b_axes = plan.batch_axes or None

        def local(p_l, x_l):
            if plan.fsdp_axis:
                p_l = _ep_gather(p_l, plan.fsdp_axis)
            t = x_l.reshape(-1, D)
            y = moe_mod.ep_moe_decode_local(p_l, t, cfg, plan.model_axis)
            return y.reshape(x_l.shape)

        specs = _moe_specs(plan, plan.fsdp_axis)
        fn = shard_map(local, mesh=plan.mesh,
                       in_specs=(specs, P(b_axes, None, None)),
                       out_specs=P(b_axes, None, None), check_vma=False)
        y = fn(routed, x)
        aux = jnp.float32(0.0)       # decode: no aux loss needed
    else:
        b_axes = plan.batch_axes or None
        tp = plan.model_axis
        axes_all = plan.all_axes

        def local(p_l, x_l):
            if plan.fsdp_axis:
                p_l = _ep_gather(p_l, plan.fsdp_axis)
            t = x_l.reshape(-1, D)
            y, aux = moe_mod.ep_moe_local(p_l, t, cfg, tp)
            for ax in axes_all:
                if ax != tp:
                    aux = jax.lax.pmean(aux, ax)
            return y.reshape(x_l.shape), aux

        specs = _moe_specs(plan, plan.fsdp_axis)
        fn = shard_map(local, mesh=plan.mesh,
                       in_specs=(specs, P(b_axes, tp, None)),
                       out_specs=(P(b_axes, tp, None), P()), check_vma=False)
        y, aux = fn(routed, x)

    if m.num_shared_experts:
        # shared experts as a plain TP MLP (outside the EP region) so their
        # d_ff shards over the model axis instead of replicating; SP gather/
        # scatter keeps both terms S-sharded
        shared = swiglu(sp_gather(x, plan, mode, cfg), params["ws_gate"],
                        params["ws_up"], params["ws_down"])
        y = y + sp_scatter(shared, plan, mode, cfg)
    return y, aux


def _ep_gather(p_l, fs):
    """FSDP all-gather of expert weights at use time (storage stays sharded)."""
    return {
        "router": p_l["router"],
        "we_gate": jax.lax.all_gather(p_l["we_gate"], fs, axis=1, tiled=True),
        "we_up": jax.lax.all_gather(p_l["we_up"], fs, axis=1, tiled=True),
        "we_down": jax.lax.all_gather(p_l["we_down"], fs, axis=1, tiled=True),
    }


# ---------------------------------------------------------------------------
# Sequence parallelism plumbing (Megatron-SP)
# ---------------------------------------------------------------------------


def sp_enabled(cfg: ModelConfig, plan, seq_len: int, mode: str = "train") -> bool:
    """Whether the residual stream runs sequence-sharded for this cell —
    the single source of truth shared by blocks, embedding and the loss
    head (mismatched producers/consumers cause per-layer gather storms —
    measured on hymba, EXPERIMENTS §Perf)."""
    if not (plan is not None and plan.mesh is not None
            and plan.model_axis is not None and mode in ("train", "prefill")):
        return False
    tp = plan.plan.axis_size(plan.model_axis)
    if tp <= 1 or seq_len % tp:
        return False
    if cfg.num_heads % tp != 0:
        return False
    return cfg.param_count() >= 1_000_000_000


def _sp_on(x, plan, mode, cfg: Optional[ModelConfig] = None) -> bool:
    if not (plan is not None and plan.mesh is not None
            and plan.model_axis is not None and mode in ("train", "prefill")
            and x.ndim == 3
            and plan.plan.axis_size(plan.model_axis) > 1
            and x.shape[1] % plan.plan.axis_size(plan.model_axis) == 0):
        return False
    if cfg is not None:
        tp = plan.plan.axis_size(plan.model_axis)
        # SP only pays when the mixers actually shard over the model axis:
        # measured regressions on hymba (25 heads % 16), llama4 (40 % 16)
        # and sub-1B models (xlstm) — see EXPERIMENTS §Perf.
        if cfg.num_heads % tp != 0:
            return False
        if cfg.param_count() < 1_000_000_000:
            return False
    return True


def sp_gather(x, plan, mode, cfg: Optional[ModelConfig] = None):
    """S-sharded residual -> full sequence at a mixer input (all-gather)."""
    if not _sp_on(x, plan, mode, cfg):
        return x
    from jax.sharding import NamedSharding
    b = plan.batch_axes or None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(plan.mesh, P(b, None, None)))


def sp_scatter(x, plan, mode, cfg: Optional[ModelConfig] = None):
    """Mixer output (partial-sum over TP) -> S-sharded residual.  Turns the
    TP all-reduce into a reduce-scatter: same wire bytes, 1/TP the HBM
    writes, and the remat'd scan carry shrinks by TP."""
    if not _sp_on(x, plan, mode, cfg):
        return x
    from jax.sharding import NamedSharding
    b = plan.batch_axes or None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(plan.mesh, P(b, plan.model_axis, None)))


# ---------------------------------------------------------------------------
# Block apply
# ---------------------------------------------------------------------------


def apply_block(params, x, positions, cfg: ModelConfig, kind: str, plan,
                cache: Optional[Dict], mode: str, write_mask=None):
    """Returns (x, new_cache, aux_loss).  The residual stream enters and
    leaves S-sharded (SP); each mixer gathers the sequence at its input and
    scatters its output.  ``write_mask`` (B,) gates decode-step attention
    cache writes per slot (the fused K-step block freezes finished slots;
    recurrent states need no mask — a dead slot only corrupts itself and is
    reset wholesale at refill)."""
    aux = jnp.float32(0.0)
    eps = cfg.norm_eps
    if kind in ("attn", "local", "moe", "mla_moe"):
        h = sp_gather(rms_norm(x, params["ln1"], eps), plan, mode, cfg)
        if kind == "mla_moe":
            a, new_cache = attn_mod.mla_apply(params["attn"], h, positions, cfg,
                                              plan, cache, mode,
                                              write_mask=write_mask)
        else:
            a, new_cache = attn_mod.gqa_apply(
                params["attn"], h, positions, cfg,
                "local" if kind == "local" else "full", plan, cache, mode,
                write_mask=write_mask)
        x = x + sp_scatter(a, plan, mode, cfg)
        h = rms_norm(x, params["ln2"], eps)
        if kind in ("moe", "mla_moe"):
            # EP consumes S-sharded tokens directly — no gather needed
            f, aux = apply_moe(params["moe"], h, cfg, plan, mode)
            x = x + sp_scatter(f, plan, mode, cfg)
        else:
            f = swiglu(sp_gather(h, plan, mode, cfg), params["mlp"]["w_gate"],
                       params["mlp"]["w_up"], params["mlp"]["w_down"])
            x = x + sp_scatter(f, plan, mode, cfg)
    elif kind == "hybrid":
        h = sp_gather(rms_norm(x, params["ln1"], eps), plan, mode, cfg)
        a, attn_cache = attn_mod.gqa_apply(params["attn"], h, positions, cfg,
                                           "local", plan,
                                           cache.get("attn") if cache else None,
                                           mode, write_mask=write_mask)
        s, ssm_cache = ssm_mod.mamba_apply(params["ssm"], h, cfg, plan,
                                           cache.get("ssm") if cache else None, mode)
        x = x + sp_scatter(0.5 * (a + s), plan, mode, cfg)
        h = sp_gather(rms_norm(x, params["ln2"], eps), plan, mode, cfg)
        f = swiglu(h, params["mlp"]["w_gate"], params["mlp"]["w_up"],
                   params["mlp"]["w_down"])
        x = x + sp_scatter(f, plan, mode, cfg)
        new_cache = None
        if attn_cache is not None or ssm_cache is not None:
            new_cache = {"attn": attn_cache, "ssm": ssm_cache}
    elif kind == "mlstm":
        h = sp_gather(rms_norm(x, params["ln1"], eps), plan, mode, cfg)
        y, new_cache = ssm_mod.mlstm_apply(params["core"], h, cfg, plan, cache, mode)
        x = x + sp_scatter(y, plan, mode, cfg)
    elif kind == "slstm":
        h = sp_gather(rms_norm(x, params["ln1"], eps), plan, mode, cfg)
        y, new_cache = ssm_mod.slstm_apply(params["core"], h, cfg, plan, cache, mode)
        x = x + sp_scatter(y, plan, mode, cfg)
    else:
        raise ValueError(kind)
    return x, new_cache, aux


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int, dtype,
                     paged: bool = False, num_pages: int = 0,
                     page_size: int = 16):
    """Decode cache for one block (None for cacheless kinds in train).

    ``paged=True`` gives full-attention GQA layers the paged-pool layout
    (``attn.init_paged_gqa_cache``); window/ring and recurrent layers keep
    their dense layout — their state is already bounded (window / constant)
    so paging buys nothing there.
    """
    if kind in ("attn", "moe"):
        if paged:
            return attn_mod.init_paged_gqa_cache(cfg, batch, num_pages,
                                                 page_size, max_len, dtype)
        return attn_mod.init_gqa_cache(cfg, "full", batch, max_len, dtype)
    if kind == "local":
        return attn_mod.init_gqa_cache(cfg, "local", batch, max_len, dtype)
    if kind == "mla_moe":
        return attn_mod.init_mla_cache(cfg, batch, max_len, dtype)
    if kind == "hybrid":
        return {"attn": attn_mod.init_gqa_cache(cfg, "local", batch, max_len, dtype),
                "ssm": ssm_mod.init_mamba_cache(cfg, batch, dtype)}
    if kind == "mlstm":
        return ssm_mod.init_mlstm_cache(cfg, batch)
    if kind == "slstm":
        return ssm_mod.init_slstm_cache(cfg, batch)
    raise ValueError(kind)
