"""LM assembly: embedding → scanned block groups → head.

Layers are scanned in groups (``cfg.group_size``) with stacked parameters so
HLO size is O(group) not O(depth); remat policy per config.  Entry points:

  loss_fn       (params, batch, cfg, plan) -> (loss, metrics)     [train]
  prefill_fn    (params, batch, cfg, plan) -> (next_token, caches)
  decode_fn     (params, caches, token, pos, cfg, plan) -> (token, caches)
  input_specs   (cfg, shape) -> pytree of ShapeDtypeStruct (dry-run stand-ins)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from repro.core import embedding as emb
from repro.models import blocks as blk
from repro.models.layers import KeyGen, rms_norm, dense_init
from repro.sharding import ParallelPlan, ShardingRecipe

LOCAL = ShardingRecipe(plan=ParallelPlan(), batch_axes=(), seq_axes=())


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def group_pattern(cfg: ModelConfig) -> Tuple[str, ...]:
    return cfg.layer_pattern[: cfg.group_size]


def num_groups(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.group_size


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dtype = _dtype(cfg)
    kg = KeyGen(key)
    params: Dict[str, Any] = {"embed": {"table": emb.embed_params(cfg, kg, dtype)}}
    blocks: Dict[str, Any] = {}
    for j, kind in enumerate(group_pattern(cfg)):
        keys = jax.random.split(kg(), num_groups(cfg))
        blocks[f"b{j}"] = jax.vmap(
            lambda k, kind=kind: blk.block_params(cfg, kind, k, dtype))(keys)
    params["blocks"] = blocks
    params["final_norm"] = jnp.zeros((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        params["head"] = {"w_head": dense_init(
            kg(), (emb.padded_vocab(cfg.vocab_size), cfg.d_model), dtype)}
    return params


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStructs for params — no allocation (dry-run / spec building)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


@functools.lru_cache(maxsize=None)
def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    import math
    shapes = abstract_params(cfg)
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = math.prod(leaf.shape)
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if active_only and name.startswith("we_") and cfg.moe:
            n = n * cfg.moe.top_k // cfg.moe.num_experts
        total += n
    return total


@functools.lru_cache(maxsize=None)
def count_flops_params(cfg: ModelConfig, active_only: bool = True) -> int:
    """Params entering the 6ND estimate (excludes embedding table & head)."""
    import math
    shapes = abstract_params(cfg)
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        names = [str(p.key) for p in path if hasattr(p, "key")]
        if "embed" in names or "head" in names:
            continue
        n = math.prod(leaf.shape)
        if active_only and names[-1].startswith("we_") and cfg.moe:
            n = n * cfg.moe.top_k // cfg.moe.num_experts
        total += n
    return total


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _remat_policy(cfg: ModelConfig):
    if cfg.remat == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return None


def _sp_constraint(x, cfg: ModelConfig, plan):
    """Sequence parallelism: keep the residual stream sharded over the model
    axis between blocks (Megatron-SP).  The saved scan carry — the dominant
    activation residency under remat — shrinks by the TP degree; XLA turns
    the surrounding TP all-reduces into reduce-scatter + all-gather pairs
    (same wire bytes, 16x less HBM)."""
    if plan is None or plan.mesh is None or plan.model_axis is None:
        return x
    tp = plan.plan.axis_size(plan.model_axis)
    if tp <= 1 or x.ndim != 3 or x.shape[1] % tp:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    b = plan.batch_axes or None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(plan.mesh, P(b, plan.model_axis, None)))


def run_blocks(params, x, positions, cfg: ModelConfig, plan, caches=None,
               mode: str = "train", write_mask=None):
    """x: (B,S,D).  Returns (x, new_caches, aux_total)."""
    gpat = group_pattern(cfg)
    use_sp = mode in ("train", "prefill") and blk.sp_enabled(
        cfg, plan, x.shape[1], mode)

    def body(carry, xs):
        x, aux = carry
        gparams, gcache = xs
        new_gc = {}
        for j, kind in enumerate(gpat):
            c = None if gcache is None else gcache.get(f"b{j}")
            # blocks keep the residual S-sharded internally (Megatron-SP);
            # see blocks.sp_gather / sp_scatter
            x, nc, a = blk.apply_block(gparams[f"b{j}"], x, positions, cfg,
                                       kind, plan, c, mode,
                                       write_mask=write_mask)
            aux = aux + a
            if nc is not None:
                new_gc[f"b{j}"] = nc
        return (x, aux), new_gc

    if mode == "train" and cfg.remat != "none":
        body = jax.checkpoint(body, policy=_remat_policy(cfg),
                              prevent_cse=False)

    if use_sp:
        x = _sp_constraint(x, cfg, plan)
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                        (params["blocks"], caches))
    return x, new_caches, aux


def _head_table(params, cfg: ModelConfig):
    return params["embed"]["table"] if cfg.tie_embeddings else params["head"]["w_head"]


def _embed_input(params, batch, cfg: ModelConfig, plan, mode: str = "train"):
    if "embeddings" in batch:          # modality frontend stub output
        return batch["embeddings"].astype(_dtype(cfg))
    sp = blk.sp_enabled(cfg, plan, batch["tokens"].shape[1], mode)
    return emb.embed_lookup(params["embed"]["table"], batch["tokens"], plan,
                            seq_sharded=sp)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def loss_fn(params, batch, cfg: ModelConfig, plan=LOCAL):
    """batch: {tokens|embeddings, labels}.  Returns (loss, metrics)."""
    x = _embed_input(params, batch, cfg, plan, "train")
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x, _, aux = run_blocks(params, x, positions, cfg, plan, None, "train")
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    labels = batch["labels"]
    per_tok = emb.sharded_xent(x, _head_table(params, cfg), jnp.maximum(labels, 0),
                               plan, cfg,
                               seq_sharded=blk.sp_enabled(cfg, plan, S, "train"))
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    xent = (per_tok * mask).sum() / denom
    aux_coef = cfg.moe.aux_loss_coef if cfg.moe else 0.0
    aux = aux / max(cfg.num_layers // cfg.group_size, 1)
    loss = xent + aux_coef * aux
    return loss, {"xent": xent, "aux": aux, "tokens": denom}


def prefill_fn(params, batch, cfg: ModelConfig, plan=LOCAL):
    """Full-sequence prefill.  Returns (next_token (B,), caches).

    With ``batch["lengths"]`` (B,) the prompts are right-padded to a common
    S and each row samples its next token at position ``lengths[i] - 1``
    (pad tokens only ever attend causally *forward*, so the first
    ``lengths[i]`` cache entries are exact — the serve engine masks the
    rest via kpos).
    """
    x = _embed_input(params, batch, cfg, plan, "prefill")
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x, caches, _ = run_blocks(params, x, positions, cfg, plan, _abstract_none(cfg),
                              "prefill")
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if "lengths" in batch:
        last = x[jnp.arange(B), batch["lengths"].astype(jnp.int32) - 1]
    else:
        last = x[:, -1]
    nxt = emb.greedy_sample(last, _head_table(params, cfg), plan, cfg)
    return nxt, caches


def _abstract_none(cfg: ModelConfig):
    """Scan xs placeholder when caches don't exist yet (prefill builds them)."""
    return None


def decode_fn(params, caches, token, pos, cfg: ModelConfig, plan=LOCAL,
              write_mask=None):
    """One decode step.  token: (B,1) int32; pos: () int32 (uniform batch
    pos) or (B,) int32 per-slot positions against ``per_slot`` caches (the
    continuous-batching serve layout).

    ``write_mask`` (B,) bool gates cache writes per slot — the fused
    K-step decode block keeps finished slots inert while the rest of the
    pool keeps stepping.  Returns (next_token (B,), new_caches).
    """
    x = emb.embed_lookup(params["embed"]["table"], token, plan)
    positions = pos[None].astype(jnp.int32) if pos.ndim == 0 else pos
    x, new_caches, _ = run_blocks(params, x, positions, cfg, plan, caches,
                                  "decode", write_mask=write_mask)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    nxt = emb.greedy_sample(x[:, -1], _head_table(params, cfg), plan, cfg)
    return nxt, new_caches


def decode_block_fn(params, caches, tokens, positions, alive, remaining,
                    cfg: ModelConfig, plan=LOCAL, *, k_steps: int,
                    eos_id: Optional[int], max_len: int):
    """Device-resident fused decode loop: up to ``k_steps`` greedy decode
    steps in ONE jitted dispatch — sampling, per-slot position increments,
    EOS / max-new / max-len termination masks and KV writes all stay on
    device; the host reads back one (K, B) token block per call instead of
    one token per step.

    tokens: (B,) int32 current input token per slot; positions: (B,) int32
    next cache position; alive: (B,) bool decode-active slots; remaining:
    (B,) int32 tokens each slot may still emit.  The loop exits early once
    every slot is done (no wasted steps when a whole block finishes).

    Returns (out (K, B) int32 — -1 where a slot emitted nothing that step,
    n_steps executed, tokens, positions, alive, remaining, caches).  Slot
    state evolves exactly as the K=1 host reference loop
    (``train.serve_loop._decode_step`` + ``_push_token``): a slot's step
    emits ``next``, advances its position, then finishes on EOS, cache-full
    (pos reaching ``max_len - 1``) or its max-new budget; finished slots
    are frozen via the decode ``write_mask`` so their caches stay inert.
    """
    B = tokens.shape[0]

    def cond(state):
        i, _, _, _, alive, _, _ = state
        return (i < k_steps) & alive.any()

    def body(state):
        i, out, tok, pos, alive, rem, caches = state
        nxt, caches = decode_fn(params, caches, tok[:, None], pos, cfg, plan,
                                write_mask=alive)
        nxt = nxt.astype(jnp.int32)
        out = out.at[i].set(jnp.where(alive, nxt, -1))
        pos = jnp.where(alive, pos + 1, pos)
        rem = jnp.where(alive, rem - 1, rem)
        eos = (nxt == eos_id) if eos_id is not None \
            else jnp.zeros((B,), bool)
        done = eos | (pos >= max_len - 1) | (rem <= 0)
        tok = jnp.where(alive, nxt, tok)
        alive = alive & ~done
        return i + 1, out, tok, pos, alive, rem, caches

    state = (jnp.int32(0), jnp.full((k_steps, B), -1, jnp.int32),
             tokens.astype(jnp.int32), positions.astype(jnp.int32),
             alive, remaining.astype(jnp.int32), caches)
    i, out, tok, pos, alive, rem, caches = jax.lax.while_loop(cond, body,
                                                              state)
    return out, i, tok, pos, alive, rem, caches


def prefill_chunk_fn(params, caches, tokens, qpos, last_idx,
                     cfg: ModelConfig, plan=LOCAL):
    """One chunk of an incremental (chunked) prefill for a single slot.

    tokens: (1, C) int32 chunk token ids (pad rows 0); qpos: (1, C) int32
    logical positions of each row (-1 = pad); last_idx: (1,) int32 index of
    the chunk's final real row (where the next token samples — only the
    last chunk's sample is consumed).  ``caches`` is a pool-view pytree
    whose ``pages`` leaves are the target slot's page-table row
    ((num_groups, 1, maxp)) over the shared kp/vp pools, so the chunk
    splices into the paged pool without touching other slots.

    Returns (next_token (1,), updated caches).  Requires a pure paged
    full-attention stack (the engine gates chunking on that).
    """
    x = emb.embed_lookup(params["embed"]["table"], tokens, plan)
    x, new_caches, _ = run_blocks(params, x, qpos.astype(jnp.int32), cfg,
                                  plan, caches, "chunk")
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    last = x[jnp.arange(x.shape[0]), last_idx.astype(jnp.int32)]
    nxt = emb.greedy_sample(last, _head_table(params, cfg), plan, cfg)
    return nxt, new_caches


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                per_slot: bool = False, paged: bool = False,
                page_size: int = 16, num_pages: Optional[int] = None):
    """Stacked decode caches: leaves (num_groups, ...).

    ``per_slot=True`` gives every batch slot its own kpos track
    ((num_groups, batch, S) instead of (num_groups, S)) so slots can sit at
    different positions — required by the continuous-batching serve engine.

    ``paged=True`` (implies per-slot) replaces the dense per-slot strips of
    full-attention layers with paged KV pools: ``num_pages`` physical pages
    of ``page_size`` token rows each (+1 scratch page) and a per-slot page
    table, managed by ``core.kv_pages.PageAllocator`` in the engine.  The
    default ``num_pages`` covers the dense worst case; size it down to cap
    KV memory at expected live tokens (admission backpressures on
    exhaustion).  Window/ring and recurrent layers keep dense state.
    """
    dtype = _dtype(cfg)
    gpat = group_pattern(cfg)
    ng = num_groups(cfg)
    if paged:
        per_slot = True
        if num_pages is None:
            from repro.core.kv_pages import pages_for
            num_pages = batch * pages_for(max_len, page_size)
    out = {}
    for j, kind in enumerate(gpat):
        one = blk.init_block_cache(cfg, kind, batch, max_len, dtype,
                                   paged=paged, num_pages=num_pages or 0,
                                   page_size=page_size)
        out[f"b{j}"] = jax.tree.map(
            lambda l: jnp.zeros((ng,) + l.shape, l.dtype) if l.dtype != jnp.int32
            else jnp.broadcast_to(l, (ng,) + l.shape).copy(), one)
    # kpos slots must start empty (-1), zeros would alias position 0
    def fix_kpos(path, leaf):
        names = [str(p.key) for p in path if hasattr(p, "key")]
        if names and names[-1] == "kpos":
            shape = (leaf.shape[0], batch) + leaf.shape[1:] if per_slot \
                else leaf.shape
            return jnp.full(shape, -1, jnp.int32)
        return leaf
    return jax.tree_util.tree_map_with_path(fix_kpos, out)


def abstract_caches(cfg: ModelConfig, batch: int, max_len: int,
                    per_slot: bool = False, paged: bool = False,
                    page_size: int = 16, num_pages: Optional[int] = None):
    return jax.eval_shape(lambda: init_caches(cfg, batch, max_len, per_slot,
                                              paged, page_size, num_pages))


# ---------------------------------------------------------------------------
# Input specs (dry-run stand-ins)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStructs for every model input of this (arch, shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.frontend:
            return {"embeddings": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                       _dtype(cfg)),
                    "labels": jax.ShapeDtypeStruct((B, S), i32)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32)}
    if shape.kind == "prefill":
        if cfg.frontend:
            return {"embeddings": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                       _dtype(cfg))}
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    # decode: one new token against a cache of S
    return {
        "token": jax.ShapeDtypeStruct((B, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
        "caches": abstract_caches(cfg, B, S),
    }
