"""Attention layers: GQA (full / sliding-window) and DeepSeek-style MLA.

Three entry modes:
  train    — full-sequence causal, no cache
  prefill  — full-sequence causal, emits a decode cache
  decode   — one new token per sequence against the cache (single step)

Decode caches carry an explicit per-slot position array ``kpos`` (S,),
-1 marking empty slots; sliding-window layers use a ring buffer of size
``window``.  The decode attention itself is delegated to
``repro.core.decode_attention`` which implements the ISP (sequence-sharded
KV, partial-softmax combine) path when a mesh is present.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import AttnConfig, ModelConfig
from repro.core.kv_pages import pages_for
from repro.kernels import ops as kops
from repro.models.layers import KeyGen, apply_rope, dense_init, rms_norm


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------


def gqa_params(cfg: ModelConfig, kg: KeyGen, dtype) -> Dict[str, Any]:
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "wq": dense_init(kg(), (d, h, dh), dtype),
        "wk": dense_init(kg(), (d, hkv, dh), dtype),
        "wv": dense_init(kg(), (d, hkv, dh), dtype),
        "wo": dense_init(kg(), (h, dh, d), dtype, scale=(h * dh) ** -0.5),
    }


def mla_params(cfg: ModelConfig, kg: KeyGen, dtype) -> Dict[str, Any]:
    a = cfg.attn
    d, h = cfg.d_model, cfg.num_heads
    qk = a.qk_nope_dim + a.qk_rope_dim
    p: Dict[str, Any] = {
        "wkv_a": dense_init(kg(), (d, a.kv_lora_rank + a.qk_rope_dim), dtype),
        "kv_norm": jnp.zeros((a.kv_lora_rank,), dtype),
        "wk_b": dense_init(kg(), (a.kv_lora_rank, h, a.qk_nope_dim), dtype),
        "wv_b": dense_init(kg(), (a.kv_lora_rank, h, a.v_head_dim), dtype),
        "wo": dense_init(kg(), (h, a.v_head_dim, d), dtype, scale=(h * a.v_head_dim) ** -0.5),
    }
    if a.q_lora_rank:
        p["wq_a"] = dense_init(kg(), (d, a.q_lora_rank), dtype)
        p["q_norm"] = jnp.zeros((a.q_lora_rank,), dtype)
        p["wq_b"] = dense_init(kg(), (a.q_lora_rank, h, qk), dtype)
    else:
        p["wq"] = dense_init(kg(), (d, h, qk), dtype)
    return p


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def init_gqa_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int, dtype):
    window = cfg.attn.window if kind == "local" else None
    s = window if window else max_len    # ring invariant: slot = pos % window
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, s, hkv, dh), dtype),
        "v": jnp.zeros((batch, s, hkv, dh), dtype),
        "kpos": jnp.full((s,), -1, jnp.int32),
    }


def init_paged_gqa_cache(cfg: ModelConfig, batch: int, num_pages: int,
                         page_size: int, max_len: int, dtype):
    """Paged decode cache for a full-attention GQA layer (serve engine).

    ``kp``/``vp`` are pools of ``num_pages`` physical pages (+1 scratch page
    at index ``num_pages`` that absorbs writes of inactive slots); ``pages``
    is the per-slot page table (-1 = unallocated) the engine maintains via
    ``core.kv_pages.PageAllocator``.  Memory is governed by the allocator's
    live-page count, not ``batch * max_len``.
    """
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    maxp = pages_for(max_len, page_size)
    return {
        "kp": jnp.zeros((num_pages + 1, page_size, hkv, dh), dtype),
        "vp": jnp.zeros((num_pages + 1, page_size, hkv, dh), dtype),
        "pages": jnp.full((batch, maxp), -1, jnp.int32),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    a = cfg.attn
    return {
        "ckv": jnp.zeros((batch, max_len, a.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, a.qk_rope_dim), dtype),
        "kpos": jnp.full((max_len,), -1, jnp.int32),
    }


def _ring_update(buf, new, pos, ring: bool):
    """Insert ``new`` (B, 1, ...) at slot pos (scalar) — ring or linear."""
    s = buf.shape[1]
    slot = pos % s if ring else jnp.minimum(pos, s - 1)
    return jax.lax.dynamic_update_slice_in_dim(buf, new.astype(buf.dtype), slot, axis=1)


def _paged_cache(cache) -> bool:
    """Whether this decode cache is the paged-pool layout (kp/vp pools +
    per-slot page table) rather than dense per-slot strips."""
    return "pages" in cache


def _per_slot_cache(cache) -> bool:
    """Whether this decode cache keeps one position track per batch slot
    (kpos (B, S), or a paged page table) — the continuous-batching serve
    layout — vs one shared track (kpos (S,)) for uniform-position decode."""
    return _paged_cache(cache) or cache["kpos"].ndim == 2


def _decode_positions(positions, batch: int, cache, mode: str):
    """(per_slot, posb, rope_pos) for the decode/chunk position layouts:
    per-slot (B,) positions against a per-slot cache, per-row (B, C)
    positions for a chunked-prefill continuation, or the shared (1,S)
    rope layout used by train/prefill/uniform decode."""
    if mode == "chunk":
        # chunked prefill: explicit (B, C) logical positions (-1 = pad row)
        return False, None, positions.astype(jnp.int32)
    if mode == "decode" and cache is not None and _per_slot_cache(cache):
        posb = jnp.broadcast_to(positions, (batch,)).astype(jnp.int32)
        return True, posb, posb[:, None]
    return False, None, positions[None, :]


def _slot_scatter(buf, new, slot):
    """Insert ``new`` (B, 1, ...) at per-batch slots ``slot`` (B,)."""
    bidx = jnp.arange(buf.shape[0])
    return buf.at[bidx, slot].set(new[:, 0].astype(buf.dtype))


def _paged_update(cache, k_new, v_new, posb, write_mask=None):
    """Paged decode-step cache update: write each slot's (1, hkv, dh) row
    into its page table's physical page at offset ``pos % page_size``.
    Slots whose logical page is unallocated (inactive slots) — and slots
    masked off by ``write_mask`` (slots that finished mid-way through a
    fused K-step decode block) — write into the scratch page (index
    num_pages), which is never read back."""
    ps = cache["kp"].shape[1]
    scratch = cache["kp"].shape[0] - 1
    bidx = jnp.arange(posb.shape[0])
    page = cache["pages"][bidx, posb // ps]
    page = jnp.where(page < 0, scratch, page)
    if write_mask is not None:
        page = jnp.where(write_mask, page, scratch)
    off = posb % ps
    return {
        "kp": cache["kp"].at[page, off].set(k_new[:, 0].astype(cache["kp"].dtype)),
        "vp": cache["vp"].at[page, off].set(v_new[:, 0].astype(cache["vp"].dtype)),
        "pages": cache["pages"],
    }


def _paged_chunk_update(cache, k_new, v_new, positions):
    """Chunked-prefill cache update: scatter a whole chunk of rows (B, C,
    hkv, dh) into the paged pools at their logical positions (-1 = pad row,
    routed to the scratch page)."""
    from repro.core.kv_pages import scatter_rows
    return {
        "kp": scatter_rows(cache["kp"], cache["pages"], positions, k_new),
        "vp": scatter_rows(cache["vp"], cache["pages"], positions, v_new),
        "pages": cache["pages"],
    }


def _slot_update(cache, new_vals, posb, ring: bool, write_mask=None):
    """Per-slot decode-step cache update: write each (B,1,...) value at its
    slot's position and stamp that slot's kpos track.  ``write_mask`` (B,)
    keeps masked slots' rows (and kpos stamps) untouched — used by the fused
    K-step decode block so slots that finished mid-block stay inert."""
    s = cache["kpos"].shape[1]
    slot = posb % s if ring else jnp.minimum(posb, s - 1)
    bidx = jnp.arange(len(posb))
    out = {}
    for name, val in new_vals.items():
        row = val[:, 0].astype(cache[name].dtype)
        if write_mask is not None:
            keep = write_mask.reshape((-1,) + (1,) * (row.ndim - 1))
            row = jnp.where(keep, row, cache[name][bidx, slot])
        out[name] = cache[name].at[bidx, slot].set(row)
    stamp = posb if write_mask is None else \
        jnp.where(write_mask, posb, cache["kpos"][bidx, slot])
    out["kpos"] = cache["kpos"].at[bidx, slot].set(stamp)
    return out


# ---------------------------------------------------------------------------
# GQA apply
# ---------------------------------------------------------------------------


def gqa_apply(params, x, positions, cfg: ModelConfig, kind: str, plan,
              cache: Optional[Dict] = None, mode: str = "train",
              write_mask=None):
    """x: (B, S, D); positions: (S,) int32 (decode: (1,) current position, or
    (B,) per-slot positions against a per-slot kpos (B,S) cache; chunk:
    (B, C) per-row logical positions of a chunked-prefill continuation).

    ``write_mask`` (B,) bool gates decode cache writes per slot (fused
    K-step blocks freeze finished slots).  Returns (out (B,S,D),
    new_cache | None).
    """
    a = cfg.attn
    window = a.window if kind == "local" else None
    rope_base = a.rope_base_local if kind == "local" else a.rope_base
    dh = cfg.resolved_head_dim

    per_slot, posb, rope_pos = _decode_positions(positions, x.shape[0],
                                                 cache, mode)

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = apply_rope(q, rope_pos, rope_base)
    k = apply_rope(k, rope_pos, rope_base)

    new_cache = None
    if mode == "chunk":
        # chunked prefill continuation (serve engine): one chunk of a long
        # prompt against the paged pool — write the chunk's rows into the
        # slot's pages, then attend each chunk row to the cached span + the
        # chunk's own causal prefix.
        assert cache is not None and _paged_cache(cache) and window is None, \
            "chunked prefill requires the paged full-attention layout"
        new_cache = _paged_chunk_update(cache, k, v, positions)
        from repro.core.decode_attention import chunk_prefill_attention
        out_h = chunk_prefill_attention(q, new_cache["kp"], new_cache["vp"],
                                        cache["pages"], positions, plan=plan)
        out = jnp.einsum("bshk,hkd->bsd", out_h.astype(x.dtype), params["wo"])
        return out, new_cache
    if mode == "decode":
        assert cache is not None
        ring = window is not None
        if _paged_cache(cache):
            # paged pool layout (serve engine): window-less full attention
            # only — ring/window layers keep the dense window-sized strip
            assert window is None, "paged KV applies to full-attention layers"
            new_cache = _paged_update(cache, k, v, posb, write_mask)
            from repro.core.decode_attention import paged_decode_attention
            out_h = paged_decode_attention(q[:, 0], new_cache["kp"],
                                           new_cache["vp"], cache["pages"],
                                           posb, window=None, plan=plan)
            out_h = out_h[:, None]                                # (B,1,H,dh)
            out = jnp.einsum("bshk,hkd->bsd", out_h.astype(x.dtype),
                             params["wo"])
            return out, new_cache
        if per_slot:
            new_cache = _slot_update(cache, {"k": k, "v": v}, posb, ring,
                                     write_mask)
            pos = posb
        else:
            pos = positions[0]
            s = cache["k"].shape[1]
            ck = _ring_update(cache["k"], k, pos, ring)
            cv = _ring_update(cache["v"], v, pos, ring)
            slot = pos % s if ring else jnp.minimum(pos, s - 1)
            kpos = jax.lax.dynamic_update_slice_in_dim(
                cache["kpos"], pos[None].astype(jnp.int32), slot, axis=0)
            new_cache = {"k": ck, "v": cv, "kpos": kpos}
        ck, cv, kpos = new_cache["k"], new_cache["v"], new_cache["kpos"]
        from repro.core.decode_attention import decode_attention  # avoid cycle
        out_h = decode_attention(q[:, 0], ck, cv, kpos, pos, window=window, plan=plan)
        out_h = out_h[:, None]                                    # (B,1,H,dh)
    else:
        # Repeat KV heads to full H for the batched paths: SPMD sharding of
        # the q-head dim propagates cleanly only when the GQA group reshape
        # is trivial (g=1).  Without this, XLA replicates all attention
        # activations across the model axis (measured: 16x memory blow-up on
        # llama3-405b).  Per-device cost equals q-size; the decode path and
        # the Pallas TPU kernel keep the true GQA layout.
        h, hkv = q.shape[2], k.shape[2]
        k_cache, v_cache = k, v          # caches keep the true GQA layout
        tp = plan.plan.axis_size(plan.model_axis) if (
            plan is not None and plan.mesh is not None) else 1
        if h != hkv and tp > 1 and h % tp == 0:
            # only when the q-head dim actually shards over the model axis —
            # otherwise the repeat just multiplies replicated KV bytes
            k = jnp.repeat(k, h // hkv, axis=2)
            v = jnp.repeat(v, h // hkv, axis=2)
        out_h = kops.flash_attention(q, k, v, causal=True, window=window,
                                     q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk)
        if mode == "prefill":
            k, v = k_cache, v_cache
            sq = x.shape[1]
            if window is not None:
                w = min(window, sq)
                ck, cv = k[:, sq - w:], v[:, sq - w:]
                # ring layout: slot = pos % window
                kpos = jnp.arange(sq - w, sq, dtype=jnp.int32)
                roll = (sq % window) if sq >= window else 0
                ck = jnp.roll(ck, roll, axis=1)
                cv = jnp.roll(cv, roll, axis=1)
                kpos = jnp.roll(kpos, roll, axis=0)
                if w < window:   # pad ring up to window for steady-state decode
                    pad = window - w
                    ck = jnp.pad(ck, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    cv = jnp.pad(cv, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    kpos = jnp.concatenate([kpos, jnp.full((pad,), -1, jnp.int32)])
                new_cache = {"k": ck, "v": cv, "kpos": kpos}
            else:
                kpos = jnp.arange(sq, dtype=jnp.int32)
                new_cache = {"k": k, "v": v, "kpos": kpos}

    out = jnp.einsum("bshk,hkd->bsd", out_h.astype(x.dtype), params["wo"])
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA apply
# ---------------------------------------------------------------------------


def _mla_q(params, x, cfg: ModelConfig):
    a = cfg.attn
    if a.q_lora_rank:
        qa = jnp.einsum("bsd,dr->bsr", x, params["wq_a"])
        qa = rms_norm(qa, params["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", qa, params["wq_b"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    return q[..., : a.qk_nope_dim], q[..., a.qk_nope_dim:]


def mla_apply(params, x, positions, cfg: ModelConfig, plan,
              cache: Optional[Dict] = None, mode: str = "train",
              write_mask=None):
    a = cfg.attn
    B, S, _ = x.shape
    if mode == "chunk":
        raise NotImplementedError(
            "chunked prefill covers paged full-attention GQA layers only "
            "(MLA caches are dense per-slot strips — see ROADMAP open items)")
    per_slot, posb, rope_pos = _decode_positions(positions, B, cache, mode)
    q_nope, q_rope = _mla_q(params, x, cfg)                      # (B,S,H,·)
    q_rope = apply_rope(q_rope, rope_pos, a.rope_base)

    kv_a = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    ckv, k_rope = kv_a[..., : a.kv_lora_rank], kv_a[..., a.kv_lora_rank:]
    ckv = rms_norm(ckv, params["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], rope_pos, a.rope_base)[:, :, 0]

    scale = (a.qk_nope_dim + a.qk_rope_dim) ** -0.5

    new_cache = None
    if mode == "decode":
        assert cache is not None
        if per_slot:
            new_cache = _slot_update(cache, {"ckv": ckv, "krope": k_rope},
                                     posb, ring=False,
                                     write_mask=write_mask)
            pos = posb
        else:
            pos = positions[0]
            s = cache["ckv"].shape[1]
            cckv = _ring_update(cache["ckv"], ckv, pos, ring=False)
            ckr = _ring_update(cache["krope"], k_rope, pos, ring=False)
            slot = jnp.minimum(pos, s - 1)
            kpos = jax.lax.dynamic_update_slice_in_dim(
                cache["kpos"], pos[None].astype(jnp.int32), slot, axis=0)
            new_cache = {"ckv": cckv, "krope": ckr, "kpos": kpos}
        cckv, ckr, kpos = (new_cache["ckv"], new_cache["krope"],
                           new_cache["kpos"])
        from repro.core.decode_attention import mla_decode_attention
        ctx = mla_decode_attention(
            q_nope[:, 0], q_rope[:, 0], cckv, ckr, kpos, pos,
            params["wk_b"], scale=scale, plan=plan)              # (B,H,kv_lora)
        out_h = jnp.einsum("bhr,rhv->bhv", ctx.astype(x.dtype), params["wv_b"])[:, None]
    else:
        # non-absorbed prefill/train: materialize per-head k, v from ckv
        k_nope = jnp.einsum("bsr,rhk->bshk", ckv, params["wk_b"])
        v = jnp.einsum("bsr,rhv->bshv", ckv, params["wv_b"])
        k = jnp.concatenate([k_nope, jnp.broadcast_to(
            k_rope[:, :, None, :], (B, S, cfg.num_heads, a.qk_rope_dim))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out_h = kops.flash_attention(q, k, v, causal=True, scale=scale,
                                     q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk)
        if mode == "prefill":
            new_cache = {"ckv": ckv, "krope": k_rope,
                         "kpos": jnp.arange(S, dtype=jnp.int32)}

    out = jnp.einsum("bshv,hvd->bsd", out_h.astype(x.dtype), params["wo"])
    return out, new_cache
