"""Mixture-of-Experts FFN with expert parallelism.

The routed path is the paper's insight verbatim: tokens (small) are shipped
via ``all_to_all`` to the shard that owns the expert weights (big); only the
FFN outputs come back.  Weights never move.

Two implementations:
  * ``dense_moe``  — every expert computed for every token, masked by gates.
    O(E) flops: test oracle + single-device fallback.
  * ``ep_moe``     — shard_map expert-parallel: capacity-bounded scatter
    dispatch, all_to_all over the model axis, per-shard expert FFN,
    reverse all_to_all, gate-weighted combine.  Exact up to capacity drops.

Decode uses a no-all_to_all variant (tokens replicated over the model axis;
each shard computes only its own experts and psums) — at batch sizes of a
few tokens/shard the index traffic would exceed the result traffic, so the
ISP rule "ship the smaller thing" picks psum instead.

Shared experts are ordinary TP MLPs handled in blocks.py (outside the EP
region) so their d_ff shards over the model axis instead of replicating.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import KeyGen, dense_init


def moe_params(cfg: ModelConfig, kg: KeyGen, dtype) -> Dict:
    m = cfg.moe
    d = cfg.d_model
    p = {
        "router": dense_init(kg(), (d, m.num_experts), jnp.float32, scale=d ** -0.5),
        "we_gate": dense_init(kg(), (m.num_experts, d, m.d_ff_expert), dtype),
        "we_up": dense_init(kg(), (m.num_experts, d, m.d_ff_expert), dtype),
        "we_down": dense_init(kg(), (m.num_experts, m.d_ff_expert, d), dtype),
    }
    if m.num_shared_experts:
        f = (m.d_ff_shared or m.d_ff_expert) * m.num_shared_experts
        p["ws_gate"] = dense_init(kg(), (d, f), dtype)
        p["ws_up"] = dense_init(kg(), (d, f), dtype)
        p["ws_down"] = dense_init(kg(), (f, d), dtype)
    return p


def _router(params, x, cfg: ModelConfig):
    """Returns (gates (..., k) fp32, experts (..., k) int32, probs (..., E))."""
    m = cfg.moe
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, experts, probs


def aux_load_loss(probs, experts, cfg: ModelConfig):
    """Switch-style load-balancing loss: E * sum_e f_e * p_e."""
    m = cfg.moe
    e1 = jax.nn.one_hot(experts, m.num_experts, dtype=jnp.float32).sum(-2)
    frac = e1.reshape(-1, m.num_experts).mean(0) / max(m.top_k, 1)
    pbar = probs.reshape(-1, m.num_experts).mean(0)
    return m.num_experts * jnp.sum(frac * pbar)


def _expert_ffn(we_gate, we_up, we_down, xs):
    """xs: (E, C, D) tokens grouped by expert; weights (E, D, F)/(E, F, D)."""
    g = jnp.einsum("ecd,edf->ecf", xs, we_gate)
    u = jnp.einsum("ecd,edf->ecf", xs, we_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xs.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, we_down)


def dense_moe(params, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Oracle: all experts on all tokens, gate-masked combine."""
    m = cfg.moe
    gates, experts, probs = _router(params, x, cfg)
    shape = x.shape
    xf = x.reshape(-1, shape[-1])                                  # (T, D)
    outs = _expert_ffn(params["we_gate"], params["we_up"], params["we_down"],
                       jnp.broadcast_to(xf[None], (m.num_experts,) + xf.shape))
    gf = gates.reshape(-1, m.top_k)
    ef = experts.reshape(-1, m.top_k)
    w = jnp.zeros((xf.shape[0], m.num_experts), jnp.float32)
    w = jax.vmap(lambda row, e, g: row.at[e].add(g))(w, ef, gf)
    y = jnp.einsum("te,etd->td", w.astype(x.dtype), outs)
    return y.reshape(shape), aux_load_loss(probs, experts, cfg)


# ---------------------------------------------------------------------------
# Expert-parallel (shard_map) path
# ---------------------------------------------------------------------------


def _dispatch_indices(experts, gates, num_experts: int, capacity: int):
    """Flatten (T, k) assignments into per-expert slots.

    Returns (e_idx (T*k,), slot (T*k,), keep (T*k,), gate (T*k,)).
    Slot = position of this assignment within its expert's capacity buffer.
    """
    t, k = experts.shape
    ef = experts.reshape(-1)
    gf = gates.reshape(-1)
    onehot = jax.nn.one_hot(ef, num_experts, dtype=jnp.int32)       # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot                       # exclusive
    slot = jnp.take_along_axis(pos, ef[:, None], axis=1)[:, 0]
    keep = slot < capacity
    return ef, jnp.where(keep, slot, 0), keep, gf


def ep_moe_local(params_local, x_local, cfg: ModelConfig, axis: str):
    """Per-shard EP MoE body (runs inside shard_map).

    x_local: (T_local, D) — this shard's slice of the tokens.
    params_local: router replicated; expert weights sharded on E over ``axis``.
    Returns (y_local (T_local, D), aux scalar replicated).
    """
    m = cfg.moe
    ep = jax.lax.psum(1, axis)                                     # EP degree
    e_local = m.num_experts // ep
    t_local, d = x_local.shape
    capacity = max(1, int(t_local * m.top_k * m.capacity_factor / m.num_experts))

    gates, experts, probs = _router(params_local, x_local, cfg)
    aux = aux_load_loss(probs, experts, cfg)
    aux = jax.lax.pmean(aux, axis)

    e_idx, slot, keep, gate = _dispatch_indices(experts, gates, m.num_experts, capacity)
    # scatter tokens into (E, C, D) send buffer
    xk = jnp.repeat(x_local, m.top_k, axis=0)                      # (T*k, D)
    buf = jnp.zeros((m.num_experts, capacity, d), x_local.dtype)
    buf = buf.at[e_idx, slot].add(jnp.where(keep[:, None], xk, 0))
    # ship tokens to the expert's home shard
    buf = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=1, tiled=True)
    # (e_local, C * ep, D): on-shard expert compute — weights never moved
    y = _expert_ffn(params_local["we_gate"], params_local["we_up"],
                    params_local["we_down"], buf)
    # ship results back
    y = jax.lax.all_to_all(y, axis, split_axis=1, concat_axis=0, tiled=True)
    # gate-weighted combine
    rows = y[e_idx, slot]                                          # (T*k, D)
    rows = jnp.where(keep[:, None], rows, 0)
    rows = rows * gate[:, None].astype(rows.dtype)
    y_tok = rows.reshape(t_local, m.top_k, d).sum(axis=1)
    return y_tok, aux


def ep_moe_decode_local(params_local, x_local, cfg: ModelConfig, axis: str):
    """Decode-time EP: tokens replicated over ``axis``; each shard runs only
    its own experts and psums results (no all_to_all — see module docstring).

    x_local: (T, D) — same tokens on every shard of ``axis``.
    """
    m = cfg.moe
    ep = jax.lax.psum(1, axis)
    e_local = m.num_experts // ep
    shard = jax.lax.axis_index(axis)
    lo = shard * e_local
    t, d = x_local.shape

    gates, experts, _ = _router(params_local, x_local, cfg)        # (T,k)
    # mask assignments not owned by this shard
    owned = (experts >= lo) & (experts < lo + e_local)
    e_rel = jnp.clip(experts - lo, 0, e_local - 1)
    # dense-over-local-experts compute with gate masking (T*k small at decode)
    w = jnp.zeros((t, e_local), jnp.float32)
    w = jax.vmap(lambda row, e, g, o: row.at[e].add(jnp.where(o, g, 0.0)))(
        w, e_rel, gates, owned)
    outs = _expert_ffn(params_local["we_gate"], params_local["we_up"],
                       params_local["we_down"],
                       jnp.broadcast_to(x_local[None], (e_local, t, d)))
    y = jnp.einsum("te,etd->td", w.astype(x_local.dtype), outs)
    return jax.lax.psum(y, axis)
