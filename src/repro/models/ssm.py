"""State-space / recurrent sequence mixers: Mamba, mLSTM, sLSTM.

All three expose (params, x, cfg, plan, cache, mode) -> (y, new_cache) with a
*constant-size* recurrent state — the "resident state" analogue of the
paper's in-storage data: at decode time the state never leaves its shard.

Numerics:
  * Mamba: selective scan; chunked lax.scan with an associative_scan inside
    each chunk (checkpointed so the backward saves only per-chunk carries).
  * mLSTM: chunkwise-parallel matrix-memory recurrence, exactly equivalent
    (up to fp rounding) to the stabilized per-step form; per-step form kept
    as test oracle (``mlstm_step_ref``).
  * sLSTM: inherently sequential (recurrent gate feedback) — scan over time
    in chunks with checkpointed inner scans.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import KeyGen, dense_init


# ---------------------------------------------------------------------------
# Mamba selective SSM
# ---------------------------------------------------------------------------


def mamba_params(cfg: ModelConfig, kg: KeyGen, dtype) -> Dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    dt_rank = s.dt_rank or -(-d // 16)
    p = {
        "w_in": dense_init(kg(), (d, 2 * d_in), dtype),
        "conv_w": dense_init(kg(), (s.conv_width, d_in), dtype, scale=s.conv_width ** -0.5),
        "conv_b": jnp.zeros((d_in,), dtype),
        "w_x": dense_init(kg(), (d_in, dt_rank + 2 * s.state_dim), dtype),
        "w_dt": dense_init(kg(), (dt_rank, d_in), dtype, scale=dt_rank ** -0.5),
        "dt_bias": jnp.zeros((d_in,), jnp.float32),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, s.state_dim + 1, dtype=jnp.float32), (d_in, s.state_dim))),
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "w_out": dense_init(kg(), (d_in, d), dtype),
    }
    return p


def _mamba_scan_chunk(h0, a, bx):
    """Associative scan of h_t = a_t * h_{t-1} + bx_t within a chunk.

    a, bx: (L, B, d_in, N) fp32; h0: (B, d_in, N).  Returns (h_all, h_last).
    """

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    a_acc, b_acc = jax.lax.associative_scan(comb, (a, bx), axis=0)
    h_all = a_acc * h0[None] + b_acc
    return h_all, h_all[-1]


def mamba_apply(params, x, cfg: ModelConfig, plan, cache: Optional[Dict] = None,
                mode: str = "train"):
    """x: (B, S, D).  Cache: {"conv": (B, W-1, d_in), "ssm": (B, d_in, N)}."""
    s = cfg.ssm
    B, S, D = x.shape
    d_in = s.expand * D
    N = s.state_dim
    W = s.conv_width

    xz = jnp.einsum("bsd,de->bse", x, params["w_in"])
    xs, z = jnp.split(xz, 2, axis=-1)

    # causal depthwise conv over time
    if mode == "decode":
        assert cache is not None
        conv_in = jnp.concatenate([cache["conv"], xs], axis=1)      # (B, W, d_in)
        new_conv = conv_in[:, 1:]
    else:
        conv_in = jnp.pad(xs, ((0, 0), (W - 1, 0), (0, 0)))
        new_conv = conv_in[:, -(W - 1):] if W > 1 else jnp.zeros((B, 0, d_in), xs.dtype)
    xc = sum(conv_in[:, i: i + S] * params["conv_w"][i] for i in range(W))
    xc = jax.nn.silu((xc + params["conv_b"]).astype(jnp.float32)).astype(x.dtype)

    proj = jnp.einsum("bse,ef->bsf", xc, params["w_x"])
    dt_rank = proj.shape[-1] - 2 * N
    dt, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jnp.einsum("bsr,re->bse", dt, params["w_dt"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + params["dt_bias"])                    # (B,S,d_in)
    a = -jnp.exp(params["a_log"])                                   # (d_in, N)
    da = jnp.exp(dt[..., None] * a)                                 # (B,S,d_in,N)
    dbx = (dt * xc.astype(jnp.float32))[..., None] * bmat.astype(jnp.float32)[:, :, None, :]

    h0 = cache["ssm"].astype(jnp.float32) if cache is not None else jnp.zeros(
        (B, d_in, N), jnp.float32)

    if mode == "decode":
        h = da[:, 0] * h0 + dbx[:, 0]
        y = jnp.einsum("ben,bn->be", h, cmat[:, 0].astype(jnp.float32))[:, None]
        h_last = h
    else:
        L = min(s.chunk_size, S)
        pad = (-S) % L
        da_p = jnp.pad(da, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        dbx_p = jnp.pad(dbx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        nchunk = da_p.shape[1] // L
        da_c = da_p.reshape(B, nchunk, L, d_in, N).transpose(1, 2, 0, 3, 4)
        dbx_c = dbx_p.reshape(B, nchunk, L, d_in, N).transpose(1, 2, 0, 3, 4)

        @jax.checkpoint
        def chunk_body(h, inp):
            a_c, b_c = inp                                          # (L,B,d_in,N)
            h_all, h_last = _mamba_scan_chunk(h, a_c, b_c)
            return h_last, h_all

        h_last, h_chunks = jax.lax.scan(chunk_body, h0, (da_c, dbx_c))
        h_all = h_chunks.transpose(2, 0, 1, 3, 4).reshape(B, nchunk * L, d_in, N)[:, :S]
        y = jnp.einsum("bsen,bsn->bse", h_all, cmat.astype(jnp.float32))

    y = y + params["d_skip"] * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), params["w_out"])
    new_cache = None
    if mode in ("decode", "prefill"):
        new_cache = {"conv": new_conv.astype(x.dtype), "ssm": h_last.astype(jnp.float32)}
    return out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, d_in), dtype),
        "ssm": jnp.zeros((batch, d_in, s.state_dim), jnp.float32),
    }


# ---------------------------------------------------------------------------
# mLSTM (matrix memory) — chunkwise parallel
# ---------------------------------------------------------------------------


def mlstm_params(cfg: ModelConfig, kg: KeyGen, dtype) -> Dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nh = s.num_heads
    dh = d_in // nh
    return {
        "w_up": dense_init(kg(), (d, 2 * d_in), dtype),            # x and gate branch
        "wq": dense_init(kg(), (d_in, nh, dh), dtype),
        "wk": dense_init(kg(), (d_in, nh, dh), dtype),
        "wv": dense_init(kg(), (d_in, nh, dh), dtype),
        "w_if": dense_init(kg(), (d_in, 2 * nh), dtype, scale=0.01),
        "if_bias": jnp.concatenate([jnp.zeros((nh,)), 3.0 * jnp.ones((nh,))]).astype(jnp.float32),
        "out_norm": jnp.zeros((d_in,), dtype),
        "w_down": dense_init(kg(), (d_in, d), dtype),
    }


def mlstm_step_ref(q, k, v, li, lf, state):
    """Stabilized per-step mLSTM — test oracle.

    q,k,v: (B,nh,dh); li,lf: (B,nh) log-space gates; state: (C,n,m).
    """
    C, n, m = state
    dh = q.shape[-1]
    k = k / jnp.sqrt(jnp.float32(dh))
    m_new = jnp.maximum(lf + m, li)
    i_p = jnp.exp(li - m_new)
    f_p = jnp.exp(lf + m - m_new)
    C = f_p[..., None, None] * C + i_p[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n = f_p[..., None] * n + i_p[..., None] * k
    num = jnp.einsum("bhk,bhkv->bhv", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)), jnp.exp(-m_new))
    h = num / den[..., None]
    return h, (C, n, m_new)


def _mlstm_chunk(state, qkv_if):
    """Chunkwise-parallel mLSTM over one chunk of length L.

    state: (C (B,nh,dh,dh), n (B,nh,dh), m (B,nh)); q,k,v: (B,L,nh,dh) fp32;
    li,lf: (B,L,nh) fp32.  Exactly matches the per-step form.
    """
    q, k, v, li, lf = qkv_if
    C, n, m = state
    B, L, nh, dh = q.shape
    k = k / jnp.sqrt(jnp.float32(dh))
    b = jnp.cumsum(lf, axis=1)                                     # (B,L,nh) inclusive
    g = b + m[:, None]                                             # state decay to t
    # intra-chunk log weights D[t,s] = b_t - b_s + li_s  (s <= t)
    dmat = b[:, :, None] - b[:, None, :] + li[:, None, :, :]       # (B,L,L,nh)
    tri = jnp.tril(jnp.ones((L, L), bool))
    dmat = jnp.where(tri[None, :, :, None], dmat, -1e30)   # avoid inf (NaN-safe grads)
    m_t = jnp.maximum(g, dmat.max(axis=2))                         # (B,L,nh)
    # intra scores
    s_qk = jnp.einsum("blhd,bshd->blsh", q, k)                     # (B,L,S,nh)
    w_intra = jnp.exp(dmat - m_t[:, :, None])                      # broadcast over S
    sw = s_qk * w_intra
    num = jnp.einsum("blsh,bshv->blhv", sw, v)
    den = jnp.sum(sw, axis=2)                                      # Σ_s w·(q·k)  (B,L,nh)
    # inter (state) contribution
    w_inter = jnp.exp(g - m_t)                                     # (B,L,nh)
    num = num + w_inter[..., None] * jnp.einsum("blhk,bhkv->blhv", q, C)
    den = den + w_inter * jnp.einsum("blhk,bhk->blh", q, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
    # state update to end of chunk
    b_l = b[:, -1]                                                 # (B,nh) total decay
    m_new = jnp.maximum(b_l + m, (b_l[:, None] - b + li).max(axis=1))
    w_st = jnp.exp(b_l[:, None] - b + li - m_new[:, None])         # (B,L,nh)
    C_new = jnp.exp(b_l + m - m_new)[..., None, None] * C + jnp.einsum(
        "blh,blhk,blhv->bhkv", w_st, k, v)
    n_new = jnp.exp(b_l + m - m_new)[..., None] * n + jnp.einsum(
        "blh,blhk->bhk", w_st, k)
    return (C_new, n_new, m_new), h


def mlstm_apply(params, x, cfg: ModelConfig, plan, cache: Optional[Dict] = None,
                mode: str = "train"):
    """xLSTM mLSTM block core (pre-up-projection style)."""
    s = cfg.ssm
    B, S, D = x.shape
    d_in = s.expand * D
    nh = s.num_heads
    dh = d_in // nh

    up = jnp.einsum("bsd,de->bse", x, params["w_up"])
    xin, gate = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bse,ehk->bshk", xin, params["wq"]).astype(jnp.float32)
    k = jnp.einsum("bse,ehk->bshk", xin, params["wk"]).astype(jnp.float32)
    v = jnp.einsum("bse,ehk->bshk", xin, params["wv"]).astype(jnp.float32)
    gif = jnp.einsum("bse,eh->bsh", xin, params["w_if"]).astype(jnp.float32)
    gif = gif + params["if_bias"]
    li, lf_raw = jnp.split(gif, 2, axis=-1)                        # (B,S,nh)
    lf = jax.nn.log_sigmoid(lf_raw)

    if cache is not None:
        state = (cache["C"].astype(jnp.float32), cache["n"].astype(jnp.float32),
                 cache["m"].astype(jnp.float32))
    else:
        state = (jnp.zeros((B, nh, dh, dh), jnp.float32),
                 jnp.zeros((B, nh, dh), jnp.float32),
                 jnp.full((B, nh), 0.0, jnp.float32))

    if mode == "decode":
        h, state = mlstm_step_ref(q[:, 0], k[:, 0], v[:, 0], li[:, 0], lf[:, 0], state)
        h = h[:, None]
    else:
        L = min(s.chunk_size, S)
        pad = (-S) % L
        def padt(t, val=0.0):
            return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2),
                           constant_values=val)
        qp, kp, vp, lfp = map(padt, (q, k, v, lf))
        lip = padt(li, -1e30)   # pad input-gate to exp(-inf)=0: pads are no-ops
        nchunk = qp.shape[1] // L
        def cchunks(t):
            return t.reshape((B, nchunk, L) + t.shape[2:]).transpose(
                (1, 0, 2) + tuple(range(3, t.ndim + 1)))
        state, h_chunks = jax.lax.scan(
            jax.checkpoint(_mlstm_chunk), state,
            tuple(map(cchunks, (qp, kp, vp, lip, lfp))))
        h = h_chunks.transpose(1, 0, 2, 3, 4).reshape(B, nchunk * L, nh, dh)[:, :S]

    h = h.reshape(B, -1, d_in)
    from repro.models.layers import rms_norm
    h = rms_norm(h.astype(x.dtype), params["out_norm"], cfg.norm_eps)
    h = h * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", h, params["w_down"])
    new_cache = None
    if mode in ("decode", "prefill"):
        C, n, m = state
        new_cache = {"C": C, "n": n, "m": m}
    return out, new_cache


def init_mlstm_cache(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = s.num_heads
    dh = d_in // nh
    return {"C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, nh, dh), jnp.float32),
            "m": jnp.zeros((batch, nh), jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, recurrent gate feedback)
# ---------------------------------------------------------------------------


def slstm_params(cfg: ModelConfig, kg: KeyGen, dtype) -> Dict:
    s = cfg.ssm
    d = cfg.d_model
    nh = s.num_heads
    dh = d // nh
    return {
        # 4 gates (z,i,f,o): input and block-diagonal recurrent weights
        "w_x": dense_init(kg(), (d, 4 * d), dtype),
        "r_h": dense_init(kg(), (nh, dh, 4 * dh), dtype, scale=dh ** -0.5),
        "bias": jnp.concatenate([
            jnp.zeros((2 * d,)), 3.0 * jnp.ones((d,)), jnp.zeros((d,))]).astype(jnp.float32),
        "out_norm": jnp.zeros((d,), dtype),
        # post-up-projection MLP (factor slstm_proj_factor, gelu)
        "w_pf1": dense_init(kg(), (d, int(d * s.slstm_proj_factor)), dtype),
        "w_pf2": dense_init(kg(), (int(d * s.slstm_proj_factor), d), dtype),
    }


def _slstm_step(params, nh, dh, carry, xs):
    """One sLSTM step.  carry: (c,n,m,h) each (B,nh,dh); xs: (x_t (B,4d), valid)."""
    x_t, valid = xs
    c, n, m, h = carry
    rec = jnp.einsum("bhk,hkf->bhf", h, params["r_h"].astype(jnp.float32))
    gates = x_t.reshape(x_t.shape[0], nh, 4 * dh) + rec            # (B,nh,4dh)
    z_t, i_t, f_t, o_t = jnp.split(gates, 4, axis=-1)
    m_new = jnp.maximum(jax.nn.log_sigmoid(f_t) + m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(jax.nn.log_sigmoid(f_t) + m - m_new)
    c_new = f_p * c + i_p * jnp.tanh(z_t)
    n_new = f_p * n + i_p
    h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1e-6)
    new_carry = (c_new, n_new, m_new, h_new)
    # padded steps must not evolve the state
    new_carry = jax.tree.map(lambda a, b: jnp.where(valid, a, b), new_carry, carry)
    return new_carry, h_new


def slstm_apply(params, x, cfg: ModelConfig, plan, cache: Optional[Dict] = None,
                mode: str = "train"):
    s = cfg.ssm
    B, S, D = x.shape
    nh = s.num_heads
    dh = D // nh
    xg = (jnp.einsum("bsd,df->bsf", x, params["w_x"]).astype(jnp.float32)
          + params["bias"])

    if cache is not None:
        carry = (cache["c"], cache["n"], cache["m"], cache["h"])
    else:
        zero = jnp.zeros((B, nh, dh), jnp.float32)
        carry = (zero, zero, zero, zero)

    step = functools.partial(_slstm_step, params, nh, dh)
    if mode == "decode":
        carry, h = step(carry, (xg[:, 0], jnp.bool_(True)))
        h_all = h[:, None]
    else:
        L = min(s.chunk_size, S)
        pad = (-S) % L
        xgp = jnp.pad(xg, ((0, 0), (0, pad), (0, 0)))
        valid = jnp.arange(xgp.shape[1]) < S
        nchunk = xgp.shape[1] // L
        xc = xgp.reshape(B, nchunk, L, -1).transpose(1, 2, 0, 3)   # (nc,L,B,4d)
        vc = valid.reshape(nchunk, L)

        @jax.checkpoint
        def chunk_body(carry, xs):
            return jax.lax.scan(step, carry, xs)

        carry, h_chunks = jax.lax.scan(chunk_body, carry, (xc, vc))
        h_all = h_chunks.reshape(nchunk * L, B, nh, dh).transpose(1, 0, 2, 3)[:, :S]

    h_all = h_all.reshape(B, -1, D)
    from repro.models.layers import rms_norm
    h_all = rms_norm(h_all.astype(x.dtype), params["out_norm"], cfg.norm_eps)
    # post-up-projection (gelu MLP)
    y = jnp.einsum("bsd,df->bsf", h_all, params["w_pf1"])
    y = jax.nn.gelu(y.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsf,fd->bsd", y, params["w_pf2"])
    new_cache = None
    if mode in ("decode", "prefill"):
        c, n, m, h = carry
        new_cache = {"c": c, "n": n, "m": m, "h": h}
    return out, new_cache


def init_slstm_cache(cfg: ModelConfig, batch: int):
    nh = cfg.ssm.num_heads
    dh = cfg.d_model // nh
    zero = jnp.zeros((batch, nh, dh), jnp.float32)
    return {"c": zero, "n": zero, "m": zero, "h": zero}
