"""Divisibility-aware sharding plans.

``ParallelPlan`` captures the mesh and axis roles; ``param_specs`` /
``batch_specs`` / ``cache_specs`` derive ``PartitionSpec`` pytrees for any
architecture, falling back per-tensor to replication when a dimension does
not divide the axis (see DESIGN.md §5: e.g. xlstm's 4 heads on a 16-way
model axis).

Axis roles:
  data axes ("pod", "data")  — batch / FSDP storage sharding
  model axis ("model")       — TP (heads, d_ff, vocab), EP (experts),
                               SP (sequence for long activations, KV spans)
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class ParallelPlan:
    mesh: Optional[Mesh] = None
    data_axes: Tuple[str, ...] = ()          # e.g. ("pod", "data") or ("data",)
    model_axis: Optional[str] = None         # "model"
    fsdp: bool = False                       # shard params/optim over data axis
    ep: bool = True                          # expert parallelism for MoE
    compress_grads: bool = False             # int8 all-reduce on pod axis

    # -- sizes ---------------------------------------------------------
    def axis_size(self, name: Optional[str]) -> int:
        if self.mesh is None or name is None:
            return 1
        return self.mesh.shape[name]

    @property
    def dp_size(self) -> int:
        return int(np.prod([self.axis_size(a) for a in self.data_axes] or [1]))

    @property
    def tp_size(self) -> int:
        return self.axis_size(self.model_axis)

    @property
    def fsdp_axis(self):
        # pod axis stays pure-DP (cross-pod traffic = gradients only — the
        # ISP rule for slow links).  With a model axis, FSDP uses the inner
        # data axis; without one (tp=1 / ZeRO-3 layout) params shard over
        # ALL non-pod axes so per-device state is params/(data·model).
        if not (self.fsdp and self.data_axes):
            return None
        inner = tuple(a for a in self.data_axes if a != "pod")
        if self.model_axis is None and len(inner) > 1:
            return inner
        return self.data_axes[-1]

    # -- spec helpers ---------------------------------------------------
    def _axis_total(self, axis) -> int:
        if axis is None:
            return 1
        if isinstance(axis, tuple):
            n = 1
            for a in axis:
                n *= self.axis_size(a)
            return n
        return self.axis_size(axis)

    def _fits(self, dim: int, axis) -> bool:
        n = self._axis_total(axis)
        return axis is not None and n > 1 and dim % n == 0

    def shard_dims(self, shape: Tuple[int, ...], prefs) -> P:
        """prefs: ordered [(dim_index, axis_name)]; first fit per dim/axis wins."""
        if self.mesh is None:
            return P()
        assign: Dict[int, str] = {}
        used = set()
        for dim, axis in prefs:
            if dim < len(shape) and axis not in used and dim not in assign \
                    and self._fits(shape[dim], axis):
                assign[dim] = axis
                used.add(axis)
        return P(*[assign.get(i) for i in range(len(shape))])

    def named(self, spec: P) -> Optional[NamedSharding]:
        return None if self.mesh is None else NamedSharding(self.mesh, spec)


def make_plan(mesh: Optional[Mesh], cfg: Optional[ModelConfig] = None, *,
              fsdp: Optional[bool] = None, compress_grads: bool = False,
              tp: Optional[int] = None) -> ParallelPlan:
    """tp=1 folds the model axis into data parallelism (pure DP+FSDP) —
    the right layout for ≤~30B dense models at large token batches, where
    TP's per-layer activation collectives dominate (see EXPERIMENTS §Perf,
    gemma3 hillclimb).  tp=None keeps the mesh's model axis for TP/EP/SP."""
    if mesh is None:
        return ParallelPlan()
    axes = tuple(mesh.axis_names)
    model_axis = "model" if "model" in axes else None
    if tp == 1:
        model_axis = None
    data_axes = tuple(a for a in axes if a != model_axis)
    if fsdp is None:
        # heuristic: large models need param/optim sharding over data
        fsdp = cfg is not None and cfg.param_count() > 3_000_000_000
    return ParallelPlan(mesh=mesh, data_axes=data_axes, model_axis=model_axis,
                        fsdp=bool(fsdp), compress_grads=compress_grads)


# ---------------------------------------------------------------------------
# Parameter specs by name pattern
# ---------------------------------------------------------------------------

# map leaf-name regex -> preference list builder(shape) -> [(dim, role)]
# roles: "tp" = model axis, "fsdp" = fsdp data axis.  Dims are indices into
# the *unstacked* shape; stacked (scan-group) leading dims are offset away.
_RULES = [
    # embeddings / output head: vocab over model, d_model over data
    (r"(table|w_head)$", lambda s: [(0, "tp"), (1, "fsdp")]),
    # attention projections
    (r"wq$", lambda s: [(1, "tp"), (0, "fsdp")]),
    (r"(wk|wv)$", lambda s: [(1, "tp"), (0, "fsdp")]),
    (r"wo$", lambda s: [(0, "tp"), (2, "fsdp")]),
    # MLA projections
    (r"(wq_b|wk_b|wv_b)$", lambda s: [(1, "tp"), (0, "fsdp")]),
    (r"(wq_a|wkv_a)$", lambda s: [(0, "fsdp")]),
    # MLPs (swiglu + xlstm/ssm projections)
    (r"(w_gate|w_up|ws_gate|ws_up|w_in|w_pf1|w_x)$", lambda s: [(len(s) - 1, "tp"), (0, "fsdp")]),
    (r"(w_down|ws_down|w_out|w_pf2|w_dt)$", lambda s: [(0, "tp"), (len(s) - 1, "fsdp")]),
    # MoE experts: E over model, D over data
    (r"(we_gate|we_up|we_down)$", lambda s: [(0, "tp"), (1, "fsdp")]),
    (r"router$", lambda s: []),
    # mamba/xlstm channel-wise tensors: shard channel dim over model
    (r"(conv_w|conv_b|a_log|d_skip|dt_bias)$", lambda s: [(len(s) - 1 if s[-1] > 64 else 0, "tp")]),
    (r"w_if$", lambda s: [(0, "tp")]),
]


def _leaf_spec(plan: ParallelPlan, path: str, shape: Tuple[int, ...],
               stacked: bool) -> P:
    base = shape[1:] if stacked else shape
    name = path.rsplit("/", 1)[-1]
    for pat, prefs_fn in _RULES:
        if re.search(pat, name):
            prefs = []
            for dim, role in prefs_fn(base):
                axis = plan.model_axis if role == "tp" else plan.fsdp_axis
                prefs.append((dim + (1 if stacked else 0), axis))
            return plan.shard_dims(shape, prefs)
    # default: replicate; fsdp models shard the largest divisible dim over data
    if plan.fsdp_axis and len(shape) > int(stacked):
        dims = sorted(range(int(stacked), len(shape)), key=lambda i: -shape[i])
        return plan.shard_dims(shape, [(dims[0], plan.fsdp_axis)])
    return P()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def param_specs(plan: ParallelPlan, params_shape, stacked_prefix: str = "blocks") -> Any:
    """PartitionSpec pytree matching a params pytree (of ShapeDtypeStructs)."""

    def spec(path, leaf):
        ps = _path_str(path)
        stacked = ps.startswith(stacked_prefix)
        return _leaf_spec(plan, ps, leaf.shape, stacked)

    return jax.tree_util.tree_map_with_path(spec, params_shape)


# ---------------------------------------------------------------------------
# Batch / activation / cache specs
# ---------------------------------------------------------------------------


def batch_spec(plan: ParallelPlan, global_batch: int) -> Tuple[Tuple[str, ...], P]:
    """Choose data axes that divide the batch; returns (axes, P(axes,...))."""
    if plan.mesh is None:
        return (), P()
    axes = []
    rem = global_batch
    for a in plan.data_axes:
        sz = plan.axis_size(a)
        if rem % sz == 0:
            axes.append(a)
            rem //= sz
    axes = tuple(axes)
    return axes, P(axes if axes else None)


def seq_axes_for_cache(plan: ParallelPlan, batch_axes: Tuple[str, ...],
                       seq_len: int) -> Tuple[str, ...]:
    """Axes available to shard the KV sequence dim (ISP decode spans)."""
    if plan.mesh is None:
        return ()
    axes = [a for a in (plan.data_axes + ((plan.model_axis,) if plan.model_axis else ()))
            if a not in batch_axes and a is not None]
    out = []
    rem = seq_len
    for a in axes:
        sz = plan.axis_size(a)
        if rem % sz == 0 and sz > 1:
            out.append(a)
            rem //= sz
    return tuple(out)


@dataclass(frozen=True)
class ShardingRecipe:
    """Everything the step builders need for one (arch, shape, mesh) cell."""
    plan: ParallelPlan
    batch_axes: Tuple[str, ...]
    seq_axes: Tuple[str, ...]          # KV-span sharding at decode

    # convenience passthroughs (models/core take a recipe as ``plan``)
    @property
    def mesh(self):
        return self.plan.mesh

    @property
    def model_axis(self):
        return self.plan.model_axis

    @property
    def data_axes(self):
        return self.plan.data_axes

    @property
    def fsdp_axis(self):
        return self.plan.fsdp_axis

    @property
    def ep(self):
        return self.plan.ep

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return tuple(self.plan.mesh.axis_names) if self.plan.mesh else ()

    @property
    def x_spec(self) -> P:             # activations (B, S, D)
        return P(self.batch_axes if self.batch_axes else None)

    def tokens_spec(self) -> P:        # (B, S)
        return P(self.batch_axes if self.batch_axes else None)

    def kv_cache_spec(self, seq_shardable: bool = True) -> P:
        # (B, S, Hkv, dh) — S over seq_axes (ISP decode)
        b = self.batch_axes if self.batch_axes else None
        s = self.seq_axes if (self.seq_axes and seq_shardable) else None
        return P(b, s)

    def kpos_spec(self, seq_shardable: bool = True) -> P:
        s = self.seq_axes if (self.seq_axes and seq_shardable) else None
        return P(s)

    def state_spec(self) -> P:         # recurrent state (B, ...)
        return P(self.batch_axes if self.batch_axes else None)


def make_recipe(plan: ParallelPlan, cfg: ModelConfig, shape: ShapeConfig) -> ShardingRecipe:
    b_axes, _ = batch_spec(plan, shape.global_batch)
    # ring caches for local layers have length `window`; global caches `seq`.
    # choose seq axes that divide the *smaller* of the two so one recipe fits
    # both cache families.
    seq_len = shape.seq_len
    if any(k == "local" for k in cfg.layer_pattern):
        seq_len = min(seq_len, cfg.attn.window)
    s_axes = seq_axes_for_cache(plan, b_axes, seq_len)
    return ShardingRecipe(plan=plan, batch_axes=b_axes, seq_axes=s_axes)
