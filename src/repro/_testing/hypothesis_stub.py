"""Minimal, deterministic stand-in for the ``hypothesis`` package.

The container image does not ship hypothesis and the repo may not add
dependencies, so ``tests/conftest.py`` installs this module under the
``hypothesis`` name *only when the real package is missing*.  It covers the
small API surface the test-suite uses:

    from hypothesis import given, settings, strategies as st
    st.floats / st.integers / st.sampled_from / st.dictionaries

Semantics: ``@given`` reruns the test for ``max_examples`` deterministic
examples (seeded per-test from the function name).  Boundary values are
emitted first — min/max of every scalar strategy — then pseudo-random
draws, which preserves most of the edge-case-hunting value of the real
thing without the shrinking machinery.
"""
from __future__ import annotations

import functools
import inspect
import itertools
import random
import types
import zlib

DEFAULT_MAX_EXAMPLES = 20


class Strategy:
    """A strategy = a draw function plus a list of boundary examples."""

    def __init__(self, draw, boundaries=()):
        self._draw = draw
        self.boundaries = list(boundaries)

    def draw(self, rng: random.Random):
        return self._draw(rng)


def floats(min_value: float, max_value: float) -> Strategy:
    lo, hi = float(min_value), float(max_value)
    return Strategy(lambda rng: rng.uniform(lo, hi), [lo, hi])


def integers(min_value: int, max_value: int) -> Strategy:
    lo, hi = int(min_value), int(max_value)
    return Strategy(lambda rng: rng.randint(lo, hi), [lo, hi])


def sampled_from(elements) -> Strategy:
    pool = list(elements)
    if not pool:
        raise ValueError("sampled_from requires a non-empty sequence")
    return Strategy(lambda rng: pool[rng.randrange(len(pool))],
                    [pool[0], pool[-1]])


def booleans() -> Strategy:
    return Strategy(lambda rng: bool(rng.getrandbits(1)), [False, True])


def lists(elements: Strategy, min_size: int = 0, max_size: int = 10) -> Strategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(n)]
    return Strategy(draw)


def dictionaries(keys: Strategy, values: Strategy, min_size: int = 0,
                 max_size: int = 10) -> Strategy:
    def draw(rng):
        target = rng.randint(min_size, max_size)
        out = {}
        for _ in range(50 * max(target, 1)):       # finite key pools cap size
            if len(out) >= target:
                break
            out[keys.draw(rng)] = values.draw(rng)
        if len(out) < min_size:                    # key pool smaller than min
            raise ValueError(
                f"dictionaries(min_size={min_size}) unsatisfiable: key "
                f"strategy yielded only {len(out)} distinct keys")
        return out
    return Strategy(draw)


def _boundary_examples(named: dict):
    """First examples: every strategy pinned to each of its boundaries (other
    params drawn randomly), mirroring hypothesis's bias toward edges."""
    for name, strat in named.items():
        for b in strat.boundaries:
            yield {name: b}


def given(**named_strategies):
    for name, s in named_strategies.items():
        if not isinstance(s, Strategy):
            raise TypeError(f"@given({name}=...) expects a strategy, got {s!r}")

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            max_examples = getattr(wrapper, "_stub_max_examples",
                                   DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            pinned = itertools.chain(_boundary_examples(named_strategies),
                                     itertools.repeat({}))
            for _, pin in zip(range(max_examples), pinned):
                drawn = {n: s.draw(rng) for n, s in named_strategies.items()}
                drawn.update(pin)
                fn(*args, **kwargs, **drawn)

        # hide the strategy-supplied params from pytest's fixture resolution
        sig = inspect.signature(fn)
        params = [p for p in sig.parameters.values()
                  if p.name not in named_strategies]
        wrapper.__signature__ = sig.replace(parameters=params)
        return wrapper

    return decorate


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    def decorate(fn):
        fn._stub_max_examples = max_examples
        return fn
    return decorate


strategies = types.ModuleType("hypothesis.strategies")
for _name, _obj in list(globals().items()):
    if _name in ("floats", "integers", "sampled_from", "booleans", "lists",
                 "dictionaries"):
        setattr(strategies, _name, _obj)
