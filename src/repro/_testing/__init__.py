"""Test-support shims (kept inside the package so tests can gate on them)."""
