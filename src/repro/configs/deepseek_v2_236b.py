"""deepseek-v2-236b — MLA kv_lora=512, 2 shared + 160 routed experts top-6 [arXiv:2405.04434].

60L d_model=5120 128H (GQA kv=128) d_ff=1536 vocab=102400.  MLA caches only
the 512-dim compressed c_kv + 64-dim rope key per token (576 values/token —
KV-transfer compression, itself very ISP-flavoured).  MoE: 2 shared + 160
routed, top-6, d_ff_expert=1536 → EP shards 10 experts per model rank.
Full (MLA) attention → long_500k skipped.
"""
from repro.config import AttnConfig, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=1536,
    vocab_size=102_400,
    block_pattern=("mla_moe",),
    attn=AttnConfig(kind="mla", kv_lora_rank=512, qk_rope_dim=64,
                    qk_nope_dim=128, v_head_dim=128, q_lora_rank=1536,
                    rope_base=10_000.0),
    moe=MoEConfig(num_experts=160, num_shared_experts=2, top_k=6,
                  d_ff_expert=1536, d_ff_shared=1536, capacity_factor=1.25),
    tie_embeddings=False,
    subquadratic=False,
    remat="full",
    grad_accum=4,
    attn_chunk=1024,
))
