"""starcoder2-15b — GQA, RoPE [arXiv:2402.19173].

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.  Pure full attention →
long_500k skipped.
"""
from repro.config import AttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24_576,
    vocab_size=49_152,
    block_pattern=("attn",),
    attn=AttnConfig(kind="full", rope_base=100_000.0),
    tie_embeddings=False,
    subquadratic=False,
))
