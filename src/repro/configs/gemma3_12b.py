"""gemma3-12b — 5:1 local:global attention, 128k context [hf:google/gemma-3].

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.  Five sliding-window
(1024) layers per one global layer.  Mostly-local → bounded decode state for
5/6 of layers; we run long_500k (global layers keep a full 500k KV, which is
O(S) memory but O(1)-per-step compute at decode; see DESIGN.md §5).
"""
from repro.config import AttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=240,
    d_ff=15_360,
    vocab_size=262_144,
    block_pattern=("local",) * 5 + ("attn",),
    attn=AttnConfig(kind="local", window=1024, rope_base=1_000_000.0, rope_base_local=10_000.0),
    tie_embeddings=True,
    subquadratic=True,
    scan_group=6,
    notes="flagship for ISP vocab embedding (262k vocab); 5:1 local:global pattern scanned in groups of 6",
))
