"""llama3-405b — GQA, 128k vocab [arXiv:2407.21783].

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.  FSDP flagship:
params+grads+m/v in bf16 → 3.24 TB state, 12.7 GB/chip on a 256-chip pod.
Pure full attention → long_500k skipped.
"""
from repro.config import AttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16_384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53_248,
    vocab_size=128_256,
    block_pattern=("attn",),
    attn=AttnConfig(kind="full", rope_base=500_000.0),
    tie_embeddings=False,
    subquadratic=False,
    remat="full",
    optimizer_state_dtype="bfloat16",
    grad_accum=1,   # accum>1 re-gathers FSDP weights per micro — measured regression (§Perf)
    attn_chunk=1024,
    notes="optimizer m/v kept bf16 so total train state fits 256x16GB (see DESIGN.md §4)",
))
