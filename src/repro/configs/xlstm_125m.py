"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517].

12L d_model=768 4H (GQA kv=4) d_ff=0 vocab=50304.  d_ff=0: xLSTM blocks carry
their own up-projections (mLSTM expand=2, sLSTM proj factor 4/3).  Alternating
mlstm/slstm pattern; fully recurrent → sub-quadratic, runs long_500k.
"""
from repro.config import AttnConfig, ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    block_pattern=("mlstm", "slstm"),
    attn=AttnConfig(kind="full"),
    ssm=SSMConfig(num_heads=4, expand=2, chunk_size=128, conv_width=4),
    tie_embeddings=True,
    subquadratic=True,
    notes="sLSTM scalar-memory + mLSTM matrix-memory blocks; no attention, no KV cache",
))
