"""hymba-1.5b — parallel attention + mamba heads per layer [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Hybrid-head blocks: every layer runs sliding-window attention heads and mamba
(SSM) heads in parallel on the same input, fuses, then MLP.  Hybrid →
sub-quadratic, runs long_500k.
"""
from repro.config import AttnConfig, ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32_001,
    block_pattern=("hybrid",),
    attn=AttnConfig(kind="local", window=1024),
    ssm=SSMConfig(state_dim=16, expand=2, conv_width=4, chunk_size=128),
    tie_embeddings=True,
    subquadratic=True,
    scan_group=1,
    notes="parallel attn+mamba heads; attn is sliding-window (hymba global KV is tiny meta tokens, stubbed)",
))
