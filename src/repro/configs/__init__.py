"""Assigned architecture configs.  Importing this package registers all archs."""
from repro.configs import (  # noqa: F401
    xlstm_125m,
    hymba_1_5b,
    gemma3_12b,
    yi_9b,
    starcoder2_15b,
    llama3_405b,
    chameleon_34b,
    musicgen_large,
    llama4_scout_17b_a16e,
    deepseek_v2_236b,
)

ASSIGNED = (
    "xlstm-125m",
    "hymba-1.5b",
    "gemma3-12b",
    "yi-9b",
    "starcoder2-15b",
    "llama3-405b",
    "chameleon-34b",
    "musicgen-large",
    "llama4-scout-17b-a16e",
    "deepseek-v2-236b",
)
