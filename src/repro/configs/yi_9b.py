"""yi-9b — llama-architecture GQA [arXiv:2403.04652].

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.  Pure full attention →
long_500k skipped per assignment note.
"""
from repro.config import AttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="yi-9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11_008,
    vocab_size=64_000,
    block_pattern=("attn",),
    attn=AttnConfig(kind="full", rope_base=10_000.0),
    tie_embeddings=False,
    subquadratic=False,
))
