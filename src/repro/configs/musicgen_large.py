"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284].

48L d_model=2048 32H (kv=32 → MHA) d_ff=8192 vocab=2048.  The EnCodec audio
frontend is a STUB: input_specs() provides precomputed frame embeddings; the
backbone is a standard decoder over the 2048-entry codebook.  Pure full
attention → long_500k skipped.
"""
from repro.config import AttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    block_pattern=("attn",),
    attn=AttnConfig(kind="full", rope_base=10_000.0),
    frontend="audio",
    tie_embeddings=True,
    subquadratic=False,
))
