"""chameleon-34b — early-fusion VQ image tokens [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.  The modality frontend
(VQ-VAE image tokenizer) is a STUB: input_specs() provides precomputed patch
embeddings for the image span; text tokens embed normally.  Pure full
attention → long_500k skipped.
"""
from repro.config import AttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22_016,
    vocab_size=65_536,
    block_pattern=("attn",),
    attn=AttnConfig(kind="full", rope_base=10_000.0),
    frontend="vlm",
    tie_embeddings=False,
    subquadratic=False,
))
