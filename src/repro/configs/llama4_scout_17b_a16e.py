"""llama4-scout-17b-a16e — MoE 16 experts top-1, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.  Every layer: GQA
attention + (1 shared expert + 16 routed experts, top-1).  EP maps 1 expert
per model-axis shard on the 16-way production mesh — the cleanest possible
"send the token to the drive that owns the weights" cell.  Full attention →
long_500k skipped.
"""
from repro.config import AttnConfig, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    block_pattern=("moe",),
    attn=AttnConfig(kind="full", rope_base=500_000.0),
    moe=MoEConfig(num_experts=16, num_shared_experts=1, top_k=1,
                  d_ff_expert=8192, d_ff_shared=8192, capacity_factor=1.25),
    tie_embeddings=False,
    subquadratic=False,
))
