"""int8 gradient compression for slow-link (pod-axis) all-reduce.

Per-tensor symmetric int8 quantization with stochastic rounding (unbiased),
used to cut the inter-pod gradient all-reduce bytes 4x (bf16→int8 would be
2x; we quantize the fp32 reduction operand, 4x).  The psum itself runs on
the int32 accumulation of int8 payloads so no precision is lost in the
reduction, only in the quantization — whose error has zero mean thanks to
stochastic rounding (property-tested).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def int8_compress(x, key) -> Tuple[jax.Array, jax.Array]:
    """Returns (q int8, scale fp32 scalar) with stochastic rounding."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    y = x32 / scale
    noise = jax.random.uniform(key, x32.shape, jnp.float32) - 0.5
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(x, axis_name: str, key):
    """psum over ``axis_name`` with int8 payload (slow-link gradient trick).

    int8 payloads are summed in int32 (exact), scales are pmax'd; the
    decompression uses the shared max-scale so the sum is consistent.
    """
    x32 = x.astype(jnp.float32)
    amax_local = jnp.max(jnp.abs(x32))
    amax = jax.lax.pmax(amax_local, axis_name)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    noise = jax.random.uniform(key, x32.shape, jnp.float32) - 0.5
    q = jnp.clip(jnp.round(x32 / scale + noise), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale
