"""AdamW with configurable state dtype (bf16 m/v for ≥100B models, see
llama3-405b config) and global-norm clipping.  Pure pytree functions — the
optimizer state inherits the parameters' sharding (FSDP shards optimizer
state for free)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    norms = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads)
    gn = jnp.sqrt(sum(jax.tree.leaves(norms)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale
    dt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * jnp.square(g)
        mhat = m32 / bc1
        vhat = v32 / bc2
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    flat = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda x: x[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda x: x[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda x: x[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}
