"""Deterministic fault injection + failure detection for the cluster tier.

The paper's deployment target is a 36-drive storage server (Table I /
Fig. 6); at that scale drive stalls, stragglers, and outright failures are
the steady state, not the exception — and in-storage processing moves the
availability responsibility onto the drive-side stack (ZCSD makes the same
argument for CSD runtimes owning failure semantics).  This module is the
pure half of that layer; ``train.cluster_loop.ClusterEngine`` consults it
each tick:

  * ``FaultSchedule`` — a seeded, replayable list of per-drive
    ``FaultEvent``s.  Five kinds:
      stall            the drive makes no progress while the event is
                       active (work sits, its virtual clock stops);
      slowdown         the drive's measured tick time is multiplied by
                       ``factor`` (>1 = slower) while active;
      crash            the drive stops responding permanently — the
                       cluster is NOT told (ground truth stays hidden);
                       only the failure layer can discover it and
                       trigger ``fail()``;
      worker_hang      the drive's worker thread really blocks for
                       ``duration`` REAL seconds at the dispatch boundary
                       (the in-flight command is lost; only a heartbeat
                       watchdog can catch it).  In the serial step loop —
                       where there is no thread to block — a hang is
                       approximated as a stall over the event window;
      page_pool_clamp  only ``factor`` (0..1) of the drive's KV page pool
                       is admissible while active — admission
                       backpressures, in-flight requests are untouched.
    Events are timed on either the cluster TICK index (``at_tick`` —
    exactly reproducible run-to-run) or the cluster wall CLOCK (``at_s`` —
    the MTTF/MTTR view; tick times are measured, so clock-based landing
    points jitter, which is fine: greedy decode makes token outputs
    identical under ANY fault landing).  ``from_rates`` draws a schedule
    from exponential MTTF/MTTR distributions with a fixed seed, and
    ``save``/``load`` round-trip a schedule through jsonl (one event per
    line, mirroring ``data.workload.save_trace``) so a chaos run can be
    replayed exactly.

  * ``FailureDetector`` — the cluster-visible health state machine
    (HEALTHY → SUSPECT → DEAD).  It sees only what a host could see: the
    per-drive virtual clocks and whether a drive with work progressed this
    tick.  A drive with work that makes no progress while the leading
    clock advances more than ``suspect_after_s`` (or for ``suspect_ticks``
    consecutive ticks) goes SUSPECT; past ``dead_after_s`` /
    ``dead_ticks`` it goes DEAD, which the engine turns into the existing
    ``fail()`` path automatically.  A SUSPECT drive that progresses again
    recovers to HEALTHY.  This clock-threshold detector is the serial step
    loop's failure oracle; the concurrent worker runtime uses
    ``core.runtime.HeartbeatWatchdog`` (same state machine, driven by
    missed heartbeats and real wall time) instead.

Everything is plain-Python and deterministic given the event list, so
token identity under any fault schedule is property-testable.
"""
from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

FAULT_KINDS = ("stall", "slowdown", "crash", "worker_hang", "page_pool_clamp")

HEALTHY = "healthy"
SUSPECT = "suspect"
DEAD = "dead"


@dataclass(frozen=True)
class FaultEvent:
    """One injected fault on one drive.

    Exactly one of ``at_tick`` / ``at_s`` must be set; ``duration`` is in
    the same unit (ticks or seconds).  ``factor`` is the slowdown
    multiplier (>= 1) or the admissible pool fraction (0..1) for
    ``page_pool_clamp``; crashes ignore both duration and factor (death is
    permanent — recovery is a *new drive*, not this event ending).  For
    ``worker_hang`` the concurrent runtime blocks the worker thread for
    ``duration`` REAL seconds when the first command lands in the event
    window; the serial loop approximates the window as a stall.
    """
    drive_id: int
    kind: str
    at_tick: Optional[int] = None
    at_s: Optional[float] = None
    duration: float = 0.0
    factor: float = 1.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"fault kind must be one of {FAULT_KINDS}, "
                             f"got {self.kind!r}")
        if (self.at_tick is None) == (self.at_s is None):
            raise ValueError("exactly one of at_tick / at_s must be set")
        if self.drive_id < 0:
            raise ValueError(f"negative drive_id {self.drive_id}")
        if self.kind != "crash" and \
                (self.duration < 0 or not math.isfinite(self.duration)):
            raise ValueError(f"duration must be finite and >= 0, "
                             f"got {self.duration}")
        if self.kind == "worker_hang" and not self.duration > 0:
            raise ValueError(f"worker_hang duration must be > 0 (real "
                             f"seconds the thread blocks), "
                             f"got {self.duration}")
        if self.kind == "slowdown" and not (self.factor >= 1.0
                                            and math.isfinite(self.factor)):
            raise ValueError(f"slowdown factor must be finite and >= 1, "
                             f"got {self.factor}")
        if self.kind == "page_pool_clamp" and not 0.0 <= self.factor <= 1.0:
            raise ValueError(f"page_pool_clamp factor must be in [0, 1], "
                             f"got {self.factor}")

    @property
    def start(self) -> float:
        return float(self.at_tick if self.at_tick is not None else self.at_s)

    @property
    def tick_based(self) -> bool:
        return self.at_tick is not None

    def active(self, tick: int, clock: float) -> bool:
        now = tick if self.tick_based else clock
        if self.kind == "crash":
            return now >= self.start
        return self.start <= now < self.start + self.duration

    @property
    def end(self) -> float:
        """First instant the event is over (inf for crashes)."""
        if self.kind == "crash":
            return math.inf
        return self.start + self.duration


class FaultSchedule:
    """A replayable set of fault events the cluster consults each tick."""

    def __init__(self, events: Sequence[FaultEvent]):
        self.events: List[FaultEvent] = sorted(
            events, key=lambda e: (e.start, e.drive_id, e.kind))
        self._crashed: set = set()   # crash events already delivered
        self._begun: set = set()     # events already counted as injected

    # -- construction ---------------------------------------------------------

    @classmethod
    def from_spec(cls, spec: Sequence[Dict]) -> "FaultSchedule":
        """Build from a list of plain dicts (the --fault-trace JSON form):
        ``{"drive_id": 1, "kind": "stall", "at_tick": 5, "duration": 10}``."""
        return cls([FaultEvent(**dict(e)) for e in spec])

    @classmethod
    def from_rates(cls, n_drives: int, mttf_s: float, mttr_s: float,
                   seed: int = 0, horizon_s: float = 60.0,
                   crash_prob: float = 0.1, slowdown_factor: float = 3.0,
                   clamp_frac: float = 0.25) -> "FaultSchedule":
        """Draw a schedule from exponential MTTF/MTTR distributions.

        Per drive, fault arrivals are a Poisson process with mean
        inter-arrival ``mttf_s``; each fault is a crash with probability
        ``crash_prob`` (permanent — the drive draws no further events),
        otherwise a stall / slowdown / page_pool_clamp (uniform) lasting
        an Exp(``mttr_s``) repair window.  Same seed, same schedule.
        """
        if n_drives < 1:
            raise ValueError("need at least one drive")
        if not (mttf_s > 0 and mttr_s > 0):
            raise ValueError("mttf_s and mttr_s must be positive")
        if not 0.0 <= crash_prob <= 1.0:
            raise ValueError(f"crash_prob must be in [0, 1], got {crash_prob}")
        rng = np.random.default_rng(seed)
        transient = ("stall", "slowdown", "page_pool_clamp")
        events: List[FaultEvent] = []
        for d in range(n_drives):
            t = 0.0
            while True:
                t += float(rng.exponential(mttf_s))
                if t >= horizon_s:
                    break
                if float(rng.random()) < crash_prob:
                    events.append(FaultEvent(d, "crash", at_s=t))
                    break                       # dead drives stay dead
                kind = transient[int(rng.integers(len(transient)))]
                dur = float(rng.exponential(mttr_s))
                factor = {"stall": 1.0, "slowdown": slowdown_factor,
                          "page_pool_clamp": clamp_frac}[kind]
                events.append(FaultEvent(d, kind, at_s=t, duration=dur,
                                         factor=factor))
                t += dur                        # repair before the next fault
        return cls(events)

    # -- persistence (mirrors data.workload.save_trace / load_trace) ----------

    def save(self, path: str) -> None:
        """Write the schedule as jsonl, one event per line, so a chaos
        run's exact schedule can be committed and replayed."""
        with open(path, "w") as f:
            for e in self.events:
                rec = {k: v for k, v in dataclasses.asdict(e).items()
                       if v is not None}
                f.write(json.dumps(rec, sort_keys=True) + "\n")

    @classmethod
    def load(cls, path: str) -> "FaultSchedule":
        """Read a schedule back.  Accepts both the jsonl form written by
        ``save`` and the legacy ``--fault-trace`` JSON-list form."""
        with open(path) as f:
            text = f.read().strip()
        if not text:
            return cls([])
        if text.startswith("["):
            return cls.from_spec(json.loads(text))
        return cls.from_spec([json.loads(line)
                              for line in text.splitlines() if line.strip()])

    # -- per-tick queries (consulted by ClusterEngine.step) -------------------

    def begins(self, tick: int, clock: float) -> List[FaultEvent]:
        """Events becoming active this tick, each reported exactly once
        (the engine's ``faults_injected`` counter)."""
        out = []
        for i, e in enumerate(self.events):
            if i not in self._begun and e.active(tick, clock):
                self._begun.add(i)
                out.append(e)
        return out

    def crashes(self, tick: int, clock: float) -> List[int]:
        """Drives whose crash event fires now (each delivered once)."""
        out = []
        for i, e in enumerate(self.events):
            if e.kind == "crash" and i not in self._crashed \
                    and e.active(tick, clock):
                self._crashed.add(i)
                out.append(e.drive_id)
        return sorted(set(out))

    def stalled(self, drive_id: int, tick: int, clock: float) -> bool:
        """True while a stall, a worker_hang window, or a delivered crash
        (a crashed drive is a permanent stall until the failure layer
        notices) holds the drive.  Pure — safe to consult from a worker
        thread without touching the delivered-event bookkeeping."""
        return any(e.drive_id == drive_id
                   and e.kind in ("stall", "crash", "worker_hang")
                   and e.active(tick, clock) for e in self.events)

    def crash_active(self, drive_id: int, tick: int, clock: float) -> bool:
        """Pure crash check (no delivered-set mutation) — the concurrent
        worker's exit condition: a crashed worker thread terminates and
        the cluster only ever sees the silence."""
        return any(e.drive_id == drive_id and e.kind == "crash"
                   and e.active(tick, clock) for e in self.events)

    def hangs(self, drive_id: int, tick: int, clock: float
              ) -> List[Tuple[int, float]]:
        """Active worker_hang events for a drive as ``(event_index,
        real_seconds)`` pairs.  Pure; the worker tracks which indices it
        has already served so each hang blocks the thread exactly once."""
        return [(i, float(e.duration)) for i, e in enumerate(self.events)
                if e.drive_id == drive_id and e.kind == "worker_hang"
                and e.active(tick, clock)]

    def slowdown(self, drive_id: int, tick: int, clock: float) -> float:
        """Multiplier on the drive's tick time (active slowdowns compound)."""
        f = 1.0
        for e in self.events:
            if e.drive_id == drive_id and e.kind == "slowdown" \
                    and e.active(tick, clock):
                f *= e.factor
        return f

    def clamp(self, drive_id: int, tick: int, clock: float) -> float:
        """Admissible fraction of the drive's KV page pool (min of active
        clamps; 1.0 = unclamped)."""
        f = 1.0
        for e in self.events:
            if e.drive_id == drive_id and e.kind == "page_pool_clamp" \
                    and e.active(tick, clock):
                f = min(f, e.factor)
        return f

    # -- progress boundaries (deadlock avoidance) -----------------------------

    def next_tick_boundary(self, tick: int) -> Optional[int]:
        """The next tick index at which some tick-based event starts or
        ends (None when no tick-based change is pending)."""
        best = None
        for e in self.events:
            if not e.tick_based:
                continue
            for b in (e.start, e.end):
                if math.isfinite(b) and b > tick and \
                        (best is None or b < best):
                    best = b
        return None if best is None else int(best)

    def next_clock_boundary(self, clock: float) -> Optional[float]:
        """The next wall-clock time at which some clock-based event starts
        or ends — where a no-progress tick can fast-forward to so stall
        windows and deadlines elapse instead of deadlocking."""
        best = None
        for e in self.events:
            if e.tick_based:
                continue
            for b in (e.start, e.end):
                if math.isfinite(b) and b > clock and \
                        (best is None or b < best):
                    best = b
        return best


class FailureDetector:
    """SUSPECT/DEAD health tracking from cluster-visible signals only.

    Per tick and per drive the engine reports the leading virtual clock,
    whether the drive had work, and whether it progressed (stepped).  Lag
    is measured as *leading-clock advance since the drive's last
    productive tick* — not absolute clock skew, which would latch forever
    after a recovered stall (a drive that lost 5s of busy time stays 5s
    behind even once healthy).
    """

    def __init__(self, n_drives: int, suspect_after_s: float = 0.25,
                 suspect_ticks: int = 20,
                 dead_after_s: Optional[float] = None,
                 dead_ticks: Optional[int] = None):
        if n_drives < 1:
            raise ValueError("need at least one drive")
        if suspect_after_s <= 0 or suspect_ticks <= 0:
            raise ValueError("suspect thresholds must be positive")
        self.n_drives = n_drives
        self.suspect_after_s = float(suspect_after_s)
        self.suspect_ticks = int(suspect_ticks)
        self.dead_after_s = float(4.0 * suspect_after_s
                                  if dead_after_s is None else dead_after_s)
        self.dead_ticks = int(4 * suspect_ticks
                              if dead_ticks is None else dead_ticks)
        if self.dead_after_s < self.suspect_after_s or \
                self.dead_ticks < self.suspect_ticks:
            raise ValueError("dead thresholds must not be below suspect "
                             "thresholds")
        self.health: List[str] = [HEALTHY] * n_drives
        self._zero_ticks = [0] * n_drives
        self._lead_at_progress = [0.0] * n_drives

    def observe(self, drive_id: int, lead: float, progressed: bool,
                has_work: bool) -> Tuple[str, str]:
        """One tick's evidence for one drive; returns (old, new) health.
        DEAD is terminal — the engine fails the drive on that edge."""
        old = self.health[drive_id]
        if old == DEAD:
            return old, old
        if progressed or not has_work:
            # an idle drive's clock legitimately stands still; never
            # suspect it — and a productive tick clears any suspicion
            self._zero_ticks[drive_id] = 0
            self._lead_at_progress[drive_id] = lead
            self.health[drive_id] = HEALTHY
            return old, HEALTHY
        self._zero_ticks[drive_id] += 1
        lag = lead - self._lead_at_progress[drive_id]
        new = old
        if self._zero_ticks[drive_id] >= self.dead_ticks or \
                lag > self.dead_after_s:
            new = DEAD
        elif self._zero_ticks[drive_id] >= self.suspect_ticks or \
                lag > self.suspect_after_s:
            new = SUSPECT
        self.health[drive_id] = new
        return old, new

    def mark_dead(self, drive_id: int) -> None:
        """Operator/engine-initiated death (explicit ``fail()``) — keep the
        detector's view consistent with ground truth it was told about."""
        self.health[drive_id] = DEAD

    @property
    def suspects(self) -> List[int]:
        return [d for d, h in enumerate(self.health) if h == SUSPECT]

    @property
    def dead(self) -> List[int]:
        return [d for d, h in enumerate(self.health) if h == DEAD]
