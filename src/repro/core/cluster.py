"""Multi-drive CSD cluster layer: routing policies over replica serve
engines behind one queue, merged transfer stats, and live energy accounting.

The paper's headline numbers come from a *cluster* of CSDs in one storage
server (36 drives, Table I / Fig. 6), not from a single device.  This module
is the pure/mechanical half of that tier — the serving half
(``train.cluster_loop.ClusterEngine``) owns the replica engines and drives
the pieces defined here:

  * ``Router`` — pluggable dispatch policies over a shared request queue:
      round_robin   cycle over accepting drives (ignores load and locality);
      least_loaded  pick the drive with the lowest live slot/page occupancy;
      data_local    requests carry a ``shard_id``; the router pins them to
                    the drive holding that shard (bring compute to data),
                    spilling to the least-loaded remote drive only when the
                    home drive has no capacity — and every remote serve is
                    charged the shard bytes that now have to cross the link;
      rate_aware    pick the drive with the shortest *expected completion*
                    (virtual clock + backlog / learned rate — the cluster
                    pull scheduler's live per-drive estimates), WAITING for
                    that drive when it is momentarily full rather than
                    burdening a slower-but-free one: a 2x-slower drive ends
                    up with proportionally fewer requests instead of an
                    equal share.  Unobserved drives are tried first so
                    every drive produces a measurement (explore, then
                    exploit);
  * ``merge_ledgers`` — fold per-drive ``TransferLedger``s (plus the
    cluster's own spill ledger) into one cluster-wide accounting;
  * ``ClusterStats`` — the merged view: aggregate tokens/s under the
    parallel-drives wall-clock model (per tick the cluster advances by the
    *slowest* stepped drive — drives are independent hardware), per-tick
    active-engine counts integrated into wall energy via
    ``core.energy.server_power``, and the Table I metric
    ``energy_per_query_mj`` next to the link/KV reductions.
"""
from __future__ import annotations

import math
import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Union

from repro.core import energy as E
from repro.core.latency import LatencyStats
from repro.core.transfer import TransferLedger

ROUTING_POLICIES = ("round_robin", "least_loaded", "data_local",
                    "rate_aware")

Placement = Union[Dict[int, int], Callable[[int], int], None]


class ClusterExhaustedError(RuntimeError):
    """Every drive is draining/failed and queued work can never be served.

    Subclasses ``RuntimeError`` (and keeps "draining/failed" in its
    message) so callers matching on the old exception keep working.  When
    the LAST healthy drive *fails*, the engine instead finishes queued
    requests with ``status="failed"`` — this error marks the drain-only
    corner, where the operator parked every drive with work still queued.
    """


def merge_ledgers(ledgers: Sequence[TransferLedger]) -> TransferLedger:
    """Fold per-drive ledgers into one cluster ledger (tiers and notes sum)."""
    out = TransferLedger()
    for led in ledgers:
        out.link_bytes += led.link_bytes
        out.local_bytes += led.local_bytes
        out.output_bytes += led.output_bytes
        out.kv_bytes += led.kv_bytes
        for note, n in led.notes.items():
            out.notes[note] = out.notes.get(note, 0.0) + n
    return out


def shard_spill_bytes(prompt_len: int, max_new: int, d_model: int,
                      bytes_per_el: int) -> float:
    """Link bytes a remote serve costs: the request's resident token rows
    (prompt + everything it will generate) live on the home drive and must
    cross the drive-to-drive link when another drive computes on them —
    the inverse of the paper's bring-compute-to-data placement."""
    return float((prompt_len + max_new) * d_model * bytes_per_el)


@dataclass
class DriveLoad:
    """One drive's live occupancy as the router sees it."""
    drive_id: int
    num_slots: int
    active: int = 0            # slots mid-flight
    pending: int = 0           # requests queued on the drive itself
    page_fill: float = 0.0     # fraction of the KV page pool in use
    accepting: bool = True     # False while draining / after a failure
    clock: float = 0.0         # drive's virtual clock (cumulative busy time)
    service_s: float = math.nan  # est. seconds to serve one request
    quota: Optional[int] = None  # optional hard cap on in-flight requests

    @property
    def capacity(self) -> int:
        """Requests the drive can take before they queue behind a slot —
        optionally hard-capped by an explicit pull quota.  (The default
        rate_aware gate prefers ETA deferral over this cap: one engine tick
        costs the same whether 1 or all slots are live, so capping a slow
        drive below its slot count wastes whole ticks on partial batches.)"""
        cap = self.num_slots if self.quota is None \
            else min(self.num_slots, self.quota)
        return cap - self.active - self.pending

    @property
    def load(self) -> float:
        """Slot occupancy, page occupancy as the tie-break (two drives with
        the same slot count but different live KV tails differ in how soon
        their pools backpressure)."""
        return (self.active + self.pending) / max(self.num_slots, 1) \
            + 0.25 * self.page_fill


@dataclass(frozen=True)
class Route:
    drive_id: int
    remote: bool = False       # data_local spill (or home drive unavailable)


class Router:
    """Pluggable routing policy over a set of ``DriveLoad``s.

    ``pick`` returns ``None`` when no eligible drive can accept the request
    this tick — the request stays in the shared queue (FIFO order is
    preserved by the caller; the cluster never reorders around a blocked
    head, which keeps replay deterministic).
    """

    def __init__(self, policy: str, n_drives: int,
                 placement: Placement = None, spill: bool = True):
        if policy not in ROUTING_POLICIES:
            raise ValueError(f"routing policy must be one of "
                             f"{ROUTING_POLICIES}, got {policy!r}")
        if n_drives < 1:
            raise ValueError("need at least one drive")
        self.policy = policy
        self.n_drives = n_drives
        self.placement = placement
        self.spill = spill
        # routing state (_rr rotation, _overrides) is shared between the
        # coordinator and anything inspecting routes concurrently; RLock
        # because pick() -> _is_remote() -> home() re-enters
        self._lock = threading.RLock()
        self._rr = 0
        # shard re-placement: overrides win over the static placement, so a
        # drained/failed drive's shards can move to a survivor once instead
        # of paying spill bytes on every future request
        self._overrides: Dict[int, int] = {}

    def home(self, shard_id: int) -> int:
        """The drive holding ``shard_id``'s data (re-placement overrides
        first, then the static placement)."""
        with self._lock:
            if shard_id in self._overrides:
                return self._overrides[shard_id]
        if callable(self.placement):
            d = self.placement(shard_id)
        elif isinstance(self.placement, dict):
            d = self.placement[shard_id]
        else:
            d = shard_id % self.n_drives
        if not 0 <= d < self.n_drives:
            raise ValueError(f"placement maps shard {shard_id} to drive {d} "
                             f"outside [0, {self.n_drives})")
        return d

    def replace_shard(self, shard_id: int, drive_id: int) -> None:
        """Move ``shard_id``'s home to ``drive_id`` (the caller charges the
        migrated bytes; from here on the shard is local to its new home)."""
        if not 0 <= drive_id < self.n_drives:
            raise ValueError(f"cannot place shard {shard_id} on drive "
                             f"{drive_id} outside [0, {self.n_drives})")
        with self._lock:
            self._overrides[shard_id] = drive_id

    def pick(self, shard_id: Optional[int],
             loads: Sequence[DriveLoad]) -> Optional[Route]:
        eligible = [l for l in loads if l.accepting and l.capacity > 0]
        if not eligible:
            return None
        with self._lock:
            if self.policy == "round_robin":
                return self._round_robin(shard_id, loads, eligible)
            if self.policy == "least_loaded":
                return self._least_loaded(shard_id, eligible)
            if self.policy == "rate_aware":
                return self._rate_aware(shard_id, loads, eligible)
            return self._data_local(shard_id, loads, eligible)

    # -- policies ------------------------------------------------------------

    def _is_remote(self, shard_id: Optional[int], drive_id: int) -> bool:
        """A sharded request served off its home drive pays the spill bytes
        regardless of which policy put it there — that is exactly the cost a
        locality-oblivious policy silently eats."""
        return shard_id is not None and self.home(shard_id) != drive_id

    def _round_robin(self, shard_id, loads, eligible) -> Route:
        # Rotate over the ELIGIBLE set: the next pick is the first eligible
        # drive in cyclic order strictly after the last one picked.  Keying
        # the rotation to the last picked drive (rather than stepping a raw
        # pointer that can come to rest on an ineligible drive) keeps the
        # distribution uniform over the survivors when a drive drains or
        # fails mid-rotation — no survivor permanently inherits the drained
        # drive's turns.
        ids = sorted(l.drive_id for l in eligible)
        d = next((i for i in ids if i >= self._rr), ids[0])
        self._rr = (d + 1) % self.n_drives
        return Route(d, remote=self._is_remote(shard_id, d))

    def _least_loaded(self, shard_id, eligible) -> Route:
        best = min(eligible, key=lambda l: (l.load, l.drive_id))
        return Route(best.drive_id,
                     remote=self._is_remote(shard_id, best.drive_id))

    def _rate_aware(self, shard_id, loads, eligible) -> Optional[Route]:
        """Shortest expected COMPLETION across the whole cluster: the
        request goes to the drive minimizing

            virtual clock + (in-flight + 1) × est. seconds per request

        i.e. when the drive would actually finish it, given how far ahead
        its clock already is and its learned service rate.  If that drive
        has no free slot the head WAITS for it (returns None) — handing
        the request to a slower-but-free drive would finish it later, and
        one engine tick costs the same whether 1 or all slots are live, so
        partially loading the slow drive wastes whole (2x-priced) ticks.
        This deferral IS the pull quota in continuous form: a 2x-slower
        drive's clock runs ahead 2x faster, so it ends up pulling
        proportionally fewer requests without any hard cap.

        Drives without an estimate yet are tried FIRST (they must serve
        something before the scheduler can rate them), ordered like
        least_loaded — a cold cluster routes exactly like least_loaded
        until the rates arrive."""
        cold = [l for l in eligible
                if not (math.isfinite(l.service_s) and l.service_s > 0.0)]
        if cold:
            best = min(cold, key=lambda l: (l.load, l.drive_id))
            return Route(best.drive_id,
                         remote=self._is_remote(shard_id, best.drive_id))
        rated = [l for l in loads if l.accepting
                 and math.isfinite(l.service_s) and l.service_s > 0.0]
        if not rated:
            return self._least_loaded(shard_id, eligible)
        best = min(rated, key=lambda l: (
            l.clock + (l.active + l.pending + 1) * l.service_s,
            l.load, l.drive_id))
        if best.capacity > 0:
            return Route(best.drive_id,
                         remote=self._is_remote(shard_id, best.drive_id))
        return None                # wait for the fastest-finishing drive

    def _data_local(self, shard_id, loads, eligible) -> Optional[Route]:
        if shard_id is None:                 # nothing to be local to
            return self._least_loaded(None, eligible)
        h = self.home(shard_id)
        home = next((l for l in loads if l.drive_id == h), None)
        if home is not None and home.accepting and home.capacity > 0:
            return Route(h, remote=False)
        home_alive = home is not None and home.accepting
        if self.spill or not home_alive:
            # overloaded (or dead) home: serve remotely and pay the shard
            # bytes rather than head-of-line-block the whole queue
            return self._least_loaded(shard_id, eligible)
        return None                          # wait for the home drive


@dataclass
class ClusterStats:
    """Merged per-drive stats + the cluster's own wall-clock/energy track.

    Wall-clock model: drives are independent hardware with no tick barrier
    (the paper's pull protocol is ack-driven, not lockstep), so the engine
    keeps one virtual clock per drive and a cluster tick costs the advance
    of the *leading* clock — work a lagging drive does in the leader's
    shadow adds no wall time, which is what makes rate-proportional load
    splitting measurable (a straggler-bound per-tick max would be invariant
    to the split).  ``cluster_s`` integrates those advances (= the leading
    drive's cumulative busy time, the parallel makespan); the serial sum of
    per-drive busy time (``serial_s``) is what one host-side engine would
    have needed — the pair gives both the scaling curve and the host
    baseline the energy reduction is measured against.

    Energy model (paper Table I): every tick integrates
    ``server_power(n_active_drives) * tick_s`` into ``energy_j``; because
    ``server_power`` is affine in the active-engine count, the accumulated
    energy equals ``server_power(mean_active) * cluster_s`` exactly, and
    ``energy_per_query_mj`` therefore matches
    ``core.energy.energy_per_query_mj(throughput_qps, mean_active)``.
    """
    drives: List = field(default_factory=list)        # per-drive ServeStats
    spill_ledger: TransferLedger = field(default_factory=TransferLedger)
    completed: int = 0         # requests fully served by the cluster
    remote_requests: int = 0   # served off their shard's home drive
    migrated_shards: int = 0   # shards re-placed after a drain/fail
    ticks: int = 0
    cluster_s: float = 0.0     # sum over ticks of max per-drive tick time
    serial_s: float = 0.0      # sum over ticks of SUM of per-drive times
    energy_j: float = 0.0      # integral of server_power(n_active) dt
    _active_dt: float = 0.0    # integral of n_active dt (for mean_active)
    # SLO accounting on the cluster's idle-aware wall clock: one
    # LatencyRecord per tracked request, plus load-shedding tallies
    # (shed_wasted_s = serving time already burned on then-dropped work)
    latency: LatencyStats = field(default_factory=LatencyStats)
    shed_requests: int = 0
    shed_wasted_s: float = 0.0
    # fault tolerance (PR 7): injected-fault and recovery accounting.
    # health mirrors the FailureDetector's per-drive state each tick
    # (healthy/suspect/dead); retries counts fail()-restarts granted;
    # failed_requests are terminal status="failed" finishes (retry budget
    # exhausted or the last drive died); hedge_wasted_s is serving time
    # burned on the losing copy of a hedged dispatch (booked like
    # shed_wasted_s).
    health: List[str] = field(default_factory=list)
    faults_injected: int = 0   # fault events that became active
    auto_failed_drives: int = 0  # drives the detector (not the operator) killed
    retries: int = 0
    failed_requests: int = 0
    hedges: int = 0            # hedged dispatches launched
    hedges_won: int = 0        # hedge copy finished first (or primary died)
    hedges_lost: int = 0       # primary finished first / hedge abandoned
    hedge_wasted_s: float = 0.0
    # tick accounting is += on floats — keep it atomic under the
    # concurrent worker runtime (excluded from repr/compare: a lock is
    # runtime plumbing, not a stat)
    _tick_lock: threading.Lock = field(default_factory=threading.Lock,
                                       repr=False, compare=False)

    def record_tick(self, n_active: int, tick_s: float,
                    tick_serial_s: Optional[float] = None) -> None:
        """One cluster tick: ``tick_s`` is the cluster wall-clock advance
        (the engine passes the leading virtual clock's delta; a lagging
        drive's overlapped work may make it 0), ``tick_serial_s`` the sum
        over stepped drives — what a lone host engine replaying the same
        work would have paid (defaults to ``tick_s``: one drive stepped)."""
        if tick_s < 0:
            raise ValueError("negative tick duration")
        with self._tick_lock:
            self.ticks += 1
            self.cluster_s += tick_s
            self.serial_s += (tick_serial_s if tick_serial_s is not None
                              else tick_s)
            self.energy_j += E.server_power(n_active) * tick_s
            self._active_dt += n_active * tick_s

    # -- merged transfer accounting ------------------------------------------

    @property
    def ledger(self) -> TransferLedger:
        return merge_ledgers([d.ledger for d in self.drives]
                             + [self.spill_ledger])

    @property
    def baseline(self) -> TransferLedger:
        return merge_ledgers([d.baseline for d in self.drives])

    @property
    def spill_bytes(self) -> float:
        """All cluster-level link bytes: per-request remote-serve spills
        plus one-time shard migrations."""
        return self.spill_ledger.link_bytes

    @property
    def shard_migration_bytes(self) -> float:
        """Bytes moved by shard re-placement (charged once per migration,
        instead of a per-request spill forever)."""
        return self.spill_ledger.notes.get("shard migration", 0.0)

    @property
    def link_bytes(self) -> float:
        return self.ledger.link_bytes

    @property
    def host_link_bytes(self) -> float:
        return self.baseline.link_bytes

    @property
    def link_reduction(self) -> float:
        if self.host_link_bytes <= 0:
            return 0.0
        return max(1.0 - self.link_bytes / self.host_link_bytes, 0.0)

    @property
    def kv_reduction(self) -> float:
        base = self.baseline.kv_bytes
        if base <= 0:
            return 0.0
        return max(1.0 - self.ledger.kv_bytes / base, 0.0)

    # -- aggregate serving numbers -------------------------------------------

    @property
    def tokens(self) -> int:
        return sum(d.tokens for d in self.drives)

    @property
    def requests_admitted(self) -> int:
        """Per-drive admissions (a failed-over request counts on each drive
        that admitted it; ``completed`` counts global requests once)."""
        return sum(d.requests for d in self.drives)

    @property
    def busy_s(self) -> float:
        """Jit-only busy time summed over drives (excludes host overhead —
        compare against ``serial_s``, which includes it on both sides)."""
        return sum(d.prefill_s + d.decode_s for d in self.drives)

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / max(self.cluster_s, 1e-9)

    @property
    def throughput_qps(self) -> float:
        return self.completed / max(self.cluster_s, 1e-9)

    # -- energy (paper Table I, live) ----------------------------------------

    @property
    def mean_active(self) -> float:
        """Time-weighted mean number of simultaneously active drives."""
        return self._active_dt / max(self.cluster_s, 1e-9)

    @property
    def energy_per_query_mj(self) -> float:
        """Table I metric from the live integral: wall energy / queries.

        Degenerate runs are reported, not raised: with zero completed
        queries (everything shed, or stats read before the first finish)
        there is no per-query denominator — the metric is 0.0 by
        convention so dashboards render a number; callers gating on it
        should check ``completed > 0`` first.
        """
        if self.completed <= 0:
            return 0.0
        return self.energy_j / self.completed * 1e3

    @property
    def mean_power_w(self) -> float:
        """Time-averaged wall power over the run; 0.0 for a zero-length
        run (no time elapsed means no power draw to average)."""
        if self.cluster_s <= 0:
            return 0.0
        return self.energy_j / self.cluster_s

    @property
    def shed_energy_mj(self) -> float:
        """Energy burned on requests that were then shed: the serving time
        already spent on dropped work, priced at the run's mean wall power.
        0.0 when nothing was shed or no wall time has elapsed (the latter
        means shed work cost no measurable energy yet, not an error)."""
        return self.shed_wasted_s * self.mean_power_w * 1e3

    @property
    def hedge_energy_mj(self) -> float:
        """Energy burned on losing hedge copies, priced like shed work at
        the run's mean wall power (0.0 when nothing was hedged)."""
        return self.hedge_wasted_s * self.mean_power_w * 1e3

    @property
    def wasted_s(self) -> float:
        """All serving time spent on work that was then thrown away —
        shed requests plus losing hedge copies."""
        return self.shed_wasted_s + self.hedge_wasted_s

    @property
    def energy_reduction_vs_host(self) -> float:
        """Energy-per-query saving vs one host-side engine serving the same
        workload serially at ISP-disabled wall power (``server_power(0)``)."""
        if self.completed <= 0 or self.serial_s <= 0 or self.cluster_s <= 0:
            return 0.0
        e_host = E.energy_per_query_mj(self.completed / self.serial_s, 0)
        e_cluster = self.energy_per_query_mj
        if not math.isfinite(e_host) or e_host <= 0:
            return 0.0
        return 1.0 - e_cluster / e_host

    # -- reporting -----------------------------------------------------------

    def metrics(self) -> dict:
        """Flat metric dict — the single source ``summary()`` renders from
        and the telemetry/metrics export publishes, so the printed and
        the exported cluster numbers can never disagree."""
        m = {
            "n_drives": len(self.drives),
            "completed": self.completed,
            "tokens": self.tokens,
            "cluster_s": self.cluster_s,
            "serial_s": self.serial_s,
            "tokens_per_s": self.tokens_per_s,
            "throughput_qps": self.throughput_qps,
            "ticks": self.ticks,
            "mean_active": self.mean_active,
            "energy_j": self.energy_j,
            "energy_per_query_mj": self.energy_per_query_mj,
            "mean_power_w": self.mean_power_w,
            "energy_reduction_vs_host": self.energy_reduction_vs_host,
            "link_bytes": self.link_bytes,
            "host_link_bytes": self.host_link_bytes,
            "link_reduction": self.link_reduction,
            "kv_bytes": self.ledger.kv_bytes,
            "kv_dense_bytes": self.baseline.kv_bytes,
            "kv_reduction": self.kv_reduction,
            "spill_bytes": self.spill_bytes,
            "remote_requests": self.remote_requests,
            "migrated_shards": self.migrated_shards,
            "shard_migration_bytes": self.shard_migration_bytes,
            "shed_requests": self.shed_requests,
            "shed_wasted_s": self.shed_wasted_s,
            "shed_energy_mj": self.shed_energy_mj,
            "faults_injected": self.faults_injected,
            "auto_failed_drives": self.auto_failed_drives,
            "retries": self.retries,
            "failed_requests": self.failed_requests,
            "hedges": self.hedges,
            "hedges_won": self.hedges_won,
            "hedges_lost": self.hedges_lost,
            "hedge_wasted_s": self.hedge_wasted_s,
            "hedge_energy_mj": self.hedge_energy_mj,
        }
        for i, d in enumerate(self.drives):
            m[f"drive.{i}.requests"] = d.requests
            m[f"drive.{i}.tokens"] = d.tokens
            m[f"drive.{i}.busy_s"] = d.prefill_s + d.decode_s
            m[f"drive.{i}.link_reduction"] = d.link_reduction
            m[f"drive.{i}.kv_reduction"] = d.kv_reduction
        return m

    def summary(self) -> str:
        m = self.metrics()
        lines = [
            f"cluster: {m['n_drives']} drives, {m['completed']} requests, "
            f"{m['tokens']} tokens in {m['cluster_s']:.2f}s parallel "
            f"({m['tokens_per_s']:.1f} tok/s; serial "
            f"{m['serial_s']:.2f}s)",
            f"energy: {m['energy_per_query_mj']:.1f} mJ/query at "
            f"{m['mean_active']:.2f} mean active drives "
            f"({m['energy_reduction_vs_host']:.0%} vs host-serial)",
            f"link bytes: {m['link_bytes'] / 1e6:.2f} MB vs host-only "
            f"{m['host_link_bytes'] / 1e6:.2f} MB "
            f"({m['link_reduction']:.0%} never crossed the link; "
            f"{m['spill_bytes'] / 1e6:.3f} MB shard spill, "
            f"{m['remote_requests']} remote requests, "
            f"{m['migrated_shards']} shards migrated "
            f"[{m['shard_migration_bytes'] / 1e6:.3f} MB])",
        ]
        if m["kv_dense_bytes"] > 0:
            lines.append(f"KV bytes touched: {m['kv_bytes'] / 1e6:.2f}"
                         f" MB vs dense {m['kv_dense_bytes'] / 1e6:.2f} MB"
                         f" ({m['kv_reduction']:.0%} fewer KV reads)")
        if self.latency.records:
            lines.append(self.latency.summary())
        if m["shed_requests"]:
            lines.append(f"shed: {m['shed_requests']} requests "
                         f"({m['shed_wasted_s']:.3f}s wasted, "
                         f"{m['shed_energy_mj']:.1f} mJ)")
        if m["faults_injected"] or m["auto_failed_drives"] or self.health:
            state = ", ".join(self.health) if self.health else "untracked"
            lines.append(f"faults: {m['faults_injected']} injected; "
                         f"health [{state}]; "
                         f"{m['auto_failed_drives']} drives auto-failed "
                         f"by the detector")
        if m["retries"] or m["failed_requests"]:
            lines.append(f"recovery: {m['retries']} retries granted, "
                         f"{m['failed_requests']} requests failed "
                         f"permanently")
        if m["hedges"]:
            lines.append(f"hedges: {m['hedges']} launched, "
                         f"{m['hedges_won']} won / {m['hedges_lost']} lost "
                         f"({m['hedge_wasted_s']:.3f}s wasted, "
                         f"{m['hedge_energy_mj']:.1f} mJ)")
        for i in range(len(self.drives)):
            lines.append(
                f"drive[{i}]: {m[f'drive.{i}.requests']} reqs, "
                f"{m[f'drive.{i}.tokens']} tok, "
                f"busy {m[f'drive.{i}.busy_s']:.2f}s, "
                f"link cut {m[f'drive.{i}.link_reduction']:.0%}, "
                f"KV cut {m[f'drive.{i}.kv_reduction']:.0%}")
        return "\n".join(lines)
