"""ISP-sharded vocabulary embedding and cross-entropy.

The vocabulary table is the "drive": it stays sharded over the model axis.
Lookups ship token *indexes* (4 bytes each) to every shard; each shard
gathers the rows it owns (`isp_gather`, zero elsewhere) and only activation
rows are reduced back — the table itself never moves.  The RecSSD-style
baseline (all-gather the table; XLA's default for a plain ``take``) is kept
as ``gather_baseline`` for the paper's host-vs-ISP comparison.

The loss head is the same idea in reverse: per-shard logits + psum'd
logsumexp scalars — the full (tokens × vocab) logits tensor never exists
unsharded, and only per-token scalars cross the link (the paper's "1.2 MB
of output text" effect).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.config import ModelConfig
from repro.kernels import ops as kops
from repro.models.layers import KeyGen, dense_init

VOCAB_PAD = 32   # table rows padded to a multiple of this (e.g. hymba's 32001)


def padded_vocab(vocab_size: int) -> int:
    return -(-vocab_size // VOCAB_PAD) * VOCAB_PAD


def embed_params(cfg: ModelConfig, kg: KeyGen, dtype) -> jax.Array:
    return dense_init(kg(), (padded_vocab(cfg.vocab_size), cfg.d_model), dtype,
                      scale=1.0)


def _sharded(plan) -> bool:
    return plan is not None and plan.mesh is not None and plan.model_axis is not None


def gather_baseline(table, tokens):
    """Host-style path: XLA will all-gather the table shard(s) to serve the
    gather — the 'ship data to compute' baseline from the paper."""
    return jnp.take(table, tokens, axis=0)


def embed_lookup(table, tokens, plan, seq_sharded=None):
    """tokens: (B, S) int32 -> (B, S, D).  ISP path when sharded.

    Preferred plan (sequence-parallel): the *indexes* are all-gathered over
    the vocab shards (4 bytes/token — the paper's protocol verbatim), each
    shard gathers the rows it owns, and a reduce-scatter returns each
    sequence shard its rows.  Wire bytes: tiny + rows·(g-1)/g — half of the
    psum fallback, and the output arrives S-sharded for the SP residual
    stream.  Falls back to psum when S doesn't divide the model axis.
    """
    if not _sharded(plan):
        return gather_baseline(table, tokens)
    tp = plan.model_axis
    fs = plan.fsdp_axis
    b_axes = plan.batch_axes or None
    v_pad = table.shape[0]
    tp_size = plan.plan.axis_size(tp)
    if v_pad % tp_size:
        return gather_baseline(table, tokens)
    table_spec = P(tp, fs) if fs else P(tp)
    if seq_sharded is None:
        seq_sharded = tokens.shape[1] % tp_size == 0 and tp_size > 1
    seq_sharded = seq_sharded and tokens.shape[1] % tp_size == 0

    def gather_local(table_l, tokens_l):
        if fs:
            # FSDP storage gather: the fs axis shards the token batch too, so
            # row *fragments* cannot be all-gathered after lookup (they would
            # mix different tokens).  Restore full row width first.
            table_l = jax.lax.all_gather(table_l, fs, axis=1, tiled=True)
        v_loc = table_l.shape[0]
        off = jax.lax.axis_index(tp) * v_loc
        return kops.isp_gather(table_l, tokens_l, shard_offset=off)

    if seq_sharded:
        def local(table_l, tokens_l):
            toks = jax.lax.all_gather(tokens_l, tp, axis=1, tiled=True)
            rows = gather_local(table_l, toks)
            return jax.lax.psum_scatter(rows, tp, scatter_dimension=1,
                                        tiled=True)

        fn = shard_map(local, mesh=plan.mesh,
                       in_specs=(table_spec, P(b_axes, tp)),
                       out_specs=P(b_axes, tp), check_vma=False)
        return fn(table, tokens)

    def local(table_l, tokens_l):
        rows = gather_local(table_l, tokens_l)
        return jax.lax.psum(rows, tp)          # activation rows, not the table

    fn = shard_map(local, mesh=plan.mesh,
                   in_specs=(table_spec, P(b_axes)),
                   out_specs=P(b_axes), check_vma=False)
    return fn(table, tokens)


# ---------------------------------------------------------------------------
# Sharded cross-entropy
# ---------------------------------------------------------------------------


def _dense_chunked_xent(x, w_head, labels, vocab_size: int, chunk: int):
    """Unsharded-vocab xent without materializing (tokens × vocab) logits:
    token-chunked scan with per-chunk remat (same trick as the sharded path;
    essential for pure-DP layouts where the vocab axis is unsharded)."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    lf = labels.reshape(t)
    c = min(chunk, t)
    pad = (-t) % c
    xf = jnp.pad(xf, ((0, pad), (0, 0)))
    lf = jnp.pad(lf, ((0, pad),), constant_values=0)
    n = xf.shape[0] // c

    @jax.checkpoint
    def body(_, xs):
        x_c, l_c = xs
        logits = jnp.einsum("td,vd->tv", x_c, w_head,
                            preferred_element_type=jnp.float32)
        mask = jnp.arange(logits.shape[-1]) < vocab_size
        logits = jnp.where(mask[None], logits, -1e30)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, l_c[:, None], axis=1)[:, 0]
        return None, lse - ll

    _, losses = jax.lax.scan(body, None, (xf.reshape(n, c, d), lf.reshape(n, c)))
    return losses.reshape(-1)[:t].reshape(b, s)


def _xent_local(w_l, x_l, labels_l, *, tp, fs, vocab_size, chunk):
    """Per-shard chunked xent.  w_l: (V_loc, D[/fs]); x_l: (B_loc,S,D);
    labels_l: (B_loc,S).  Returns per-token loss (B_loc, S) fp32."""
    if fs:
        w_l = jax.lax.all_gather(w_l, fs, axis=1, tiled=True)      # FSDP gather
    v_loc = w_l.shape[0]
    off = jax.lax.axis_index(tp) * v_loc
    b, s, d = x_l.shape
    t = b * s
    xf = x_l.reshape(t, d)
    lf = labels_l.reshape(t)
    c = min(chunk, t)
    pad = (-t) % c
    xf = jnp.pad(xf, ((0, pad), (0, 0)))
    lf = jnp.pad(lf, ((0, pad),), constant_values=0)
    n = xf.shape[0] // c

    @jax.checkpoint
    def body(_, xs):
        x_c, l_c = xs                                              # (c,D), (c,)
        logits = jnp.einsum("td,vd->tv", x_c, w_l,
                            preferred_element_type=jnp.float32)
        lmax = jax.lax.pmax(jax.lax.stop_gradient(logits).max(-1), tp)
        se = jax.lax.psum(jnp.exp(logits - lmax[:, None]).sum(-1), tp)
        loc = l_c - off
        ok = (loc >= 0) & (loc < v_loc)
        ll = jnp.take_along_axis(logits, jnp.clip(loc, 0, v_loc - 1)[:, None],
                                 axis=1)[:, 0]
        lab_logit = jax.lax.psum(jnp.where(ok, ll, 0.0), tp)
        return None, jnp.log(se) + lmax - lab_logit

    _, losses = jax.lax.scan(body, None,
                             (xf.reshape(n, c, d), lf.reshape(n, c)))
    return losses.reshape(-1)[:t].reshape(b, s)


def sharded_xent(x, w_head, labels, plan, cfg: ModelConfig,
                 chunk: int = 4096, seq_sharded=None):
    """Cross-entropy over a vocab-sharded head.  x: (B,S,D); w_head: (V,D);
    labels: (B,S).  Returns per-token loss (B,S) fp32 (caller masks/means).
    """
    if not _sharded(plan) or w_head.shape[0] % plan.plan.axis_size(plan.model_axis):
        return _dense_chunked_xent(x, w_head, labels, cfg.vocab_size, chunk)

    tp = plan.model_axis
    fs = plan.fsdp_axis
    b_axes = plan.batch_axes or None
    w_spec = P(tp, fs) if fs else P(tp)
    # the per-token loss is independent across tokens, so the sequence can
    # stay sharded over the model axis (SP) — each shard handles its slice
    # against its vocab shard, with only scalar psums crossing the link
    tp_size = plan.plan.axis_size(tp)
    if seq_sharded is None:
        seq_sharded = x.shape[1] % tp_size == 0 and tp_size > 1
    seq_sharded = seq_sharded and x.shape[1] % tp_size == 0

    import functools
    local = functools.partial(_xent_local, tp=tp, fs=fs,
                              vocab_size=cfg.vocab_size, chunk=chunk)
    if seq_sharded:
        # every vocab shard must see every token (the psum'd logsumexp spans
        # vocab shards), so gather the hidden slice in, slice the loss out.
        def local_seq(w_l, x_l, labels_l):
            s_loc = x_l.shape[1]
            x_all = jax.lax.all_gather(x_l, tp, axis=1, tiled=True)
            lab_all = jax.lax.all_gather(labels_l, tp, axis=1, tiled=True)
            losses = local(w_l, x_all, lab_all)
            i = jax.lax.axis_index(tp)
            return jax.lax.dynamic_slice_in_dim(losses, i * s_loc, s_loc, axis=1)

        fn = shard_map(local_seq, mesh=plan.mesh,
                       in_specs=(w_spec, P(b_axes, tp), P(b_axes, tp)),
                       out_specs=P(b_axes, tp), check_vma=False)
        return fn(w_head, x, labels)
    fn = shard_map(local, mesh=plan.mesh,
                   in_specs=(w_spec, P(b_axes), P(b_axes)),
                   out_specs=P(b_axes), check_vma=False)
    return fn(w_head, x, labels)


def sharded_logits_last(x_last, w_head, plan, cfg: ModelConfig):
    """Full logits for the last position (decode sampling).  x_last: (B, D).

    Returns (B, V) fp32 — pad columns masked to -inf.
    """
    if not _sharded(plan) or w_head.shape[0] % plan.plan.axis_size(plan.model_axis):
        logits = jnp.einsum("bd,vd->bv", x_last, w_head,
                            preferred_element_type=jnp.float32)
        return logits[:, : cfg.vocab_size]

    tp = plan.model_axis
    fs = plan.fsdp_axis
    b_axes = plan.batch_axes or None
    w_spec = P(tp, fs) if fs else P(tp)

    def local(w_l, x_l):
        if fs:
            w_l = jax.lax.all_gather(w_l, fs, axis=1, tiled=True)
        logits = jnp.einsum("bd,vd->bv", x_l, w_l,
                            preferred_element_type=jnp.float32)
        return logits

    fn = shard_map(local, mesh=plan.mesh,
                   in_specs=(w_spec, P(b_axes)),
                   out_specs=P(b_axes, tp), check_vma=False)
    logits = fn(w_head, x_last)
    v_pad = w_head.shape[0]
    mask = jnp.arange(v_pad) < cfg.vocab_size
    return jnp.where(mask[None], logits, -jnp.inf)


def greedy_sample(x_last, w_head, plan, cfg: ModelConfig):
    """ISP greedy sampling: each vocab shard proposes its local argmax; only
    (value, id) pairs cross the link — the winning *token id* is the entire
    inter-shard payload, the paper's 1.2 MB-of-text effect at its sharpest.
    """
    if not _sharded(plan) or w_head.shape[0] % plan.plan.axis_size(plan.model_axis):
        return jnp.argmax(sharded_logits_last(x_last, w_head, plan, cfg), axis=-1)

    tp = plan.model_axis
    fs = plan.fsdp_axis
    b_axes = plan.batch_axes or None
    w_spec = P(tp, fs) if fs else P(tp)

    def local(w_l, x_l):
        if fs:
            w_l = jax.lax.all_gather(w_l, fs, axis=1, tiled=True)
        v_loc = w_l.shape[0]
        off = jax.lax.axis_index(tp) * v_loc
        logits = jnp.einsum("bd,vd->bv", x_l, w_l,
                            preferred_element_type=jnp.float32)
        ok = (off + jnp.arange(v_loc)) < cfg.vocab_size
        logits = jnp.where(ok[None], logits, -jnp.inf)
        val = logits.max(-1)
        idx = logits.argmax(-1) + off
        best = jax.lax.pmax(val, tp)
        # ship only the winning id: psum of the (masked) local winner
        win = jnp.where(val == best, idx, 0)
        return jax.lax.pmax(win, tp).astype(jnp.int32)

    fn = shard_map(local, mesh=plan.mesh,
                   in_specs=(w_spec, P(b_axes)),
                   out_specs=P(b_axes), check_vma=False)
    return fn(w_head, x_last)
