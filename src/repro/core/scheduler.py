"""The paper's pull-based heterogeneous scheduler (§IV-A), faithfully
reimplemented, plus a discrete-event cluster simulator to evaluate it.

Mechanics reproduced from the paper:
  * pull/ack protocol — a node acks when its batch is done; the ack is the
    request for the next batch;
  * the scheduler thread wakes every 0.2 s to poll acks (we model ack
    pickup latency by quantizing assignment times to the 0.2 s grid);
  * two tunables: ``batch_size`` (items per CSD assignment) and
    ``batch_ratio`` (host batch = ratio × batch_size), with the ratio set
    from measured single-node throughputs (Xeon ≈ 20–30 × ARM A53);
  * per-batch fixed overhead — the reason Fig. 6 shows throughput rising
    with batch size and why tiny batches under-utilize the host.

The same class drives the training runtime's straggler mitigation
(``launch/elastic.py``): observed step times -> new per-worker shares.
"""
from __future__ import annotations

import heapq
import math
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class Node:
    name: str
    rate: float                  # items/s at infinite batch (steady-state)
    batch_overhead: float = 0.0  # fixed seconds per batch (dispatch+wakeup)
    is_host: bool = False

    def batch_seconds(self, n_items: int) -> float:
        return self.batch_overhead + n_items / self.rate

    def effective_rate(self, n_items: int) -> float:
        return n_items / self.batch_seconds(n_items)


@dataclass
class NodeStats:
    items: int = 0
    batches: int = 0
    busy_s: float = 0.0


@dataclass
class SimResult:
    makespan: float
    throughput: float
    per_node: Dict[str, NodeStats]
    total_items: int

    @property
    def host_fraction(self) -> float:
        host = sum(s.items for n, s in self.per_node.items() if n.startswith("host"))
        return host / max(self.total_items, 1)

    @property
    def csd_fraction(self) -> float:
        """Fraction of data processed in storage — the paper's
        'data that never left the drive' number."""
        return 1.0 - self.host_fraction


@dataclass(frozen=True)
class Assignment:
    """One pull event: ``node`` acked and was handed ``n_items`` more."""
    node: Node
    n_items: int
    start: float
    finish: float


@dataclass
class SchedulerState:
    """Mutable event-loop state so callers can drive the scheduler one pull
    at a time (the serve engine's admission loop) instead of to completion."""
    remaining: int
    total_items: int
    stats: Dict[str, NodeStats]
    heap: List[Tuple[float, int, int]] = field(default_factory=list)
    seq: int = 0
    t_end: float = 0.0

    @property
    def done(self) -> bool:
        return self.remaining <= 0

    def result(self) -> SimResult:
        assigned = self.total_items - max(self.remaining, 0)
        return SimResult(makespan=self.t_end,
                         throughput=assigned / max(self.t_end, 1e-9),
                         per_node=self.stats, total_items=assigned)


class PullScheduler:
    """Discrete-event simulation of the MPI pull scheduler."""

    def __init__(self, nodes: List[Node], batch_size: int, batch_ratio: float,
                 poll_interval: float = 0.2):
        self.nodes = nodes
        self.batch_size = batch_size
        self.batch_ratio = batch_ratio
        self.poll = poll_interval

    def node_batch(self, node: Node) -> int:
        if node.is_host:
            return max(1, int(round(self.batch_size * self.batch_ratio)))
        return max(1, self.batch_size)

    def _quantize(self, t: float) -> float:
        """Acks are picked up at the next scheduler wakeup."""
        if self.poll <= 0:
            return t
        return math.ceil(t / self.poll - 1e-9) * self.poll

    def start(self, total_items: int) -> SchedulerState:
        """Begin an incremental run: every node's initial pull is queued."""
        state = SchedulerState(remaining=total_items, total_items=total_items,
                               stats={n.name: NodeStats() for n in self.nodes})
        for i, _ in enumerate(self.nodes):
            heapq.heappush(state.heap, (0.0, state.seq, i))
            state.seq += 1
        return state

    def tick(self, state: SchedulerState) -> Optional[Assignment]:
        """Advance one pull/ack event; ``None`` once all items are assigned.

        ``run()`` is exactly ``start()`` + ``tick()`` until exhaustion, so the
        two APIs agree batch-for-batch (and therefore on makespan).
        """
        if state.remaining <= 0 or not state.heap:
            return None
        ready, _, i = heapq.heappop(state.heap)
        node = self.nodes[i]
        n = min(self.node_batch(node), state.remaining)
        state.remaining -= n
        start = self._quantize(ready)
        dur = node.batch_seconds(n)
        finish = start + dur
        st = state.stats[node.name]
        st.items += n
        st.batches += 1
        st.busy_s += dur
        state.t_end = max(state.t_end, finish)
        if state.remaining > 0:
            heapq.heappush(state.heap, (finish, state.seq, i))
            state.seq += 1
        return Assignment(node=node, n_items=n, start=start, finish=finish)

    def run(self, total_items: int) -> SimResult:
        state = self.start(total_items)
        while self.tick(state) is not None:
            pass
        return state.result()


def optimal_batch_ratio(host_rate: float, csd_rate: float) -> float:
    """The paper's rule: ratio ≈ host/CSD single-node throughput (20–30)."""
    return host_rate / csd_rate


def make_cluster(host_rate: float, csd_rate: float, n_csds: int,
                 host_overhead: float = 0.05, csd_overhead: float = 0.05) -> List[Node]:
    nodes = [Node("host", host_rate, host_overhead, is_host=True)]
    nodes += [Node(f"csd{i:02d}", csd_rate, csd_overhead) for i in range(n_csds)]
    return nodes


# ---------------------------------------------------------------------------
# Straggler mitigation for the training runtime (batch-ratio rule applied to
# observed per-worker step times)
# ---------------------------------------------------------------------------


def split_block_service(block_s: float, per_step_items: List[int]) -> List[float]:
    """Attribute one fused K-step block's wall time across its inner steps,
    proportional to the items each step actually served.

    The serve engine's device-resident decode loop observes one wall-clock
    sample per *block*; feeding that lump to ``rebalance_shares`` would make
    the batch-ratio refit see K-step-quantized service times.  Splitting it
    per step (weighted by live slots, since a step serving fewer slots did
    proportionally less work) restores the bounded per-step samples the
    K=1 loop produced.  Returns one duration per step; they sum to
    ``block_s`` exactly (idle steps get an equal share if nothing ran).
    """
    total = sum(per_step_items)
    if total <= 0:
        n = max(len(per_step_items), 1)
        return [block_s / n] * len(per_step_items)
    return [block_s * items / total for items in per_step_items]


class ClusterAdmission:
    """Cluster-wide pull scheduler: learn each drive's service rate online
    and size per-drive pull quotas the way §IV-A sizes host-vs-CSD batches.

    The paper's pull protocol lets heterogeneous nodes cooperate without
    stragglers because each node's batch is sized to its *measured* rate.
    PR 4's cluster kept one private ``AdmissionController`` per drive and a
    rate-blind router, so a slow drive was handed the same share as a fast
    one.  This controller closes that gap at the cluster level:

      * ``observe()`` feeds one engine tick per drive — the tick's wall
        time is spread over its inner decode steps with
        ``split_block_service`` (the same attribution the single-engine
        scheduler uses for fused K-blocks), and each step's per-item
        service time updates an EWMA;
      * ``rate()`` is the learned items/s estimate (NaN until observed);
      * ``quotas()`` refits per-drive in-flight quotas with
        ``rebalance_shares`` — share ∝ measured rate, blended against the
        current shares, exact-sum, and protected by the cold-start guard
        (an unobserved drive keeps the current proportions instead of
        being read as infinitely fast).
    """

    def __init__(self, n_drives: int, alpha: float = 0.15,
                 smoothing: float = 0.5):
        if n_drives < 1:
            raise ValueError("need at least one drive")
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.n_drives = n_drives
        self.alpha = alpha
        self.smoothing = smoothing
        # the EWMA / share / quarantine state is read-modify-write from
        # whoever absorbs drive ticks — serialize it so the concurrent
        # worker runtime can't interleave half-applied updates (RLock:
        # quotas() and rates() re-enter through rate())
        self._lock = threading.RLock()
        # EWMA of per-item service seconds; NaN = never observed
        self._ewma: Dict[int, float] = {d: math.nan for d in range(n_drives)}
        self.samples: Dict[int, int] = {d: 0 for d in range(n_drives)}
        self._shares: Dict[int, int] = {}
        # drives the failure detector currently suspects: their ticks are
        # untrustworthy (a half-stalled drive reports garbage service
        # times), so they neither update the EWMA nor take part in the
        # share refit until released
        self._quarantined: set = set()

    def observe(self, drive: int, block_s: float,
                per_step_items: List[int]) -> None:
        """One engine tick: ``block_s`` of serving wall time (compile time
        already excluded by the caller) over ``per_step_items`` items per
        inner step."""
        if drive not in self._ewma:
            raise KeyError(f"unknown drive {drive}")
        with self._lock:
            if drive in self._quarantined:
                return
            if block_s <= 0.0 or not math.isfinite(block_s):
                return
            for dur, items in zip(split_block_service(block_s,
                                                      per_step_items),
                                  per_step_items):
                if items <= 0 or dur <= 0.0:
                    continue
                per_item = dur / items
                prev = self._ewma[drive]
                self._ewma[drive] = per_item if not math.isfinite(prev) \
                    else self.alpha * per_item + (1.0 - self.alpha) * prev
                self.samples[drive] += 1

    def quarantine(self, drive: int) -> None:
        """Stop trusting a SUSPECT drive's ticks: its observations are
        dropped and ``quotas()`` refits shares over the others only — a
        stalled drive must not poison the learned rates or keep a share
        it cannot serve."""
        if drive not in self._ewma:
            raise KeyError(f"unknown drive {drive}")
        with self._lock:
            self._quarantined.add(drive)

    def unquarantine(self, drive: int) -> None:
        """A recovered drive's ticks count again (its pre-quarantine EWMA
        is kept — the hardware is the same, the stall was transient)."""
        with self._lock:
            self._quarantined.discard(drive)

    @property
    def quarantined(self) -> List[int]:
        return sorted(self._quarantined)

    def rate(self, drive: int) -> float:
        """Learned service rate in items/s; NaN until the drive has been
        observed (callers must treat NaN as "no estimate yet")."""
        with self._lock:
            t = self._ewma[drive]
        return 1.0 / t if (math.isfinite(t) and t > 0.0) else math.nan

    def rates(self) -> List[float]:
        return [self.rate(d) for d in range(self.n_drives)]

    def quotas(self, total: int, live: List[int]) -> Dict[int, int]:
        """Per-drive pull quotas over the ``live`` drives, summing exactly
        to ``total`` (the cluster's concurrency budget).

        ``rebalance_shares`` wants per-worker *step times for their current
        share*; feeding it ``share * ewma_per_item`` makes its throughput
        estimate ``share / t = 1/ewma`` — i.e. new share ∝ measured rate,
        which is the paper's batch-ratio rule applied across drives.  The
        cold-start guard inside ``rebalance_shares`` keeps the current
        proportions while any live drive is still unobserved.
        """
        if not live:
            return {}
        live = sorted(set(live))
        with self._lock:
            # quarantined drives are refit around, not into — unless EVERY
            # live drive is quarantined, where excluding them all would
            # leave nothing to serve at all (better a suspect share than
            # none)
            trusted = [d for d in live if d not in self._quarantined]
            if trusted:
                live = trusted
            if total < len(live):
                raise ValueError(f"quota total {total} cannot cover "
                                 f"{len(live)} drives")
            cur = {d: self._shares.get(d, 0) for d in live}
            if sum(cur.values()) <= 0:
                base, extra = divmod(total, len(live))
                cur = {d: base + (1 if i < extra else 0)
                       for i, d in enumerate(live)}
            step_times = {d: (cur[d] * self._ewma[d]
                              if math.isfinite(self._ewma[d]) else math.nan)
                          for d in live}
            new = rebalance_shares(step_times, cur, total,
                                   smoothing=self.smoothing)
            self._shares = dict(new)
            return new


def rebalance_shares(step_times: Dict[str, float], current_shares: Dict[str, int],
                     total: int, smoothing: float = 0.5,
                     min_share: int = 1) -> Dict[str, int]:
    """New per-worker microbatch shares ∝ observed throughput.

    throughput_w = share_w / step_time_w; new share ∝ throughput (the paper's
    batch-ratio rule).  ``smoothing`` blends old and new shares to avoid
    oscillation.  Shares sum exactly to ``total``.
    """
    if total < min_share * len(step_times):
        raise ValueError(
            f"cannot split {total} items across {len(step_times)} workers "
            f"with min_share={min_share}")
    # Cold-start guard: a worker that has served nothing yet reports a
    # zero/NaN service time (a cluster replica before its first observe()).
    # 1/t would read that as infinite throughput and hand it everything —
    # keep the current *proportions* (settled to the exact total below, so
    # the sum contract holds) until every worker has a real measurement.
    if any(not math.isfinite(t) or t <= 0.0 for t in step_times.values()):
        z = sum(current_shares[w] for w in step_times)
        if z <= 0:
            blended = {w: total / len(step_times) for w in step_times}
        else:
            blended = {w: total * current_shares[w] / z for w in step_times}
    else:
        tput = {w: current_shares[w] / max(t, 1e-9)
                for w, t in step_times.items()}
        z = sum(tput.values())
        raw = {w: total * tput[w] / z for w in tput}
        blended = {w: smoothing * raw[w] + (1 - smoothing) * current_shares[w]
                   for w in raw}
    # round, then resolve the drift exactly: increments go to the workers the
    # rounding short-changed most; decrements come from the workers rounding
    # (or the min_share floor) over-paid most, never dipping below min_share.
    shares = {w: max(min_share, int(v)) for w, v in blended.items()}
    drift = total - sum(shares.values())
    while drift > 0:
        w = max(shares, key=lambda w: (blended[w] - shares[w], w))
        shares[w] += 1
        drift -= 1
    while drift < 0:
        eligible = [w for w in shares if shares[w] > min_share]
        # guaranteed non-empty: sum > total >= n * min_share
        w = max(eligible, key=lambda w: (shares[w] - blended[w], w))
        shares[w] -= 1
        drift += 1
    assert sum(shares.values()) == total
    return shares
