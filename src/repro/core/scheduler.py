"""The paper's pull-based heterogeneous scheduler (§IV-A), faithfully
reimplemented, plus a discrete-event cluster simulator to evaluate it.

Mechanics reproduced from the paper:
  * pull/ack protocol — a node acks when its batch is done; the ack is the
    request for the next batch;
  * the scheduler thread wakes every 0.2 s to poll acks (we model ack
    pickup latency by quantizing assignment times to the 0.2 s grid);
  * two tunables: ``batch_size`` (items per CSD assignment) and
    ``batch_ratio`` (host batch = ratio × batch_size), with the ratio set
    from measured single-node throughputs (Xeon ≈ 20–30 × ARM A53);
  * per-batch fixed overhead — the reason Fig. 6 shows throughput rising
    with batch size and why tiny batches under-utilize the host.

The same class drives the training runtime's straggler mitigation
(``launch/elastic.py``): observed step times -> new per-worker shares.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class Node:
    name: str
    rate: float                  # items/s at infinite batch (steady-state)
    batch_overhead: float = 0.0  # fixed seconds per batch (dispatch+wakeup)
    is_host: bool = False

    def batch_seconds(self, n_items: int) -> float:
        return self.batch_overhead + n_items / self.rate

    def effective_rate(self, n_items: int) -> float:
        return n_items / self.batch_seconds(n_items)


@dataclass
class NodeStats:
    items: int = 0
    batches: int = 0
    busy_s: float = 0.0


@dataclass
class SimResult:
    makespan: float
    throughput: float
    per_node: Dict[str, NodeStats]
    total_items: int

    @property
    def host_fraction(self) -> float:
        host = sum(s.items for n, s in self.per_node.items() if n.startswith("host"))
        return host / max(self.total_items, 1)

    @property
    def csd_fraction(self) -> float:
        """Fraction of data processed in storage — the paper's
        'data that never left the drive' number."""
        return 1.0 - self.host_fraction


class PullScheduler:
    """Discrete-event simulation of the MPI pull scheduler."""

    def __init__(self, nodes: List[Node], batch_size: int, batch_ratio: float,
                 poll_interval: float = 0.2):
        self.nodes = nodes
        self.batch_size = batch_size
        self.batch_ratio = batch_ratio
        self.poll = poll_interval

    def node_batch(self, node: Node) -> int:
        if node.is_host:
            return max(1, int(round(self.batch_size * self.batch_ratio)))
        return max(1, self.batch_size)

    def _quantize(self, t: float) -> float:
        """Acks are picked up at the next scheduler wakeup."""
        if self.poll <= 0:
            return t
        return math.ceil(t / self.poll - 1e-9) * self.poll

    def run(self, total_items: int) -> SimResult:
        remaining = total_items
        stats = {n.name: NodeStats() for n in self.nodes}
        # (ready_time, seq, node_index) — seq breaks ties deterministically
        heap: List[Tuple[float, int, int]] = []
        seq = 0
        for i, _ in enumerate(self.nodes):
            heapq.heappush(heap, (0.0, seq, i))
            seq += 1
        t_end = 0.0
        while remaining > 0 and heap:
            ready, _, i = heapq.heappop(heap)
            node = self.nodes[i]
            n = min(self.node_batch(node), remaining)
            remaining -= n
            start = self._quantize(ready)
            dur = node.batch_seconds(n)
            finish = start + dur
            st = stats[node.name]
            st.items += n
            st.batches += 1
            st.busy_s += dur
            t_end = max(t_end, finish)
            if remaining > 0:
                heapq.heappush(heap, (finish, seq, i))
                seq += 1
        return SimResult(makespan=t_end, throughput=total_items / max(t_end, 1e-9),
                         per_node=stats, total_items=total_items)


def optimal_batch_ratio(host_rate: float, csd_rate: float) -> float:
    """The paper's rule: ratio ≈ host/CSD single-node throughput (20–30)."""
    return host_rate / csd_rate


def make_cluster(host_rate: float, csd_rate: float, n_csds: int,
                 host_overhead: float = 0.05, csd_overhead: float = 0.05) -> List[Node]:
    nodes = [Node("host", host_rate, host_overhead, is_host=True)]
    nodes += [Node(f"csd{i:02d}", csd_rate, csd_overhead) for i in range(n_csds)]
    return nodes


# ---------------------------------------------------------------------------
# Straggler mitigation for the training runtime (batch-ratio rule applied to
# observed per-worker step times)
# ---------------------------------------------------------------------------


def rebalance_shares(step_times: Dict[str, float], current_shares: Dict[str, int],
                     total: int, smoothing: float = 0.5,
                     min_share: int = 1) -> Dict[str, int]:
    """New per-worker microbatch shares ∝ observed throughput.

    throughput_w = share_w / step_time_w; new share ∝ throughput (the paper's
    batch-ratio rule).  ``smoothing`` blends old and new shares to avoid
    oscillation.  Shares sum exactly to ``total``.
    """
    tput = {w: current_shares[w] / max(t, 1e-9) for w, t in step_times.items()}
    z = sum(tput.values())
    raw = {w: total * tput[w] / z for w in tput}
    blended = {w: smoothing * raw[w] + (1 - smoothing) * current_shares[w] for w in raw}
    # round, preserving the total
    shares = {w: max(min_share, int(v)) for w, v in blended.items()}
    drift = total - sum(shares.values())
    order = sorted(blended, key=lambda w: blended[w] - int(blended[w]), reverse=True)
    i = 0
    while drift != 0 and order:
        w = order[i % len(order)]
        step = 1 if drift > 0 else -1
        if shares[w] + step >= min_share:
            shares[w] += step
            drift -= step
        i += 1
        if i > 10 * len(order):
            break
    return shares
