"""Paged KV-cache allocation: fixed-size token blocks + per-slot page tables.

The paper's in-storage designs win by matching on-device data layout to the
access pattern instead of padding to worst case (ZCSD makes the same
argument for flash block allocation).  Applied to LM serving: instead of
one dense ``max_len`` KV strip per batch slot — memory and decode reads
scale with ``num_slots * max_len`` no matter how many tokens are live —
the KV cache becomes a pool of fixed-size *pages* (``page_size`` token
rows each) handed out by a free-list allocator:

  * a slot's logical position ``p`` lives in logical page ``p // page_size``
    at row ``p % page_size``;
  * a per-slot page table maps logical pages to physical pool pages
    (-1 = not allocated);
  * prefill allocates ``pages_for(prompt_len)`` pages, each decode step
    allocates at most one page when the write position crosses a page
    boundary, and EOS/eviction frees the slot's pages back to the pool in
    the same engine step — KV memory tracks *live tokens*, not capacity.

The device-side pool layout (one pool per layer group, see
``models.attention.init_paged_gqa_cache``) reserves one extra *scratch*
page at index ``num_pages``: writes for inactive slots (page table row -1)
are routed there so the decode step stays a fixed-shape jitted program;
scratch contents are never read back (validity is derived from the page
table and the slot's current position).

Host-side allocator state is tiny (ints), device state is the pool; the
two meet in the engine (``train.serve_loop``), which pushes the page table
into the cache pytree whenever it changes.
"""
from __future__ import annotations

import heapq
from typing import List, Sequence

import jax.numpy as jnp


def pages_for(n_tokens: int, page_size: int) -> int:
    """Number of pages needed to hold ``n_tokens`` token rows."""
    if n_tokens <= 0:
        return 0
    return -(-n_tokens // page_size)


class KVPagesExhausted(RuntimeError):
    """The pool has no free page left for a required allocation."""


class PageAllocator:
    """Free-list allocator over a fixed pool of ``num_pages`` pages.

    Lowest-id-first allocation (a heap) keeps the in-use set compacted
    toward the bottom of the pool, so ``peak_pages`` — the high-water mark
    of *live* pages — is the pool size the workload actually needed; the
    benchmark reports ``peak_pages * page_bytes`` as peak KV memory.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError("num_pages and page_size must be positive")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages))
        heapq.heapify(self._free)
        self._in_use: set = set()
        self.peak_pages = 0

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_in_use(self) -> int:
        return len(self._in_use)

    def alloc(self, n: int = 1) -> List[int]:
        """Take ``n`` pages off the free list; raises ``KVPagesExhausted``
        (allocating nothing) when fewer than ``n`` pages are free."""
        if n < 0:
            raise ValueError("cannot allocate a negative page count")
        if n > len(self._free):
            raise KVPagesExhausted(
                f"need {n} pages, only {len(self._free)} of "
                f"{self.num_pages} free")
        out = [heapq.heappop(self._free) for _ in range(n)]
        self._in_use.update(out)
        self.peak_pages = max(self.peak_pages, len(self._in_use))
        return out

    def free(self, pages: Sequence[int]) -> None:
        """Return pages to the free list; double-free / foreign ids raise."""
        for p in pages:
            if p not in self._in_use:
                raise ValueError(f"page {p} is not allocated (double free?)")
        for p in pages:
            self._in_use.discard(p)
            heapq.heappush(self._free, p)

    def check_balanced(self) -> None:
        """Assert every page is back on the free list (tests: no leaks)."""
        if self._in_use or len(self._free) != self.num_pages:
            raise AssertionError(
                f"free-list unbalanced: {len(self._in_use)} pages still "
                f"in use, {len(self._free)}/{self.num_pages} free")


# ---------------------------------------------------------------------------
# Device-side helpers (jnp) — the reference/fallback view of a paged pool
# ---------------------------------------------------------------------------


def gather_pages(pool, pages):
    """Materialize each slot's logical KV span from the pool.

    pool:  (P(+scratch), page_size, ...) physical pages;
    pages: (B, max_pages) int32 physical ids, -1 = unallocated.
    Returns (B, max_pages * page_size, ...) — rows of unallocated pages
    contain pool garbage and MUST be masked via ``pages_kpos``.
    """
    safe = jnp.maximum(pages, 0)
    g = jnp.take(pool, safe, axis=0)            # (B, maxp, ps, ...)
    b, maxp, ps = g.shape[0], g.shape[1], g.shape[2]
    return g.reshape((b, maxp * ps) + g.shape[3:])


def pages_kpos(pages, page_size: int):
    """Per-slot kpos track for the gathered view: logical position where the
    page is allocated, -1 elsewhere.  pages: (B, maxp) -> (B, maxp * ps)."""
    b, maxp = pages.shape
    pos = jnp.arange(maxp * page_size, dtype=jnp.int32)
    alloc = jnp.repeat(pages >= 0, page_size, axis=1)
    return jnp.where(alloc, pos[None, :], -1)


def scatter_rows(pool, pages, positions, rows):
    """Scatter token rows into the pool at their logical positions.

    pool:      (P(+scratch), page_size, ...) physical pages;
    pages:     (B, max_pages) int32 per-slot page tables (-1 = unallocated);
    positions: (B, C) int32 logical positions, -1 = pad row;
    rows:      (B, C, ...) the rows to write.

    Rows whose position is -1 or whose logical page is unallocated land in
    the scratch page (index P), which is never read back — the chunked
    prefill path stays a fixed-shape jitted program across ragged chunks.
    """
    ps = pool.shape[1]
    scratch = pool.shape[0] - 1
    safe = jnp.maximum(positions, 0)
    page = jnp.take_along_axis(pages, safe // ps, axis=1)       # (B, C)
    page = jnp.where((positions < 0) | (page < 0), scratch, page)
    return pool.at[page, safe % ps].set(rows.astype(pool.dtype))


def pages_to_strips(pools, pages, page_size: int):
    """Paged pool(s) -> dense per-slot strips + kpos (the strip-layout view).

    ``pools`` is a tuple of pool arrays sharing one page table.  Used by the
    sequence-sharded decode fallback, which reuses the strip attention path
    on the gathered view.
    """
    strips = tuple(gather_pages(p, pages) for p in pools)
    return strips + (pages_kpos(pages, page_size),)
