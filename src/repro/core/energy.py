"""Energy model — reproduces the paper's Table I and generalizes to TPU.

Paper measurements (HPM-100A wall meter, AIC FB128-LX, 36 CSDs):
  idle (no drives)          167 W
  idle (36 CSDs)            405 W   -> 6.6 W per CSD
  load, ISP disabled        482 W
  load, all 36 ISP engines  492 W   -> 0.28 W marginal per active engine

Table I's energy-per-query is exactly P_load / throughput — validated in
tests against all six published numbers (5021/1662, 832/327, 51/23 mJ).

For the TPU framework we provide an analytic per-step energy estimate from
the roofline terms (DESIGN.md §2 assumption change: modeled, not metered).
"""
from __future__ import annotations

from dataclasses import dataclass

# --- paper's server constants ----------------------------------------------
SERVER_IDLE_W = 167.0
SERVER_IDLE_36CSD_W = 405.0
CSD_IDLE_W = (SERVER_IDLE_36CSD_W - SERVER_IDLE_W) / 36.0   # 6.61 W
LOAD_STORAGE_ONLY_W = 482.0
LOAD_ALL_ISP_W = 492.0
ISP_MARGINAL_W = (LOAD_ALL_ISP_W - LOAD_STORAGE_ONLY_W) / 36.0  # 0.28 W


def server_power(n_isp_active: int = 0) -> float:
    """Whole-server wall power under load with n active ISP engines."""
    return LOAD_STORAGE_ONLY_W + ISP_MARGINAL_W * n_isp_active


def energy_per_query_mj(throughput_qps: float, n_isp_active: int = 0) -> float:
    """Table I metric: wall power / throughput, in millijoules."""
    return server_power(n_isp_active) / max(throughput_qps, 1e-9) * 1e3


def energy_saving(host_only_qps: float, isp_qps: float, n_isp: int = 36) -> float:
    """Fractional energy-per-query saving of the ISP configuration."""
    e_host = energy_per_query_mj(host_only_qps, 0)
    e_isp = energy_per_query_mj(isp_qps, n_isp)
    return 1.0 - e_isp / e_host


# --- TPU v5e analytic model --------------------------------------------------
# Public figures: ~200 W peak per v5e chip.  Decomposition constants chosen so
# peak-FLOP + peak-HBM activity ≈ chip TDP; link energy per ICI byte from
# typical SerDes ~10 pJ/bit figures.
CHIP_IDLE_W = 60.0
PJ_PER_FLOP = 0.45
PJ_PER_HBM_BYTE = 45.0
PJ_PER_LINK_BYTE = 90.0


@dataclass
class TpuStepEnergy:
    compute_j: float
    hbm_j: float
    link_j: float
    idle_j: float

    @property
    def total_j(self) -> float:
        return self.compute_j + self.hbm_j + self.link_j + self.idle_j


def tpu_step_energy(dot_flops: float, hbm_bytes: float, link_bytes: float,
                    step_s: float, chips: int = 1) -> TpuStepEnergy:
    """Per-device energy for one step (multiply by chips for fleet energy)."""
    return TpuStepEnergy(
        compute_j=dot_flops * PJ_PER_FLOP * 1e-12,
        hbm_j=hbm_bytes * PJ_PER_HBM_BYTE * 1e-12,
        link_j=link_bytes * PJ_PER_LINK_BYTE * 1e-12,
        idle_j=CHIP_IDLE_W * step_s,
    )
