"""Data-movement accounting: the paper's "68% of the data never left the
storage" analysis, generalized.

An execution plan moves bytes across three tiers (paper / TPU analogue):
  link    — host↔drive PCIe / inter-chip ICI+DCN     (slow, expensive)
  local   — drive-internal flash↔DRAM / HBM↔VMEM     (fast)
  output  — results shipped back (tiny)

``TransferLedger`` tallies them; plan helpers compute ledgers for the
host-style baseline vs the ISP layout of each core primitive, which the
benchmarks then report next to the paper's numbers.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict


@dataclass
class TransferLedger:
    link_bytes: float = 0.0
    local_bytes: float = 0.0
    output_bytes: float = 0.0
    # KV-cache rows the decode step actually walked (device-local traffic,
    # accounted separately so the paged-vs-dense reduction is visible next
    # to the link reduction; see serve_loop._account_kv_step)
    kv_bytes: float = 0.0
    notes: Dict[str, float] = field(default_factory=dict)
    # float += read-modify-writes: atomic under the concurrent cluster
    # runtime (excluded from repr/compare — plumbing, not accounting)
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def add(self, tier: str, n: float, note: str = "") -> None:
        with self._lock:
            if tier == "link":
                self.link_bytes += n
            elif tier == "local":
                self.local_bytes += n
            elif tier == "kv":
                self.kv_bytes += n
            else:
                self.output_bytes += n
            if note:
                self.notes[note] = self.notes.get(note, 0.0) + n

    @property
    def total_moved(self) -> float:
        return self.link_bytes + self.output_bytes

    def reduction_vs(self, baseline: "TransferLedger") -> float:
        """Fractional link-traffic reduction vs a baseline plan."""
        if baseline.total_moved == 0:
            return 0.0
        return 1.0 - self.total_moved / baseline.total_moved


def workload_split_ledger(dataset_bytes: float, csd_fraction: float,
                          output_bytes: float) -> TransferLedger:
    """The paper's top-level accounting: the host-processed fraction crosses
    the link; the CSD-processed fraction stays put; outputs come back."""
    led = TransferLedger()
    led.add("link", dataset_bytes * (1.0 - csd_fraction), "host input")
    led.add("local", dataset_bytes * csd_fraction, "in-storage input")
    led.add("output", output_bytes, "results")
    return led


def host_only_ledger(dataset_bytes: float, output_bytes: float) -> TransferLedger:
    led = TransferLedger()
    led.add("link", dataset_bytes, "host input")
    led.add("output", output_bytes, "results")
    return led


# -- ISP primitive plans (TPU mapping) --------------------------------------


def embedding_plans(num_lookups: int, vocab: int, d_model: int,
                    bytes_per_el: int = 2, tp: int = 16):
    """(baseline, isp) ledgers for a vocab-sharded embedding lookup.

    baseline = all-gather the table shards (XLA default for plain take);
    isp      = ship indexes, psum result rows.
    """
    table = vocab * d_model * bytes_per_el
    rows = num_lookups * d_model * bytes_per_el
    base = TransferLedger()
    base.add("link", table * (tp - 1) / tp, "all-gather table")
    base.add("local", rows, "gather")
    isp = TransferLedger()
    isp.add("link", num_lookups * 4, "indexes")
    isp.add("link", 2 * rows * (tp - 1) / tp, "psum rows")
    isp.add("local", rows, "gather")
    return base, isp


def decode_attention_plans(batch: int, heads: int, head_dim: int, seq: int,
                           kv_heads: int, bytes_per_el: int = 2, shards: int = 16):
    """(baseline, isp) ledgers for one decode step's attention.

    baseline = gather the KV cache to the query's shard;
    isp      = broadcast q, psum (acc,l,m) partials.
    """
    kv = 2 * batch * seq * kv_heads * head_dim * bytes_per_el
    base = TransferLedger()
    base.add("link", kv * (shards - 1) / shards, "gather KV")
    isp = TransferLedger()
    isp.add("link", batch * heads * head_dim * bytes_per_el * shards, "broadcast q")
    isp.add("link", 2 * batch * heads * (head_dim + 2) * 4 * (shards - 1) / shards,
            "psum partials")
    isp.add("local", kv / shards, "local KV read")
    return base, isp
