"""ISP decode attention: flash-decoding over sequence-sharded KV caches.

The paper's core move — ship the small thing (here: the per-step query
vector) to where the big thing lives (the KV span resident on each shard),
compute locally, and return only tiny partials:

    per shard and head:  (acc: d_v floats, l: 1 float, m: 1 float)

The KV cache bytes never cross a link.  The combine is the standard
numerically-stable flash-decoding merge, done with pmax/psum over the
sequence-sharding axes.  This also makes decode sharding independent of
head-count divisibility (any GQA layout works on any mesh).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.kernels import ops as kops
from repro.kernels import ref


def _per_slot_layout(kpos, cur_pos, b_axes, s_axes):
    """Shard specs + normalized cur argument for the two kpos layouts:
    shared (S,) track with scalar cur_pos, or per-slot (B,S) tracks with
    (B,) cur_pos (continuous batching)."""
    per_slot = kpos.ndim == 2
    kpos_spec = P(b_axes, s_axes) if per_slot else P(s_axes)
    cur_spec = P(b_axes) if per_slot else P()
    cur = cur_pos.astype(jnp.int32) if per_slot \
        else cur_pos[None].astype(jnp.int32)
    return per_slot, kpos_spec, cur_spec, cur


def _combine(acc, l, m, axes):
    """Stable merge of per-shard partials via collectives over ``axes``."""
    m_glob = m
    for ax in axes:
        m_glob = jax.lax.pmax(m_glob, ax)
    w = jnp.exp(m - m_glob)
    acc = jax.lax.psum(acc * w[..., None], axes)
    l = jax.lax.psum(l * w, axes)
    l = jnp.where(l == 0, 1.0, l)
    return acc / l[..., None]


def decode_attention(q, k_cache, v_cache, kpos, cur_pos, *, window: Optional[int],
                     plan, scale: Optional[float] = None):
    """q: (B, H, dh); k/v_cache: (B, S, Hkv, dh); kpos: (S,); cur_pos scalar.

    Per-slot serving layout: kpos (B, S) with cur_pos (B,) — each batch slot
    masks and advances on its own timeline (continuous batching).

    Returns (B, H, dhv).  ``plan`` is a ShardingRecipe; with a mesh and
    non-empty seq_axes the KV span stays sharded and only partials move.
    """
    if plan is None or plan.mesh is None or not plan.seq_axes:
        acc, l, m = kops.decode_partial(q, k_cache, v_cache, kpos, cur_pos,
                                        window=window, scale=scale)
        return ref.combine_partials(acc[None], l[None], m[None], axis=0).astype(q.dtype)

    b_axes = plan.batch_axes or None
    s_axes = plan.seq_axes
    per_slot, kpos_spec, cur_spec, cur = _per_slot_layout(
        kpos, cur_pos, b_axes, s_axes)

    def local(q_l, k_l, v_l, kpos_l, cur):
        acc, l, m = kops.decode_partial(q_l, k_l, v_l, kpos_l,
                                        cur if per_slot else cur[0],
                                        window=window, scale=scale)
        return _combine(acc, l, m, s_axes).astype(q_l.dtype)

    fn = shard_map(
        local, mesh=plan.mesh,
        in_specs=(P(b_axes), P(b_axes, s_axes), P(b_axes, s_axes), kpos_spec,
                  cur_spec),
        out_specs=P(b_axes),
        check_vma=False)
    return fn(q, k_cache, v_cache, kpos, cur)


def paged_decode_attention(q, kpool, vpool, pages, cur_pos, *,
                           window: Optional[int], plan,
                           scale: Optional[float] = None):
    """Decode attention over a paged KV pool (the serve engine's layout).

    q: (B, H, dh); kpool/vpool: (P(+scratch), page_size, Hkv, dh); pages:
    (B, maxp) int32 per-slot page tables; cur_pos: (B,) int32 per-slot
    positions.  Returns (B, H, dhv).

    Local execution runs the fused ragged kernel (Pallas on TPU, jnp
    reference elsewhere) — one pass over exactly the pages each slot owns.
    With a sequence-sharded mesh the pool is gathered into the strip view
    and delegated to the sharded strip path (``decode_attention``): the
    page table is replicated host state, so sharding the *pool* would
    shard pages, not positions — the strip view keeps the ISP partial
    combine exact while paged allocation still governs memory.
    """
    if plan is None or plan.mesh is None or not plan.seq_axes:
        acc, l, m = kops.paged_decode_partial(q, kpool, vpool, pages, cur_pos,
                                              window=window, scale=scale)
        return ref.combine_partials(acc[None], l[None], m[None],
                                    axis=0).astype(q.dtype)

    from repro.core import kv_pages
    ps = kpool.shape[1]
    k, v, kpos = kv_pages.pages_to_strips((kpool, vpool), pages, ps)
    cur = jnp.asarray(cur_pos, jnp.int32)
    if cur.ndim == 0:
        cur = jnp.broadcast_to(cur, (q.shape[0],))
    return decode_attention(q, k, v, kpos, cur, window=window, plan=plan,
                            scale=scale)


def chunk_prefill_attention(q, kpool, vpool, pages, qpos, *, plan,
                            scale: Optional[float] = None):
    """Chunked-prefill attention over a paged KV pool.

    q: (B, C, H, dh) chunk queries; kpool/vpool: (P(+scratch), page_size,
    Hkv, dh); pages: (B, maxp) int32 page tables; qpos: (B, C) int32 query
    positions (-1 = pad row).  The chunk's rows are already scattered into
    the pool, so gathering the slot's pages into the strip view gives
    prefix + chunk in one span; ``kops.chunk_prefill_attention`` masks it
    causally per row.  Like ``paged_decode_attention``, a sequence-sharded
    mesh would shard pages rather than positions, so the gathered view is
    also what a sharded caller gets (chunk prefill is admission-path work —
    one chunk per engine tick — not the per-token hot loop).
    """
    from repro.core import kv_pages
    ps = kpool.shape[1]
    k, v, kpos = kv_pages.pages_to_strips((kpool, vpool), pages, ps)
    return kops.chunk_prefill_attention(q, k, v, kpos, qpos, scale=scale)


def mla_decode_attention(q_nope, q_rope, ckv, krope, kpos, cur_pos, wk_b, *,
                         scale: float, plan):
    """Absorbed-MLA decode over the compressed cache.

    q_nope: (B,H,n); q_rope: (B,H,r); ckv: (B,S,R); krope: (B,S,r);
    wk_b: (R,H,n).  Returns probability-weighted ckv context (B,H,R) fp32 —
    the caller applies wv_b.  The 576-float/token compressed cache is the
    only resident state; partials are (R + 2) floats per head per shard.
    """
    q_eff = jnp.einsum("bhn,rhn->bhr", q_nope.astype(jnp.float32),
                       wk_b.astype(jnp.float32))

    if plan is None or plan.mesh is None or not plan.seq_axes:
        acc, l, m = ref.mla_decode_scores_partial(
            q_eff, q_rope, ckv, krope, kpos, cur_pos, scale=scale)
        return ref.combine_partials(acc[None], l[None], m[None], axis=0)

    b_axes = plan.batch_axes or None
    s_axes = plan.seq_axes
    per_slot, kpos_spec, cur_spec, cur = _per_slot_layout(
        kpos, cur_pos, b_axes, s_axes)

    def local(q_eff_l, q_rope_l, ckv_l, krope_l, kpos_l, cur):
        acc, l, m = ref.mla_decode_scores_partial(
            q_eff_l, q_rope_l, ckv_l, krope_l, kpos_l,
            cur if per_slot else cur[0], scale=scale)
        return _combine(acc, l, m, s_axes)

    fn = shard_map(
        local, mesh=plan.mesh,
        in_specs=(P(b_axes), P(b_axes), P(b_axes, s_axes), P(b_axes, s_axes),
                  kpos_spec, cur_spec),
        out_specs=P(b_axes),
        check_vma=False)
    return fn(q_eff, q_rope, ckv, krope, kpos, cur)
