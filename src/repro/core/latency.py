"""Per-request latency records + tail-latency / goodput aggregation.

The paper's claim is end-to-end service quality on a storage server, not
just aggregate tokens/s — and at the tail, scheduling and chunked-prefill
decisions become visible only through *per-request* timing.  This module is
the measurement layer the SLO-aware serving stack is built on:

  * ``LatencyRecord`` — one request's life on the serving clock:
    submit → admit (slot assignment) → first token → completion, plus the
    request's priority class and its (absolute) TTFT deadline.  Every
    timestamp lives on ONE clock — the single engine's virtual serving
    clock, or the cluster's idle-aware wall clock — so the derived metrics
    (queue wait, TTFT, time-per-output-token, end-to-end) are internally
    consistent: ``submit_t <= admit_t <= first_token_t <= finish_t``;
  * ``LatencyStats`` — the aggregation ``ServeStats`` / ``ClusterStats``
    expose: p50/p95/p99 TTFT and end-to-end percentiles, mean TPOT/queue
    wait, SLO attainment, and goodput-under-SLO (completions that met
    their TTFT deadline, per second of serving clock).

Degenerate inputs never raise (a shed-everything or instant-drain run must
not crash a bench): percentiles over zero completed records are NaN, rates
over a zero wall clock are NaN, counts are 0.  Callers gate on finiteness.
"""
from __future__ import annotations

import math
import threading
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

NAN = float("nan")


def percentile(xs: Sequence[float], q: float) -> float:
    """q-th percentile (0..100, linear interpolation) over the finite
    entries of ``xs``; NaN when none are finite (documented, not raised)."""
    vals = sorted(x for x in xs if math.isfinite(x))
    if not vals:
        return NAN
    if len(vals) == 1:
        return vals[0]
    rank = (len(vals) - 1) * q / 100.0
    lo = int(math.floor(rank))
    hi = min(lo + 1, len(vals) - 1)
    frac = rank - lo
    return vals[lo] * (1.0 - frac) + vals[hi] * frac


@dataclass
class LatencyRecord:
    """One request's timestamps on the serving clock (NaN until stamped)."""
    rid: int
    priority: int = 0
    deadline_s: Optional[float] = None   # absolute TTFT deadline; None = no SLO
    submit_t: float = NAN                # entered the shared queue
    admit_t: float = NAN                 # got a slot (re-stamped on restart)
    first_token_t: float = NAN           # first generated token emitted
    finish_t: float = NAN                # completed (or shed / failed)
    n_tokens: int = 0
    status: str = "pending"              # pending | ok | shed | failed
    retries: int = 0                     # fail()-restarts granted so far

    # -- derived metrics -----------------------------------------------------

    @property
    def queue_wait_s(self) -> float:
        return self.admit_t - self.submit_t

    @property
    def ttft_s(self) -> float:
        """Time to first token, measured from SUBMIT (queue wait included —
        that is where scheduling decisions show up)."""
        return self.first_token_t - self.submit_t

    @property
    def e2e_s(self) -> float:
        return self.finish_t - self.submit_t

    @property
    def tpot_s(self) -> float:
        """Time per output token AFTER the first (decode cadence); NaN for
        0/1-token requests, where no inter-token interval exists."""
        if self.n_tokens <= 1:
            return NAN
        return (self.finish_t - self.first_token_t) / (self.n_tokens - 1)

    @property
    def met_deadline(self) -> bool:
        """True iff the first token arrived by the deadline.  No deadline
        means no SLO to miss; a shed / never-served request missed it."""
        if self.deadline_s is None:
            return self.status == "ok"
        return math.isfinite(self.first_token_t) and \
            self.first_token_t <= self.deadline_s

    def restart(self) -> None:
        """A fail()-restarted request replays from its prompt: the service
        clock restarts (admit / first token re-stamped by the retry) but
        queue wait keeps the ORIGINAL submit — the user has been waiting
        since then, whatever the cluster did in between.  ``retries``
        counts the restarts so the retry budget is visible per record."""
        self.admit_t = NAN
        self.first_token_t = NAN
        self.n_tokens = 0
        self.retries += 1


@dataclass
class LatencyStats:
    """Aggregate view over completed (and shed) ``LatencyRecord``s."""
    records: List[LatencyRecord] = field(default_factory=list)
    # appended to from the concurrent runtime's absorb path; list.append
    # is GIL-atomic but the explicit lock keeps the contract honest
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def add(self, rec: LatencyRecord) -> None:
        with self._lock:
            self.records.append(rec)

    # -- populations ---------------------------------------------------------

    @property
    def completed(self) -> List[LatencyRecord]:
        return [r for r in self.records if r.status == "ok"]

    @property
    def count(self) -> int:
        return len(self.completed)

    @property
    def shed(self) -> int:
        return sum(1 for r in self.records if r.status == "shed")

    @property
    def failed(self) -> int:
        """Requests that exhausted their retry budget (or died with the
        last drive) — terminal, never served."""
        return sum(1 for r in self.records if r.status == "failed")

    # -- percentiles (NaN over empty populations) ----------------------------

    def _pop(self, priority: Optional[int]) -> List[LatencyRecord]:
        """Completed records, optionally one priority class only — EDF
        trades the loose-deadline tail for the tight one, so aggregate
        percentiles hide exactly the improvement class-level ones show."""
        if priority is None:
            return self.completed
        return [r for r in self.completed if r.priority == priority]

    def ttft_p(self, q: float, priority: Optional[int] = None) -> float:
        return percentile([r.ttft_s for r in self._pop(priority)], q)

    def e2e_p(self, q: float, priority: Optional[int] = None) -> float:
        return percentile([r.e2e_s for r in self._pop(priority)], q)

    def queue_wait_p(self, q: float,
                     priority: Optional[int] = None) -> float:
        return percentile([r.queue_wait_s for r in self._pop(priority)], q)

    @property
    def p50_ttft_s(self) -> float:
        return self.ttft_p(50)

    @property
    def p95_ttft_s(self) -> float:
        return self.ttft_p(95)

    @property
    def p99_ttft_s(self) -> float:
        return self.ttft_p(99)

    @property
    def p99_e2e_s(self) -> float:
        return self.e2e_p(99)

    @property
    def mean_tpot_s(self) -> float:
        vals = [r.tpot_s for r in self.completed if math.isfinite(r.tpot_s)]
        return sum(vals) / len(vals) if vals else NAN

    @property
    def mean_queue_wait_s(self) -> float:
        vals = [r.queue_wait_s for r in self.completed
                if math.isfinite(r.queue_wait_s)]
        return sum(vals) / len(vals) if vals else NAN

    # -- SLO attainment ------------------------------------------------------

    @property
    def slo_met(self) -> int:
        """Completed requests whose first token beat their TTFT deadline
        (no-deadline completions count as met: there was no SLO to miss)."""
        return sum(1 for r in self.completed if r.met_deadline)

    @property
    def slo_attainment(self) -> float:
        """Fraction of ALL tracked requests (shed and failed included —
        both missed by construction) that met their deadline; NaN when
        nothing tracked."""
        denom = self.count + self.shed + self.failed
        return self.slo_met / denom if denom > 0 else NAN

    def goodput_qps(self, wall_s: float) -> float:
        """Goodput-under-SLO: deadline-met completions per second of serving
        clock.  NaN for a zero/negative wall clock (an instant-drain run)."""
        if not (wall_s > 0.0) or not math.isfinite(wall_s):
            return NAN
        return self.slo_met / wall_s

    # -- reporting -----------------------------------------------------------

    def metrics(self, wall_s: Optional[float] = None) -> dict:
        """Flat metric dict — the single source ``summary()`` (and the
        telemetry/metrics export) renders from, so printed and exported
        numbers cannot drift.  ``wall_s`` adds goodput on that clock."""
        m = {
            "count": self.count,
            "shed": self.shed,
            "failed": self.failed,
            "tracked": self.count + self.shed + self.failed,
            "p50_ttft_s": self.p50_ttft_s,
            "p95_ttft_s": self.p95_ttft_s,
            "p99_ttft_s": self.p99_ttft_s,
            "p99_e2e_s": self.p99_e2e_s,
            "mean_tpot_s": self.mean_tpot_s,
            "mean_queue_wait_s": self.mean_queue_wait_s,
            "slo_met": self.slo_met,
            "slo_attainment": self.slo_attainment,
        }
        if wall_s is not None:
            m["wall_s"] = wall_s
            m["goodput_qps"] = self.goodput_qps(wall_s)
        return m

    def summary(self) -> str:
        m = self.metrics()
        if m["tracked"] == 0:
            return "latency: no completed requests"
        failed = f" / {m['failed']} failed" if m["failed"] else ""
        return (f"latency: {m['count']} ok / {m['shed']} shed{failed}; TTFT "
                f"p50 {m['p50_ttft_s'] * 1e3:.1f} / p95 "
                f"{m['p95_ttft_s'] * 1e3:.1f} / p99 "
                f"{m['p99_ttft_s'] * 1e3:.1f} ms; e2e p99 "
                f"{m['p99_e2e_s'] * 1e3:.1f} ms; TPOT "
                f"{m['mean_tpot_s'] * 1e3:.2f} ms; queue wait "
                f"{m['mean_queue_wait_s'] * 1e3:.1f} ms; SLO met "
                f"{m['slo_met']}/{m['tracked']}")
