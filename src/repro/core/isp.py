"""ISP execution-plan helpers: pick the cheaper side of the link.

The paper's rule, made explicit: given a workload with a big resident
object (table / KV cache / expert weights) and a small query stream, choose
between shipping data to compute ("host plan") and shipping queries to data
("ISP plan") by comparing link bytes — then record the decision in a
transfer ledger.  `core.embedding` / `core.decode_attention` / `models.moe`
implement the winning plans; this module exposes the decision function the
serving layer and benchmarks use.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Literal

from repro.core.transfer import (TransferLedger, decode_attention_plans,
                                 embedding_plans, host_only_ledger,
                                 workload_split_ledger)

Plan = Literal["host", "isp"]


@dataclass(frozen=True)
class PlanChoice:
    plan: Plan
    host_link_bytes: float
    isp_link_bytes: float

    @property
    def saving(self) -> float:
        hi = max(self.host_link_bytes, 1e-9)
        return 1.0 - min(self.isp_link_bytes, hi) / hi


def choose_embedding_plan(num_lookups: int, vocab: int, d_model: int,
                          tp: int = 16, bytes_per_el: int = 2) -> PlanChoice:
    base, isp = embedding_plans(num_lookups, vocab, d_model,
                                bytes_per_el=bytes_per_el, tp=tp)
    plan: Plan = "isp" if isp.total_moved < base.total_moved else "host"
    return PlanChoice(plan, base.total_moved, isp.total_moved)


def choose_decode_plan(batch: int, heads: int, head_dim: int, seq: int,
                       kv_heads: int, shards: int = 16) -> PlanChoice:
    base, isp = decode_attention_plans(batch, heads, head_dim, seq, kv_heads,
                                       shards=shards)
    plan: Plan = "isp" if isp.total_moved < base.total_moved else "host"
    return PlanChoice(plan, base.total_moved, isp.total_moved)
