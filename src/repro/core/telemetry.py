"""One opt-in telemetry hub for the whole serving stack.

Three faces, one object:

* **request spans** — every request traces ``submit -> route -> admit ->
  prefill_chunk* -> decode_block* -> (retry/hedge/shed/cancel)* ->
  finish``.  Spans are *keyed* (``("req", rid)``, ``("hedge", grid)``)
  so the owner that opened a span is not necessarily the one that
  closes it; double-closes and orphan closes are counted, never raised.
* **metrics registry** — counters, gauges and fixed-bucket histograms
  that the engines publish into each tick, plus the derived fault
  **detection latency** (injection -> SUSPECT -> DEAD, per authority)
  that no per-subsystem stats object could compute alone.
* **exporters** — a jsonl event log, a Chrome-trace / Perfetto JSON
  (one track per drive worker + coordinator + counter tracks), and a
  plain metrics snapshot dict.

Clock-domain rule (mirrors the ``LatencyRecord`` caveat from PR 6):
every event is stamped by its *caller* on the clock that owns the
track — a standalone engine stamps its virtual serving clock, a
cluster's drive engines stamp their per-drive virtual clocks, and the
coordinator (request spans included) stamps the cluster wall.  The hub
never reads a clock itself; one timebase per track is the invariant
the monotonicity tests enforce.

Honesty about cost: the module-level ``NULL_HUB`` is a no-op whose
every method is ``pass`` behind ``enabled = False`` — instrumentation
sites guard on that flag so the disabled path costs one attribute
check (tier-1 gated).  The enabled hub keeps events in a bounded
``deque`` ring so open-loop soak runs cannot OOM; drops are counted in
``events_dropped``.
"""
from __future__ import annotations

import json
import math
import threading
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["NullHub", "NULL_HUB", "TelemetryHub", "DEFAULT_HIST_BUCKETS"]

# seconds-scale latency buckets: 1ms .. 30s, roughly x3 apart
DEFAULT_HIST_BUCKETS = (0.001, 0.003, 0.01, 0.03, 0.1, 0.3, 1.0, 3.0,
                        10.0, 30.0)


class NullHub:
    """Disabled telemetry: every method is a no-op.

    Call sites guard on ``hub.enabled`` before building attribute
    dicts, so with the null hub a traced tick pays one attribute load
    per site and nothing else.
    """

    enabled = False

    def counter(self, name, inc=1):            # pragma: no cover - trivial
        pass

    def gauge(self, name, value):              # pragma: no cover - trivial
        pass

    def observe(self, name, value):            # pragma: no cover - trivial
        pass

    def phase(self, track, name, t0, dur, **attrs):
        pass

    def point(self, track, name, t, **attrs):
        pass

    def counter_sample(self, track, name, t, value):
        pass

    def open_span(self, key, t, track, name, **attrs):
        pass

    def close_span(self, key, t, status, **attrs):
        pass

    def open_request(self, rid, t, **attrs):
        pass

    def request_point(self, rid, name, t, **attrs):
        pass

    def close_request(self, rid, t, status, **attrs):
        pass

    def fault_injected(self, drive, kind, t, tick):
        pass

    def health_transition(self, authority, drive, old, new, t):
        pass

    def publish(self, name, mapping):
        pass


NULL_HUB = NullHub()


class TelemetryHub:
    """Thread-safe, bounded-memory telemetry hub.

    One internal lock guards everything; callers already hold engine or
    cluster locks, and the hub never calls back out, so lock ordering
    stays ``caller lock -> hub lock`` with no cycles.
    """

    enabled = True

    def __init__(self, capacity: int = 65536,
                 hist_buckets: Tuple[float, ...] = DEFAULT_HIST_BUCKETS):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=int(capacity))
        self.capacity = int(capacity)
        self.events_dropped = 0
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hist_buckets = tuple(hist_buckets)
        self._hists: Dict[str, List[int]] = {}   # name -> len(buckets)+1 bins
        self._hist_sum: Dict[str, float] = {}
        self._open: Dict[Any, dict] = {}         # span key -> attrs at open
        self._published: Dict[str, dict] = {}
        # detection latency: first injection per drive, first transition
        # per (authority, drive, state)
        self._inject: Dict[int, Tuple[str, float, int]] = {}
        self._detect: Dict[Tuple[str, int], Dict[str, float]] = {}

    # -- raw event plumbing -------------------------------------------------

    def _emit(self, ev: dict) -> None:
        # caller holds self._lock
        if len(self._events) == self._events.maxlen:
            self.events_dropped += 1
        self._events.append(ev)

    # -- metrics registry ---------------------------------------------------

    def counter(self, name: str, inc: float = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + inc

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        """Record ``value`` into the fixed-bucket histogram ``name``."""
        v = float(value)
        with self._lock:
            bins = self._hists.get(name)
            if bins is None:
                bins = [0] * (len(self._hist_buckets) + 1)
                self._hists[name] = bins
                self._hist_sum[name] = 0.0
            i = 0
            for b in self._hist_buckets:
                if v <= b:
                    break
                i += 1
            bins[i] += 1
            if math.isfinite(v):
                self._hist_sum[name] += v

    def publish(self, name: str, mapping: Dict[str, Any]) -> None:
        """Merge a stats-object snapshot into the metrics export."""
        with self._lock:
            self._published[name] = dict(mapping)

    # -- track events -------------------------------------------------------

    def phase(self, track: str, name: str, t0: float, dur: float,
              **attrs) -> None:
        """A complete span ``[t0, t0+dur]`` on ``track`` (Chrome "X")."""
        with self._lock:
            self._emit({"ev": "phase", "track": track, "name": name,
                        "t": float(t0), "dur": float(dur), "attrs": attrs})

    def point(self, track: str, name: str, t: float, **attrs) -> None:
        """An instant event on ``track`` (Chrome "i")."""
        with self._lock:
            self._emit({"ev": "point", "track": track, "name": name,
                        "t": float(t), "attrs": attrs})

    def counter_sample(self, track: str, name: str, t: float,
                       value: float) -> None:
        """A sampled counter value on ``track`` (Chrome "C")."""
        with self._lock:
            self._emit({"ev": "counter", "track": track, "name": name,
                        "t": float(t), "value": float(value)})

    # -- keyed spans --------------------------------------------------------

    def open_span(self, key: Any, t: float, track: str, name: str,
                  **attrs) -> None:
        with self._lock:
            if key in self._open:
                # double-open: count it, keep the original
                self._counters["telemetry.span_double_open"] = \
                    self._counters.get("telemetry.span_double_open", 0) + 1
                return
            self._open[key] = {"t0": float(t), "track": track,
                               "name": name, "attrs": dict(attrs)}
            self._emit({"ev": "point", "track": track,
                        "name": f"{name}:open", "t": float(t),
                        "attrs": dict(attrs)})

    def close_span(self, key: Any, t: float, status: str, **attrs) -> None:
        """Close a keyed span; unknown/already-closed keys are counted
        (``telemetry.span_double_close``) and dropped, never raised."""
        with self._lock:
            sp = self._open.pop(key, None)
            if sp is None:
                self._counters["telemetry.span_double_close"] = \
                    self._counters.get("telemetry.span_double_close", 0) + 1
                return
            merged = dict(sp["attrs"])
            merged.update(attrs)
            merged["status"] = status
            t0 = sp["t0"]
            self._emit({"ev": "phase", "track": sp["track"],
                        "name": sp["name"], "t": t0,
                        "dur": max(0.0, float(t) - t0), "attrs": merged})
            self._counters[f"spans.{status}"] = \
                self._counters.get(f"spans.{status}", 0) + 1

    def open_span_count(self) -> int:
        with self._lock:
            return len(self._open)

    def span_point(self, key: Any, name: str, t: float, **attrs) -> None:
        """An instant event on the track of the open span ``key``."""
        with self._lock:
            sp = self._open.get(key)
            track = sp["track"] if sp is not None else "orphans"
            self._emit({"ev": "point", "track": track, "name": name,
                        "t": float(t), "attrs": attrs})

    # -- request-span conveniences -----------------------------------------

    def open_request(self, rid: int, t: float, **attrs) -> None:
        self.open_span(("req", rid), t, "requests", f"req{rid}",
                       rid=rid, **attrs)

    def request_point(self, rid: int, name: str, t: float, **attrs) -> None:
        self.span_point(("req", rid), name, t, rid=rid, **attrs)

    def close_request(self, rid: int, t: float, status: str,
                      **attrs) -> None:
        self.close_span(("req", rid), t, status, **attrs)

    # -- fault detection latency -------------------------------------------

    def fault_injected(self, drive: int, kind: str, t: float,
                       tick: int) -> None:
        with self._lock:
            if drive not in self._inject:      # first injection wins
                self._inject[drive] = (kind, float(t), int(tick))
            self._emit({"ev": "point", "track": "coordinator",
                        "name": "fault_injected", "t": float(t),
                        "attrs": {"drive": drive, "kind": kind,
                                  "tick": tick}})

    def health_transition(self, authority: str, drive: int, old: str,
                          new: str, t: float) -> None:
        with self._lock:
            self._emit({"ev": "point", "track": "coordinator",
                        "name": "health_transition", "t": float(t),
                        "attrs": {"authority": authority, "drive": drive,
                                  "old": old, "new": new}})
            inj = self._inject.get(drive)
            if inj is None:
                return
            key = (authority, drive)
            rec = self._detect.setdefault(key, {})
            field = {"suspect": "suspect_s", "dead": "dead_s"}.get(new)
            if field is not None and field not in rec:
                rec[field] = float(t) - inj[1]

    # -- exporters ----------------------------------------------------------

    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def metrics(self) -> dict:
        with self._lock:
            hists = {}
            for name, bins in self._hists.items():
                n = sum(bins)
                hists[name] = {
                    "buckets": list(self._hist_buckets),
                    "counts": list(bins),
                    "count": n,
                    "sum": self._hist_sum[name],
                    "mean": self._hist_sum[name] / n if n else 0.0,
                }
            detection = {}
            for (auth, drive), rec in sorted(self._detect.items()):
                inj = self._inject.get(drive)
                detection[f"{auth}.drive{drive}"] = {
                    "kind": inj[0] if inj else None,
                    "injected_t": inj[1] if inj else None,
                    **rec,
                }
            return {
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "histograms": hists,
                "detection_latency": detection,
                "open_spans": len(self._open),
                "events_dropped": self.events_dropped,
                "published": {k: dict(v) for k, v in
                              self._published.items()},
            }

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for ev in self.events():
                f.write(json.dumps(ev) + "\n")

    def to_chrome_trace(self) -> dict:
        """Render the event ring as Chrome-trace / Perfetto JSON.

        One pid per track (coordinator first, then drives/workers in
        name order); timestamps are microseconds on each track's own
        clock — comparing across tracks compares different timebases,
        which the ROADMAP clock-domain note spells out.
        """
        evs = self.events()
        tracks = sorted({e["track"] for e in evs},
                        key=lambda t: (t != "coordinator", t))
        pid_of = {t: i + 1 for i, t in enumerate(tracks)}
        out: List[dict] = []
        for t in tracks:
            out.append({"name": "thread_name", "ph": "M",
                        "pid": pid_of[t], "tid": 0,
                        "args": {"name": t}})
            out.append({"name": "process_name", "ph": "M",
                        "pid": pid_of[t], "tid": 0,
                        "args": {"name": t}})
        for e in evs:
            pid = pid_of[e["track"]]
            ts = e["t"] * 1e6
            if e["ev"] == "phase":
                out.append({"name": e["name"], "ph": "X", "pid": pid,
                            "tid": 0, "ts": ts,
                            "dur": max(e["dur"], 0.0) * 1e6,
                            "args": e.get("attrs", {})})
            elif e["ev"] == "counter":
                out.append({"name": e["name"], "ph": "C", "pid": pid,
                            "tid": 0, "ts": ts,
                            "args": {"value": e["value"]}})
            else:
                out.append({"name": e["name"], "ph": "i", "pid": pid,
                            "tid": 0, "ts": ts, "s": "t",
                            "args": e.get("attrs", {})})
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)

    def write_metrics(self, path: str,
                      extra: Optional[dict] = None) -> None:
        snap = self.metrics()
        if extra:
            snap = {**snap, **extra}
        with open(path, "w") as f:
            json.dump(snap, f, indent=2, sort_keys=True)
