"""Concurrent drive-worker runtime: workers, heartbeats, watchdog.

The paper's storage server is 36 drives computing *in parallel*; the
cluster tier's serial step loop modeled that overlap with per-drive
virtual clocks, so failure detection had to infer death from clock lag.
This module provides the real thing: one ``DriveWorker`` thread per
drive, fed tick commands over a per-drive ``queue.Queue`` by the
coordinator (the ``ClusterEngine.step`` caller), replying with
``Heartbeat``s on a shared monitor queue.  Failure is then what it is in
production — *silence on a real channel* — and the
``HeartbeatWatchdog`` drives the HEALTHY -> SUSPECT -> DEAD state
machine from missed heartbeats and wall-clock silence, not modeled lag.

Protocol (fork-join per tick):

  coordinator                      worker (one per drive)
  -----------                      ----------------------
  dispatch requests                loop:
  put WorkerCommand(tick,epoch) ->   get command
  join on monitor queue              consult PURE fault predicates only:
  (dispatch_timeout_s)                 crash   -> thread exits (silence)
    absorb tick_done payloads          hang    -> really block; command
    under the cluster lock                        lost; late "alive" beat
  watchdog.observe(...) per drive      stall   -> "alive" beat, no work
  DEAD edge -> engine.fail()         else: lock drive, step engine,
                                       pad to emulated service time,
                                     <- put Heartbeat(tick_done, payload)

Workers never touch shared cluster state: the engine step runs under the
drive's own lock, and everything shared (queue, admission, router,
ledgers, stats) is mutated by the coordinator while absorbing payloads.
``fail()`` bumps the drive's epoch under the drive lock; stale-epoch
commands and heartbeats are discarded on both sides, which is what makes
kill-while-mid-tick race-safe.

Ground truth stays hidden: workers consult only the pure
``FaultSchedule`` predicates (``crash_active`` / ``hangs`` /
``stalled``), never the delivered-set mutating queries — the watchdog
can only learn about a fault from the missing heartbeat.
"""
from __future__ import annotations

import queue
import random
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

from ..core.faults import DEAD, HEALTHY, SUSPECT, FaultSchedule
from ..core.telemetry import NULL_HUB as _NULL


@dataclass(frozen=True)
class WorkerCommand:
    """One coordinator -> worker message.  ``kind`` is "tick" or "stop";
    ``epoch`` is the drive's fail-epoch at dispatch time — a worker that
    receives a stale epoch discards the command (the drive was failed
    while the command was in flight)."""
    kind: str
    tick: int = 0
    clock: float = 0.0
    epoch: int = 0


@dataclass(frozen=True)
class Heartbeat:
    """One worker -> coordinator message on the shared monitor queue.

    ``kind`` is "tick_done" (payload carries the step results) or
    "alive" (liveness only: a stalled drive's firmware still answers
    pings, and a worker waking from a hang announces it lost the
    command).  ``busy_s`` is the worker's real wall time for the command
    including the emulated-service-time padding; the coordinator turns it
    into the drive's measured tick cost."""
    drive_id: int
    kind: str
    tick: int
    epoch: int
    busy_s: float = 0.0
    payload: Optional[Dict[str, Any]] = None


class DriveWorker(threading.Thread):
    """One drive's worker thread.

    ``step_fn(tick, clock)`` is supplied by the cluster engine and runs
    the drive's engine tick under the drive lock, returning a payload
    dict ``{"finished", "obs", "raw_s"}`` or None when there was nothing
    to do (or the drive was failed/stale meanwhile).  The worker owns the
    generic machinery: the command loop, pure-predicate fault behavior,
    service-time emulation (floor + injected slowdown + modeled drive
    speed + jitter, all slept with the GIL released), and heartbeats.
    """

    def __init__(self, drive_id: int, step_fn: Callable[[int, float], Optional[dict]],
                 commands: "queue.Queue[WorkerCommand]",
                 monitor: "queue.Queue[Heartbeat]",
                 stop_event: threading.Event,
                 epoch_of: Callable[[], int],
                 faults: Optional[FaultSchedule] = None,
                 speed: float = 1.0, min_tick_s: float = 0.0,
                 jitter_s: float = 0.0, seed: int = 0,
                 telemetry=None):
        super().__init__(name=f"drive-worker-{drive_id}", daemon=True)
        self.drive_id = drive_id
        self.step_fn = step_fn
        self.commands = commands
        self.monitor = monitor
        self.stop_event = stop_event
        self.epoch_of = epoch_of
        self.faults = faults
        # optional telemetry hub: heartbeats become instant events on the
        # f"worker{d}" track, stamped at the COMMAND's cluster clock (the
        # worker has no clock of its own; per-track monotonicity follows
        # from command clocks being monotone per drive)
        self.tele = telemetry if telemetry is not None else _NULL
        self._track = f"worker{drive_id}"
        self.speed = float(speed)
        self.min_tick_s = float(min_tick_s)
        self.jitter_s = float(jitter_s)
        self.rng = random.Random(seed)
        self.hangs_served = 0           # debug/test visibility
        self._hung: set = set()         # hang event indices already served

    def run(self) -> None:
        while not self.stop_event.is_set():
            try:
                cmd = self.commands.get(timeout=0.05)
            except queue.Empty:
                continue
            if cmd.kind == "stop":
                break
            t0 = time.perf_counter()
            if self.faults is not None:
                if self.faults.crash_active(self.drive_id, cmd.tick, cmd.clock):
                    if self.tele.enabled:
                        # a trace annotation only — the watchdog never
                        # reads the hub, so ground truth stays hidden
                        # from detection
                        self.tele.point(self._track, "worker_exit",
                                        cmd.clock, tick=cmd.tick,
                                        reason="crash")
                    return              # a crashed worker dies: pure silence
                hung = False
                for idx, dur in self.faults.hangs(self.drive_id, cmd.tick,
                                                  cmd.clock):
                    if idx in self._hung:
                        continue
                    self._hung.add(idx)
                    self.hangs_served += 1
                    # the thread REALLY blocks; only stop_event (shutdown)
                    # can interrupt it — the command it held is lost
                    self.stop_event.wait(dur)
                    hung = True
                if hung:
                    # woke up: announce liveness so the coordinator clears
                    # the outstanding command and dispatches again
                    self._beat("alive", cmd, reason="hang_wakeup")
                    continue
                if self.faults.stalled(self.drive_id, cmd.tick, cmd.clock):
                    self._beat("alive", cmd, reason="stalled")
                    continue
            if cmd.epoch != self.epoch_of():
                continue                # failed while the command flew
            payload = self.step_fn(cmd.tick, cmd.clock)
            if payload is None:
                self._beat("alive", cmd, reason="idle")
                continue
            raw = float(payload.get("raw_s", 0.0))
            compile_s = float(getattr(payload.get("obs"), "compile_s", 0.0))
            base = max(raw - compile_s, 0.0)
            slow = 1.0
            if self.faults is not None:
                slow = self.faults.slowdown(self.drive_id, cmd.tick, cmd.clock)
            # emulated drive service time: floor to min_tick_s, stretch by
            # the injected slowdown and the modeled drive speed, add jitter
            target = max(base, self.min_tick_s) * slow / self.speed
            if self.jitter_s > 0.0:
                target += self.rng.uniform(0.0, self.jitter_s)
            pad = target - base
            if pad > 0.0:
                self.stop_event.wait(pad)   # GIL released: real overlap
            busy = time.perf_counter() - t0
            if self.tele.enabled:
                self.tele.point(self._track, "heartbeat", cmd.clock,
                                kind="tick_done", tick=cmd.tick,
                                epoch=cmd.epoch, busy_s=busy)
            self.monitor.put(Heartbeat(self.drive_id, "tick_done", cmd.tick,
                                       cmd.epoch, busy_s=busy,
                                       payload=payload))

    def _beat(self, kind: str, cmd: WorkerCommand, reason: str) -> None:
        """Liveness-only heartbeat + its telemetry point."""
        if self.tele.enabled:
            self.tele.point(self._track, "heartbeat", cmd.clock, kind=kind,
                            tick=cmd.tick, epoch=cmd.epoch, reason=reason)
        self.monitor.put(Heartbeat(self.drive_id, kind, cmd.tick, cmd.epoch))


class HeartbeatWatchdog:
    """HEALTHY/SUSPECT/DEAD from heartbeats and wall-clock silence.

    Deliberately NOT a wrapper over ``FailureDetector``: feeding wall
    ``time.monotonic()`` in as the detector's "leading clock" would
    instantly kill a drive that crashed before its first productive tick
    (the detector initializes its progress marks at 0.0).  The watchdog
    keeps the same API shape (``observe`` -> (old, new), ``mark_dead``,
    ``health``, ``suspects``, ``dead``) so the cluster engine treats
    either as its health authority.

    Per coordinator join, each drive with work is observed: ``replied``
    (any current-epoch heartbeat arrived) and ``progressed`` (a tick_done
    with a payload).  A productive beat — or an idle tick — resets both
    the miss counter and the silence timer; everything else counts a miss
    and lets silence accrue.  SUSPECT at ``suspect_misses`` consecutive
    misses or ``suspect_after_s`` of silence; DEAD at the ``dead_*``
    thresholds.  Silence is measured from the last productive beat, first
    observed lazily so a drive dead-on-arrival is judged by its own
    timeline, not the process start.
    """

    def __init__(self, n_drives: int, suspect_after_s: float = 0.25,
                 suspect_misses: int = 20,
                 dead_after_s: Optional[float] = None,
                 dead_misses: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic):
        if n_drives < 1:
            raise ValueError("need at least one drive")
        if suspect_after_s <= 0 or suspect_misses <= 0:
            raise ValueError("suspect thresholds must be positive")
        self.n_drives = n_drives
        self.suspect_after_s = float(suspect_after_s)
        self.suspect_misses = int(suspect_misses)
        self.dead_after_s = float(4.0 * suspect_after_s
                                  if dead_after_s is None else dead_after_s)
        self.dead_misses = int(4 * suspect_misses
                               if dead_misses is None else dead_misses)
        if self.dead_after_s < self.suspect_after_s or \
                self.dead_misses < self.suspect_misses:
            raise ValueError("dead thresholds must not be below suspect "
                             "thresholds")
        self._clock = clock
        self.health: List[str] = [HEALTHY] * n_drives
        self._missed = [0] * n_drives
        self._last_beat: List[Optional[float]] = [None] * n_drives

    def observe(self, drive_id: int, replied: bool, progressed: bool,
                has_work: bool) -> Tuple[str, str]:
        """One join's evidence for one drive; returns (old, new) health.
        DEAD is terminal — the engine fails the drive on that edge."""
        now = self._clock()
        old = self.health[drive_id]
        if old == DEAD:
            return old, old
        if self._last_beat[drive_id] is None:
            self._last_beat[drive_id] = now
        if (replied and progressed) or not has_work:
            # idle drives are never suspected; a productive heartbeat
            # clears any suspicion and resets the silence timer
            self._missed[drive_id] = 0
            self._last_beat[drive_id] = now
            self.health[drive_id] = HEALTHY
            return old, HEALTHY
        self._missed[drive_id] += 1
        silent_s = now - self._last_beat[drive_id]
        new = old
        if self._missed[drive_id] >= self.dead_misses or \
                silent_s > self.dead_after_s:
            new = DEAD
        elif self._missed[drive_id] >= self.suspect_misses or \
                silent_s > self.suspect_after_s:
            new = SUSPECT
        self.health[drive_id] = new
        return old, new

    def mark_dead(self, drive_id: int) -> None:
        """Operator/engine-initiated death (explicit ``fail()``)."""
        self.health[drive_id] = DEAD

    @property
    def suspects(self) -> List[int]:
        return [d for d, h in enumerate(self.health) if h == SUSPECT]

    @property
    def dead(self) -> List[int]:
        return [d for d, h in enumerate(self.health) if h == DEAD]
