from repro.data.pipeline import (  # noqa: F401
    DataConfig, SyntheticTokenSource, MemmapTokenSource, ShardedLoader,
    write_token_file)
