from repro.data.pipeline import (  # noqa: F401
    DataConfig, SyntheticTokenSource, MemmapTokenSource, ShardedLoader,
    write_token_file)
from repro.data.workload import (  # noqa: F401
    ARRIVAL_MODES, DEFAULT_CLASSES, PriorityClass, ReplayReport,
    TraceRequest, WorkloadConfig, generate_trace, load_trace,
    replay_open_loop, save_trace, scale_trace)
