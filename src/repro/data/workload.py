"""Open-loop workload generation for the serving benches.

The benches historically pushed 4–32 *closed-loop* requests (submit all,
drain); the paper's scenario is a storage server fielding bursty open-loop
traffic from millions of users.  This module generates that traffic as a
reproducible trace — arrival times on the serving clock, mixed
prompt/output lengths, per-request priority class and TTFT deadline — and
replays it against a serve engine:

  arrival processes
    poisson   homogeneous Poisson: exponential inter-arrival times at
              ``rate`` requests/s — memoryless background load;
    bursty    on/off modulated Poisson (an MMPP): ``duty`` of each
              ``period_s`` cycle runs at ``rate * burst_factor`` (the
              burst), the rest at a trickle — queues build during bursts,
              which is where FIFO vs EDF admission becomes visible;
    diurnal   non-homogeneous Poisson with a sinusoidal rate ramp of one
              ``period_s`` cycle (thinning) — the millions-of-users
              day/night curve compressed onto the bench clock.

  request mix
    every request draws a ``PriorityClass`` by weight; the class fixes its
    priority, TTFT SLO budget (``slo_s`` after arrival; None = best
    effort) and its prompt / max_new length ranges — e.g. interactive
    traffic is short prompts with tight deadlines, batch traffic long
    prompts with loose ones.

``replay_open_loop`` drives any engine exposing the serving-clock API
(``clock`` / ``advance_clock`` / ``submit`` / ``step`` — both
``ServeEngine`` and ``ClusterEngine``): requests are submitted when the
clock reaches their arrival time, the clock fast-forwards across idle
gaps, and the engine's own per-request ``LatencyRecord``s pick up the
queue-wait/TTFT story from there.
"""
from __future__ import annotations

import json
import math
from dataclasses import asdict, dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

ARRIVAL_MODES = ("poisson", "bursty", "diurnal")


@dataclass(frozen=True)
class PriorityClass:
    """One traffic class: scheduling priority (lower = more urgent), TTFT
    budget after arrival (None = best-effort), and its length mix."""
    name: str
    priority: int = 0
    weight: float = 1.0
    slo_s: Optional[float] = None
    prompt_range: Tuple[int, int] = (4, 16)
    max_new_range: Tuple[int, int] = (4, 16)


# a serviceable default mix: mostly tight-deadline interactive traffic with
# a long-prompt batch tail (weights ≈ the interactive-heavy mixes real
# serving fleets report)
DEFAULT_CLASSES = (
    PriorityClass("interactive", priority=0, weight=0.7, slo_s=1.0,
                  prompt_range=(4, 12), max_new_range=(4, 12)),
    PriorityClass("batch", priority=1, weight=0.3, slo_s=8.0,
                  prompt_range=(16, 40), max_new_range=(8, 24)),
)


@dataclass
class TraceRequest:
    """One generated request: arrival on the serving clock + its payload.
    ``deadline_s`` is ABSOLUTE (arrival + class SLO budget); None = no SLO."""
    arrival_s: float
    prompt: List[int]
    max_new: int
    priority: int = 0
    deadline_s: Optional[float] = None
    cls: str = ""


@dataclass(frozen=True)
class WorkloadConfig:
    n_requests: int
    vocab_size: int
    arrival: str = "poisson"       # poisson | bursty | diurnal
    rate: float = 4.0              # mean requests/s on the serving clock
    burst_factor: float = 4.0      # bursty: on-phase rate multiplier
    duty: float = 0.25             # bursty: fraction of the period that is on
    period_s: float = 4.0          # bursty/diurnal: cycle length
    classes: Sequence[PriorityClass] = DEFAULT_CLASSES
    seed: int = 0

    def __post_init__(self):
        if self.arrival not in ARRIVAL_MODES:
            raise ValueError(f"arrival must be one of {ARRIVAL_MODES}, "
                             f"got {self.arrival!r}")
        if self.n_requests < 1:
            raise ValueError("n_requests must be positive")
        if not (self.rate > 0.0 and math.isfinite(self.rate)):
            raise ValueError(f"rate must be finite and positive, "
                             f"got {self.rate}")
        if not 0.0 < self.duty <= 1.0:
            raise ValueError(f"duty must be in (0, 1], got {self.duty}")
        if not self.classes:
            raise ValueError("need at least one priority class")


def _arrival_times(cfg: WorkloadConfig, rng) -> List[float]:
    """Monotone arrival times for ``cfg.n_requests`` requests."""
    if cfg.arrival == "poisson":
        gaps = rng.exponential(1.0 / cfg.rate, cfg.n_requests)
        return np.cumsum(gaps).tolist()
    if cfg.arrival == "bursty":
        # on/off modulated Poisson with mean rate == cfg.rate: the on phase
        # runs at rate * burst_factor for duty * period; the off phase
        # carries whatever rate keeps the cycle mean at cfg.rate (floored
        # at a trickle so the off phase is quiet, not silent)
        on_rate = cfg.rate * cfg.burst_factor
        off_rate = max((cfg.rate - on_rate * cfg.duty) / (1.0 - cfg.duty),
                       0.05 * cfg.rate) if cfg.duty < 1.0 else on_rate
        out: List[float] = []
        t = 0.0
        while len(out) < cfg.n_requests:
            phase_on = (t % cfg.period_s) < cfg.duty * cfg.period_s
            r = on_rate if phase_on else off_rate
            # step to the next event OR the next phase boundary, whichever
            # comes first (the rate changes there)
            gap = rng.exponential(1.0 / r)
            boundary = cfg.duty * cfg.period_s if phase_on else cfg.period_s
            into = t % cfg.period_s
            to_boundary = boundary - into
            if gap < to_boundary:
                t += gap
                out.append(t)
            else:
                t += to_boundary + 1e-9
        return out
    # diurnal: non-homogeneous Poisson via thinning against the peak rate
    peak = 2.0 * cfg.rate
    out = []
    t = 0.0
    while len(out) < cfg.n_requests:
        t += rng.exponential(1.0 / peak)
        lam = cfg.rate * (1.0 + math.sin(2.0 * math.pi * t / cfg.period_s))
        if rng.random() * peak < lam:
            out.append(t)
    return out


def generate_trace(cfg: WorkloadConfig) -> List[TraceRequest]:
    """Generate the open-loop request trace (deterministic per seed)."""
    rng = np.random.default_rng(cfg.seed)
    arrivals = _arrival_times(cfg, rng)
    weights = np.asarray([c.weight for c in cfg.classes], float)
    weights = weights / weights.sum()
    picks = rng.choice(len(cfg.classes), size=cfg.n_requests, p=weights)
    trace: List[TraceRequest] = []
    for t, ci in zip(arrivals, picks):
        c = cfg.classes[int(ci)]
        plen = int(rng.integers(c.prompt_range[0], c.prompt_range[1] + 1))
        max_new = int(rng.integers(c.max_new_range[0],
                                   c.max_new_range[1] + 1))
        prompt = rng.integers(0, cfg.vocab_size, plen).tolist()
        deadline = None if c.slo_s is None else float(t) + c.slo_s
        trace.append(TraceRequest(arrival_s=float(t), prompt=prompt,
                                  max_new=max_new, priority=c.priority,
                                  deadline_s=deadline, cls=c.name))
    return trace


def scale_trace(trace: List[TraceRequest], time_scale: float
                ) -> List[TraceRequest]:
    """Stretch/compress a trace's time axis (arrivals AND deadlines) by
    ``time_scale`` — how the benches calibrate a generated trace to the
    measured service rate of the box they run on."""
    if not (time_scale > 0.0 and math.isfinite(time_scale)):
        raise ValueError(f"time_scale must be finite and positive, "
                         f"got {time_scale}")
    out = []
    for r in trace:
        out.append(TraceRequest(
            arrival_s=r.arrival_s * time_scale, prompt=list(r.prompt),
            max_new=r.max_new, priority=r.priority,
            deadline_s=None if r.deadline_s is None
            else r.deadline_s * time_scale, cls=r.cls))
    return out


def save_trace(path: str, trace: List[TraceRequest]) -> None:
    """One JSON object per line — diffable, streamable, replayable."""
    with open(path, "w") as f:
        for r in trace:
            f.write(json.dumps(asdict(r)) + "\n")


def load_trace(path: str) -> List[TraceRequest]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            out.append(TraceRequest(**json.loads(line)))
    return out


@dataclass
class ReplayReport:
    """What one open-loop replay produced: the engine's results plus the
    trace-level accounting the SLO bench gates on."""
    results: list = field(default_factory=list)
    submitted: int = 0
    wall_s: float = 0.0            # serving clock at drain (idle included)

    @property
    def completed(self) -> int:
        return sum(1 for r in self.results if r.status == "ok")

    @property
    def shed(self) -> int:
        return sum(1 for r in self.results if r.status == "shed")

    @property
    def failed(self) -> int:
        """Terminal ``status="failed"`` finishes (retry budget exhausted or
        the last drive died) — the third leg of the conservation invariant
        ``submitted == completed + shed + failed``."""
        return sum(1 for r in self.results if r.status == "failed")


def replay_open_loop(engine, trace: List[TraceRequest],
                     use_deadlines: bool = True,
                     submit_kw=None) -> ReplayReport:
    """Replay an open-loop trace against a serve engine on ITS clock.

    Requests are submitted when the engine clock reaches their arrival
    time; when the engine is idle ahead of the next arrival, the clock
    fast-forwards to it (open-loop idle is real wall time, not work).
    ``use_deadlines=False`` strips priorities/deadlines — the FIFO
    baseline replay, which must see exactly the same arrival process.
    """
    order = sorted(range(len(trace)), key=lambda i: trace[i].arrival_s)
    report = ReplayReport()
    kw = dict(submit_kw or {})
    i = 0
    while True:
        while i < len(order) and trace[order[i]].arrival_s <= engine.clock:
            r = trace[order[i]]
            if use_deadlines:
                engine.submit(r.prompt, max_new=r.max_new,
                              priority=r.priority, deadline_s=r.deadline_s,
                              **kw)
            else:
                engine.submit(r.prompt, max_new=r.max_new, **kw)
            report.submitted += 1
            i += 1
        # in_flight (cluster: active slots + drive-local queues) falls back
        # to num_active for the single engine, whose queue IS `pending`
        busy = engine.pending > 0 or \
            getattr(engine, "in_flight", engine.num_active) > 0
        if not busy and i >= len(order):
            break
        if not busy:
            # idle gap: jump the serving clock to the next arrival
            engine.advance_clock(trace[order[i]].arrival_s)
            continue
        report.results.extend(engine.step())
    report.results.sort(key=lambda r: r.rid)
    report.wall_s = engine.clock
    return report
