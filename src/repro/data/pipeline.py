"""Deterministic sharded data pipeline.

The OCFS2 "send indexes, not data" protocol becomes a pure function: every
worker derives its slice of step ``s`` from (step, host_id, shares) alone —
no dispatcher process, no shared-filesystem locking, and restart-exact
(checkpointing the pipeline = storing the step integer).

Sources:
  SyntheticTokenSource — hash-based deterministic tokens (tests, dry-runs)
  MemmapTokenSource    — binary .bin file of uint16/uint32 tokens, mmap'd
                         so each worker reads only its own byte ranges (the
                         in-storage path: bytes the worker doesn't own are
                         never read).

The loader supports heterogeneous per-host shares (the paper's batch ratio)
via ``shares``: host h gets ``shares[h]`` of every global batch.
"""
from __future__ import annotations

import dataclasses
import hashlib
import pathlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0


class SyntheticTokenSource:
    """Deterministic pseudo-random tokens: token[i] = h(seed, i) % vocab."""

    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab = int(vocab_size)
        self.seed = int(seed)

    def read(self, start: int, count: int) -> np.ndarray:
        # counter-based generation — O(1) seek, restart-exact
        blocks = []
        blk = 1 << 16
        b0, b1 = start // blk, (start + count - 1) // blk
        for b in range(b0, b1 + 1):
            rng = np.random.default_rng((self.seed << 32) ^ b)
            blocks.append(rng.integers(0, self.vocab, blk, dtype=np.int64))
        cat = np.concatenate(blocks)
        off = start - b0 * blk
        return cat[off: off + count].astype(np.int32)

    def __len__(self) -> int:
        return 1 << 62


class MemmapTokenSource:
    """Token stream backed by a flat binary file (np.memmap, read-only)."""

    def __init__(self, path, dtype=np.uint16):
        self.path = pathlib.Path(path)
        self.arr = np.memmap(self.path, dtype=dtype, mode="r")

    def read(self, start: int, count: int) -> np.ndarray:
        n = len(self.arr)
        idx = (start + np.arange(count)) % n       # wrap (epoch boundary)
        return self.arr[idx].astype(np.int32)

    def __len__(self) -> int:
        return len(self.arr)


def write_token_file(path, tokens: np.ndarray, dtype=np.uint16) -> None:
    np.asarray(tokens, dtype=dtype).tofile(path)


class ShardedLoader:
    """Per-host batch loader with heterogeneous shares.

    Global batch b of step s covers token span
      [s * global_batch * (seq+1), (s+1) * global_batch * (seq+1))
    split contiguously by per-host shares; host h reads only its own span —
    that is the ISP property (bytes never visit a coordinator).
    """

    def __init__(self, source, cfg: DataConfig,
                 shares: Optional[Dict[str, int]] = None,
                 host: str = "host0", num_hosts: int = 1):
        self.source = source
        self.cfg = cfg
        self.host = host
        if shares is None:
            base = cfg.global_batch // num_hosts
            shares = {f"host{i}": base for i in range(num_hosts)}
            shares[f"host{num_hosts - 1}"] += cfg.global_batch - base * num_hosts
        assert sum(shares.values()) == cfg.global_batch, shares
        self.shares = dict(shares)

    def set_shares(self, shares: Dict[str, int]) -> None:
        """Straggler rebalancing entry point (paper's batch-ratio rule)."""
        assert sum(shares.values()) == self.cfg.global_batch
        self.shares = dict(shares)

    def _host_offset(self, host: str) -> int:
        off = 0
        for h in sorted(self.shares):
            if h == host:
                return off
            off += self.shares[h]
        raise KeyError(host)

    def batch_at(self, step: int, host: Optional[str] = None) -> Dict[str, np.ndarray]:
        """Returns {"tokens": (share, seq), "labels": (share, seq)}."""
        host = host or self.host
        cfg = self.cfg
        stride = cfg.seq_len + 1
        base = step * cfg.global_batch * stride
        off = self._host_offset(host)
        n = self.shares[host]
        flat = self.source.read(base + off * stride, n * stride)
        seqs = flat.reshape(n, stride)
        return {"tokens": seqs[:, :-1].copy(), "labels": seqs[:, 1:].copy()}

    def global_batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Assemble the full global batch (tests / single-host training)."""
        parts = [self.batch_at(step, h) for h in sorted(self.shares)]
        return {k: np.concatenate([p[k] for p in parts], axis=0)
                for k in parts[0]}
