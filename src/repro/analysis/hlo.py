"""Post-SPMD HLO analysis: trip-aware FLOP, byte and collective accounting.

XLA's ``compiled.cost_analysis()`` counts each op once, ignoring while-loop
trip counts — useless for scan-over-layers programs.  We therefore walk the
optimized HLO text ourselves:

  * build a per-computation symbol table (value name → shape),
  * build a call graph (while body/condition with ``known_trip_count``,
    fusions, calls, conditionals) and propagate execution weights,
  * count FLOPs exactly for ``dot`` (2 · |result| · |contraction|) and
    approximately (1 flop/elem of the result) for fused elementwise ops,
  * count HBM bytes at fusion granularity (operands + result of each
    non-trivial op — post-opt HLO is already fused so this approximates
    actual traffic),
  * count per-device collective wire bytes with ring-algorithm factors:
      all-gather R·(g-1)/g,  reduce-scatter O·(g-1)/g,
      all-reduce 2·O·(g-1)/g,  all-to-all O·(g-1)/g,  permute O.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}]+)\s+([\w\-]+)")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_GROUPS_PAIR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')

_NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
               "after-all", "partition-id", "replica-id", "iota", "while",
               "conditional", "call", "custom-call"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start"}


def _parse_dims(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _first_shape(s: str) -> Tuple[Optional[str], Tuple[int, ...]]:
    m = _SHAPE_RE.search(s)
    if not m:
        return None, ()
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    return m.group(1), dims


def _shape_bytes_all(s: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(s):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        total += _parse_dims(m.group(2)) * _DTYPE_BYTES[dt]
    return total


# ops that read only a window of their (first) operand
_SLICING = {"dynamic-slice", "slice", "gather"}
# ops that write only a window (traffic = update read + update write)
_WINDOW_WRITE = {"dynamic-update-slice"}


@dataclass
class OpRecord:
    kind: str
    result_bytes: int
    operand_bytes: int
    flops: float
    elementwise: float = 0.0
    wire_bytes: float = 0.0
    coll_kind: str = ""
    name: str = ""
    hbm_bytes: float = 0.0          # slice-aware traffic (set at parse time)


@dataclass
class Computation:
    name: str
    ops: List[OpRecord] = field(default_factory=list)
    # (callee, trip_factor)
    calls: List[Tuple[str, int]] = field(default_factory=list)
    fusion_bodies: List[str] = field(default_factory=list)
    # param name -> (full_bytes, bytes actually read if all uses are slices)
    param_reads: Dict[str, Tuple[int, Optional[int]]] = field(default_factory=dict)
    # ordered fusion-call operand lists: op result name -> operand names
    operand_names: Dict[str, List[str]] = field(default_factory=dict)
    fusion_callee: Dict[str, str] = field(default_factory=dict)


@dataclass
class HloStats:
    flops: float = 0.0
    elementwise_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    bytes_by_kind: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    count_by_kind: Dict[str, float] = field(default_factory=lambda: defaultdict(float))

    @property
    def total_flops(self) -> float:
        return self.flops + self.elementwise_flops

    def as_dict(self) -> dict:
        return {"dot_flops": self.flops, "elementwise_flops": self.elementwise_flops,
                "total_flops": self.total_flops, "hbm_bytes": self.hbm_bytes,
                "collective_bytes": self.collective_bytes,
                "collective_bytes_by_kind": dict(self.bytes_by_kind),
                "collective_count_by_kind": dict(self.count_by_kind)}


def _group_size(line: str) -> int:
    m = _GROUPS_PAIR_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip()]), 1)
    return 2


def parse_module(hlo_text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    symbols: Dict[str, str] = {}      # value -> shape string (per computation)

    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        cm = None
        # computation headers: "%name (params...) -> type {"; beware that
        # parameter lists contain "/*index=5*/" comments (bare "=" is fine,
        # only op definitions have " = ")
        if line.endswith("{") and "->" in line and " = " not in line:
            cm = _COMP_RE.match(line.strip())
        if cm:
            current = Computation(cm.group(1))
            comps[current.name] = current
            symbols = {}
            continue
        if current is None:
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, shape_str, kind = dm.groups()
        symbols[name] = shape_str

        # call graph edges
        if kind == "while":
            tm = _TRIP_RE.search(line)
            trip = int(tm.group(1)) if tm else 1
            bm = re.search(r"body=%?([\w\.\-]+)", line)
            cm2 = re.search(r"condition=%?([\w\.\-]+)", line)
            if bm:
                current.calls.append((bm.group(1), trip))
            if cm2:
                current.calls.append((cm2.group(1), trip + 1))
        elif kind == "fusion":
            fm = re.search(r"calls=%?([\w\.\-]+)", line)
            if fm:
                # fusion bodies are covered by the fusion op itself (traffic =
                # operands+result; flops ~ result elems); exclude from walk.
                current.fusion_bodies.append(fm.group(1))
        elif kind in ("call", "custom-call"):
            fm = re.search(r"to_apply=%?([\w\.\-]+)", line)
            if fm:
                current.calls.append((fm.group(1), 1))
        elif kind == "conditional":
            for fm in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                  r"true_computation=%?([\w\.\-]+)|"
                                  r"false_computation=%?([\w\.\-]+))", line):
                names = fm.group(1) or ""
                for n in re.findall(r"%?([\w\.\-]+)", names):
                    current.calls.append((n, 1))
                for g in (fm.group(2), fm.group(3)):
                    if g:
                        current.calls.append((g, 1))

        # op record
        args = line.split("(", 1)[1] if "(" in line else ""
        arg_names = _OPERAND_RE.findall(args.split("metadata")[0])
        operand_bytes = sum(_shape_bytes_all(symbols.get(a, "")) for a in arg_names)
        result_bytes = _shape_bytes_all(shape_str)

        # slice-aware HBM traffic estimate for this op
        if kind in _SLICING:
            hbm = 2.0 * result_bytes                 # read window + write result
        elif kind in _WINDOW_WRITE:
            upd = _shape_bytes_all(symbols.get(arg_names[1], "")) if len(arg_names) > 1 else result_bytes
            hbm = 2.0 * upd                          # read update + write window
        elif kind == "broadcast":
            hbm = result_bytes
        else:
            hbm = result_bytes + operand_bytes

        # track how fusion-body parameters are read (full vs sliced)
        if kind == "parameter":
            current.param_reads[name] = (result_bytes, 0)
        for a in arg_names:
            if a in current.param_reads:
                full, sliced = current.param_reads[a]
                if sliced is not None:
                    if kind in _SLICING and arg_names and arg_names[0] == a:
                        current.param_reads[a] = (full, sliced + 2 * result_bytes)
                    elif kind in _WINDOW_WRITE and a == arg_names[0]:
                        upd_b = _shape_bytes_all(symbols.get(arg_names[1], "")) if len(arg_names) > 1 else 0
                        current.param_reads[a] = (full, sliced + 2 * upd_b)
                    else:
                        current.param_reads[a] = (full, None)   # full read
        if kind == "fusion":
            fm2 = re.search(r"calls=%?([\w\.\-]+)", line)
            if fm2:
                current.fusion_callee[name] = fm2.group(1)
                current.operand_names[name] = arg_names

        flops = 0.0
        ew = 0.0
        if kind == "dot":
            _, rdims = _first_shape(shape_str)
            cm3 = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            contract = 1
            if cm3 and arg_names:
                lhs_shape = symbols.get(arg_names[0], "")
                _, ldims = _first_shape(lhs_shape)
                for d in cm3.group(1).split(","):
                    if d and int(d) < len(ldims):
                        contract *= ldims[int(d)]
            n_res = 1
            for d in rdims:
                n_res *= d
            flops = 2.0 * n_res * contract
        elif kind not in _NO_TRAFFIC and kind not in _COLLECTIVES:
            # fused elementwise / reductions: ~1 flop per result element
            _, rdims = _first_shape(shape_str)
            n_res = 1
            for d in rdims:
                n_res *= d
            ew = float(n_res)

        rec = OpRecord(kind=kind, result_bytes=result_bytes,
                       operand_bytes=operand_bytes, flops=flops, elementwise=ew,
                       name=name, hbm_bytes=hbm)
        if kind in _COLLECTIVES:
            base_kind = kind.replace("-start", "")
            g = _group_size(line)
            factor = (g - 1) / g
            ob = operand_bytes or result_bytes
            if base_kind == "all-gather":
                rec.wire_bytes = result_bytes * factor
            elif base_kind == "reduce-scatter":
                rec.wire_bytes = ob * factor
            elif base_kind == "all-reduce":
                rec.wire_bytes = 2.0 * ob * factor
            elif base_kind == "all-to-all":
                rec.wire_bytes = ob * factor
            else:
                rec.wire_bytes = ob
            rec.coll_kind = base_kind
        current.ops.append(rec)
    return comps


def _weights(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Execution weight per computation (roots = 1; propagate trip counts).

    Fusion bodies get weight 0 (their cost is carried by the fusion op)."""
    import functools
    import sys
    sys.setrecursionlimit(10000)

    callers: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
    called = set()
    fused = set()
    for c in comps.values():
        for callee, trip in c.calls:
            callers[callee].append((c.name, trip))
            called.add(callee)
        fused.update(c.fusion_bodies)
    roots = {n for n in comps if n not in called and n not in fused}

    @functools.lru_cache(maxsize=None)
    def w(name: str) -> float:
        if name in fused:
            return 0.0
        if name in roots:
            return 1.0
        return sum(w(cn) * trip for cn, trip in callers.get(name, []))

    return {name: w(name) for name in comps}


def analyze(hlo_text: str) -> HloStats:
    comps = parse_module(hlo_text)
    weights = _weights(comps)
    stats = HloStats()
    for name, comp in comps.items():
        wt = weights.get(name, 1.0)
        if wt == 0.0:
            continue          # fusion bodies / dead computations
        for op in comp.ops:
            stats.flops += wt * op.flops
            stats.elementwise_flops += wt * op.elementwise
            if op.kind not in _NO_TRAFFIC:
                stats.hbm_bytes += wt * _op_traffic(op, comp, comps)
            if op.coll_kind:
                stats.collective_bytes += wt * op.wire_bytes
                stats.bytes_by_kind[op.coll_kind] += wt * op.wire_bytes
                stats.count_by_kind[op.coll_kind] += wt
    return stats


def _op_traffic(op: OpRecord, comp: Computation, comps: Dict[str, Computation]) -> float:
    """Slice-aware HBM traffic: fusion operands consumed only through
    dynamic-slice/slice inside the body count their windows, not the full
    buffer (critical for scan-over-chunks attention loops)."""
    if op.kind != "fusion":
        return op.hbm_bytes
    body = comps.get(comp.fusion_callee.get(op.name, ""))
    operands = comp.operand_names.get(op.name, [])
    if body is None or not operands:
        return op.hbm_bytes
    # fusion body parameters are parameter(i) in order of operands
    params = [o.name for o in body.ops if o.kind == "parameter"]
    total = float(op.result_bytes)
    # map body param order by the index in its definition order
    for i, arg in enumerate(operands):
        full = 0
        sliced = None
        if i < len(params):
            full, sliced = body.param_reads.get(params[i], (0, None))
        if sliced is not None and sliced < full:
            total += sliced
        else:
            total += full
    return total


# Backwards-compatible collective-only interface ----------------------------


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, float]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    def as_dict(self) -> dict:
        return {"bytes_by_kind": dict(self.bytes_by_kind),
                "count_by_kind": dict(self.count_by_kind),
                "total_bytes": self.total_bytes}


def collective_stats(hlo_text: str, **_kw) -> CollectiveStats:
    s = analyze(hlo_text)
    return CollectiveStats(bytes_by_kind=dict(s.bytes_by_kind),
                           count_by_kind=dict(s.count_by_kind))
