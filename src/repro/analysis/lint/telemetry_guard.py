"""telemetry-guard: every hub event call must sit behind an ``enabled``
check.

The instrumentation convention (ROADMAP "Observability"): the hot
paths — ``serve_loop.py``, ``cluster_loop.py``, ``runtime.py`` — hold
a hub reference (``self.tele``, defaulting to ``NULL_HUB``) and guard
every event emission with ``if self.tele.enabled:`` so the disabled
path costs exactly one attribute test, never a method call with
argument construction.  This checker makes the convention mechanical:
any call through a hub-ish receiver (``tele`` / ``telemetry`` / ``hub``
/ ``_hub``, or a local alias assigned from one) in those three files
must be *dominated* by an ``.enabled`` check — either an enclosing
``if``/ternary whose test reads ``.enabled`` (with the call on the
true path), or an earlier early-return guard in the same function
(``if not t.enabled: return``).

The hub's own methods (core/telemetry.py) are out of scope by
construction — the hub may call itself freely; the guard discipline is
for its callers.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Optional, Set

from .astutil import contains_attr, dotted, on_body_path
from .framework import Checker, FileContext, register

SCOPED_FILES = {"serve_loop.py", "cluster_loop.py", "runtime.py"}
HUB_NAMES = {"tele", "telemetry", "hub", "_hub"}


def _hubish(node: ast.AST, aliases: Set[str]) -> bool:
    parts = dotted(node)
    if not parts:
        return False
    return parts[-1] in HUB_NAMES or (len(parts) == 1
                                      and parts[0] in aliases)


def _is_terminal(stmt: ast.stmt) -> bool:
    return isinstance(stmt, (ast.Return, ast.Raise, ast.Continue, ast.Break))


@register
class TelemetryGuardChecker(Checker):
    name = "telemetry-guard"
    description = ("hub event calls in serve_loop/cluster_loop/runtime "
                   "must be dominated by an .enabled check")
    contract = ("NULL_HUB convention: the disabled telemetry path costs "
                "one attribute test, never an event-call's argument "
                "construction")

    def __init__(self):
        super().__init__()
        self._alias_cache = {}

    def _aliases(self, fn) -> Set[str]:
        """Local names assigned from a hub-ish expression inside ``fn``
        (``t = self.tele`` makes ``t`` hub-ish for the function)."""
        if fn is None:
            return set()
        cached = self._alias_cache.get(id(fn))
        if cached is not None:
            return cached
        out: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                parts = dotted(node.value)
                if parts and parts[-1] in HUB_NAMES:
                    out.add(node.targets[0].id)
        self._alias_cache[id(fn)] = out
        return out

    def visit_Call(self, node: ast.Call, ctx: FileContext):
        if Path(ctx.path).name not in SCOPED_FILES:
            return
        func = node.func
        if not isinstance(func, ast.Attribute):
            return
        fn = ctx.enclosing_function()
        aliases = self._aliases(fn)
        if not _hubish(func.value, aliases):
            return
        if self._dominated(node, ctx, fn):
            return
        recv = ".".join(dotted(func.value) or ("<hub>",))
        self.report_node(
            ctx, node,
            f"{recv}.{func.attr}(...) is not dominated by an .enabled "
            f"check — wrap it in 'if {recv}.enabled:' (or add an early "
            f"'if not {recv}.enabled: return') so the disabled path stays "
            f"one attribute test")

    def _dominated(self, node: ast.Call, ctx: FileContext, fn) -> bool:
        # 1. enclosing if/ternary testing .enabled, call on the true path
        for anc in ctx.ancestors:
            if isinstance(anc, ast.If) and contains_attr(anc.test, "enabled"):
                if on_body_path(ctx.ancestors, node, anc):
                    return True
            if isinstance(anc, ast.IfExp) \
                    and contains_attr(anc.test, "enabled"):
                return True
        # 2. earlier early-return guard in the same function:
        #    if not <...>.enabled: return/raise/continue/break
        if fn is None:
            return False
        for stmt in fn.body:
            if stmt.lineno >= node.lineno:
                break
            if isinstance(stmt, ast.If) \
                    and isinstance(stmt.test, ast.UnaryOp) \
                    and isinstance(stmt.test.op, ast.Not) \
                    and contains_attr(stmt.test.operand, "enabled") \
                    and stmt.body and all(_is_terminal(s)
                                          for s in stmt.body):
                return True
        return False
