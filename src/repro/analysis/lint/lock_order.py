"""lock-order: the cluster -> drive -> leaf/hub acquisition order.

The runtime's documented locking contract (ROADMAP "Concurrency"):

  * the coordinator takes the cluster lock (``ClusterEngine._lock``)
    first, then at most one drive lock (``_Drive.lock``);
  * workers take only their own drive lock;
  * the telemetry hub lock is terminal — callers call into the hub,
    the hub never calls back out while holding its lock.

Lock *domains* are classified from the acquired expression's attribute
name plus the file it lives in: an attribute literally named ``lock``
is a drive lock; ``_lock`` in ``cluster_loop.py`` is the cluster lock;
``_lock`` in ``telemetry.py`` is the hub lock; any other ``*_lock`` is
a leaf (terminal, nothing nests inside it).  Domains are ordered
cluster(0) < drive(1) < leaf(2) = hub(2): an acquisition is legal only
if its level is strictly greater than every lock already held — except
*re-entrance*: re-acquiring the same lock is legal when that lock is
statically known to be an ``threading.RLock`` (the checker records
``self.x = threading.RLock()`` assignments and ``x: threading.RLock``
class annotations).  That covers the two documented re-entrant paths:
the coordinator holding the cluster RLock calls ``fail`` which
re-enters it, and ``Router.pick`` -> ``home`` re-enters the router
RLock.  Re-entering a plain ``Lock`` the same way is a real deadlock
and is flagged.

Analysis is interprocedural but deliberately conservative: each
function's direct acquisitions are recorded with the lexically-held
locks, every call made under a lock is recorded, a may-acquire set is
propagated to a fixpoint over the resolvable call graph, and a call
under lock H to a function that may acquire A is flagged when A is not
allowed under H.  Calls resolve only when unambiguous — bare names to
same-module functions, ``self.m()`` to the enclosing class, and
``obj.m()`` only when exactly one analyzed class defines ``m`` —
anything ambiguous is skipped (false negatives over false positives).

The no-callbacks-out rule: while the hub lock is held, calling a bare
name that is a *parameter* of the enclosing function (i.e. an injected
callback) is flagged — that is exactly the shape that lets user code
re-enter the hub and deadlock.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from .astutil import dotted, func_params
from .framework import Checker, FileContext, register

CLUSTER, DRIVE, LEAF, HUB = "cluster", "drive", "leaf", "hub"
LEVEL = {CLUSTER: 0, DRIVE: 1, LEAF: 2, HUB: 2}

_RLOCK_CTORS = {("threading", "RLock"), ("RLock",)}


def classify_lock(path: str, expr: ast.AST) -> Optional[str]:
    """Map an acquired expression to a lock domain, or None if the
    expression does not look like a lock at all."""
    parts = dotted(expr)
    name = parts[-1] if parts else None
    if name is None:
        return None
    if name == "lock":
        return DRIVE
    if not name.endswith("_lock"):
        return None
    base = Path(path).name
    if name == "_lock":
        if base == "cluster_loop.py":
            return CLUSTER
        if base == "telemetry.py":
            return HUB
    return LEAF


@register
class LockOrderChecker(Checker):
    name = "lock-order"
    description = ("lock acquisitions must follow cluster -> drive -> "
                   "leaf/hub; the hub never calls out under its lock")
    contract = ("ROADMAP Concurrency: coordinator takes cluster then "
                "drive; workers take only their drive lock; hub lock "
                "is terminal (caller->hub, no callbacks out)")

    def __init__(self):
        super().__init__()
        # func key -> [(domain, identity, line, col, held_tuple)]
        self._acquires: Dict[Tuple, List] = {}
        # func key -> [(ref, line, col, held_tuple)]
        self._calls: Dict[Tuple, List] = {}
        self._module_defs: Dict[str, Dict[str, Tuple]] = {}
        self._class_methods: Dict[Tuple[str, str], Dict[str, Tuple]] = {}
        self._method_owners: Dict[str, List[Tuple]] = {}
        # lock identities (path, class-or-None, attr) built as RLock()
        self._reentrant: Set[Tuple] = set()
        self._reported: Set[Tuple] = set()

    # -- identities --------------------------------------------------------

    def _identity(self, ctx: FileContext, expr: ast.AST) -> Optional[Tuple]:
        """Stable identity for a lock expression when we can pin it to a
        definition site: ``self.x`` -> (path, EnclosingClass, x), a bare
        module-level name -> (path, None, name).  ``other.lock`` has no
        resolvable identity (None) and never matches for re-entrance."""
        parts = dotted(expr)
        if parts is None:
            return None
        if len(parts) == 2 and parts[0] == "self":
            cls = ctx.enclosing_class()
            if cls is not None:
                return (ctx.path, cls.name, parts[1])
            return None
        if len(parts) == 1:
            return (ctx.path, None, parts[0])
        return None

    def visit_Assign(self, node: ast.Assign, ctx: FileContext):
        if not (isinstance(node.value, ast.Call)
                and dotted(node.value.func) in _RLOCK_CTORS):
            return
        for target in node.targets:
            ident = self._identity(ctx, target)
            if ident is not None:
                self._reentrant.add(ident)

    def visit_AnnAssign(self, node: ast.AnnAssign, ctx: FileContext):
        # dataclass-style `lock: threading.RLock = field(...)` in a class
        if dotted(node.annotation) not in _RLOCK_CTORS:
            return
        cls = ctx.enclosing_class()
        if cls is not None and isinstance(node.target, ast.Name):
            self._reentrant.add((ctx.path, cls.name, node.target.id))

    def _allowed(self, held: Tuple, acquired: Tuple) -> bool:
        hdom, hident = held
        adom, aident = acquired
        if hident is not None and hident == aident \
                and hident in self._reentrant:
            return True            # re-entering a known RLock
        return LEVEL[adom] > LEVEL[hdom]

    # -- collection --------------------------------------------------------

    def _func_key(self, ctx: FileContext, extra: ast.AST = None):
        """Identity of the innermost enclosing function: (path, class
        qualname-or-None, function qualname).  Nested defs get their own
        key (their acquisitions are not their parent's)."""
        names, cls = [], None
        chain = list(ctx.ancestors) + ([extra] if extra is not None else [])
        for node in chain:
            if isinstance(node, ast.ClassDef):
                cls = node.name
                names = []            # methods key under their class
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                names.append(node.name)
        if not names:
            return (ctx.path, cls, "<module>")
        return (ctx.path, cls, ".".join(names))

    def _held(self, ctx: FileContext, node: ast.AST) -> List[Tuple]:
        """(domain, identity) of every lock lexically held at ``node``:
        each ancestor ``with`` whose path continues through its *body*
        (not the context expression itself)."""
        held = []
        chain = list(ctx.ancestors) + [node]
        for i, anc in enumerate(chain[:-1]):
            if not isinstance(anc, ast.With):
                continue
            child = chain[i + 1]
            in_body = any(child is stmt or
                          any(n is child for n in ast.walk(stmt))
                          for stmt in anc.body)
            if not in_body:
                continue
            for item in anc.items:
                dom = classify_lock(ctx.path, item.context_expr)
                if dom is not None:
                    held.append((dom, self._identity(ctx,
                                                     item.context_expr)))
        return held

    def visit_FunctionDef(self, node, ctx: FileContext):
        key = self._func_key(ctx, extra=node)
        self._acquires.setdefault(key, [])
        self._calls.setdefault(key, [])
        cls = ctx.enclosing_class()
        fn = ctx.enclosing_function()
        if fn is None:                      # top-level def or direct method
            if cls is None:
                self._module_defs.setdefault(ctx.path, {})[node.name] = key
            else:
                self._class_methods.setdefault(
                    (ctx.path, cls.name), {})[node.name] = key
                self._method_owners.setdefault(node.name, []).append(key)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_With(self, node: ast.With, ctx: FileContext):
        key = self._func_key(ctx)
        held = self._held(ctx, node)
        for item in node.items:
            dom = classify_lock(ctx.path, item.context_expr)
            if dom is None:
                continue
            ident = self._identity(ctx, item.context_expr)
            self._acquires.setdefault(key, []).append(
                (dom, ident, item.context_expr.lineno,
                 item.context_expr.col_offset, tuple(held)))
            held = held + [(dom, ident)]  # later items in this `with` nest

    def visit_Call(self, node: ast.Call, ctx: FileContext):
        key = self._func_key(ctx)
        held = self._held(ctx, node)
        func = node.func
        # explicit .acquire() counts as taking the lock
        if isinstance(func, ast.Attribute) and func.attr == "acquire":
            dom = classify_lock(ctx.path, func.value)
            if dom is not None:
                self._acquires.setdefault(key, []).append(
                    (dom, self._identity(ctx, func.value), node.lineno,
                     node.col_offset, tuple(held)))
                return
        ref = None
        if isinstance(func, ast.Name):
            ref = ("bare", func.id)
        elif isinstance(func, ast.Attribute):
            base = dotted(func.value)
            if base == ("self",):
                cls = ctx.enclosing_class()
                ref = ("self", cls.name if cls else None, func.attr)
            else:
                ref = ("attr", func.attr)
        if ref is not None:
            self._calls.setdefault(key, []).append(
                (ref, node.lineno, node.col_offset, tuple(held)))
        # no-callbacks-out: a bare-name call to a parameter of the
        # enclosing function while the hub lock is held
        if any(dom == HUB for dom, _ in held) and isinstance(func, ast.Name):
            fn = ctx.enclosing_function()
            if fn is not None and func.id in func_params(fn):
                self.report_node(
                    ctx, node,
                    f"call to injected callback {func.id!r} while holding "
                    f"the hub lock — the hub must never call out under its "
                    f"lock (caller->hub only)")

    # -- cross-file analysis ----------------------------------------------

    def _resolve(self, caller_key: Tuple, ref: Tuple) -> Optional[Tuple]:
        path = caller_key[0]
        if ref[0] == "bare":
            return self._module_defs.get(path, {}).get(ref[1])
        if ref[0] == "self":
            _, cls, meth = ref
            if cls is None:
                return None
            return self._class_methods.get((path, cls), {}).get(meth)
        # obj.m(): only when exactly one analyzed class defines m
        owners = self._method_owners.get(ref[1], [])
        return owners[0] if len(owners) == 1 else None

    def finish(self):
        # direct out-of-order acquisitions
        for key, acqs in self._acquires.items():
            for dom, ident, line, col, held in acqs:
                for h in held:
                    if not self._allowed(h, (dom, ident)):
                        self._emit(key[0], line, col,
                                   f"{dom} lock acquired while holding the "
                                   f"{h[0]} lock — order is cluster -> "
                                   f"drive -> leaf/hub")
        # may-acquire fixpoint over the resolvable call graph
        may: Dict[Tuple, Set[Tuple]] = {
            key: {(dom, ident) for dom, ident, *_ in acqs}
            for key, acqs in self._acquires.items()}
        edges: Dict[Tuple, Set[Tuple]] = {}
        for key, calls in self._calls.items():
            for ref, _line, _col, _held in calls:
                callee = self._resolve(key, ref)
                if callee is not None and callee != key:
                    edges.setdefault(key, set()).add(callee)
        changed = True
        while changed:
            changed = False
            for key, callees in edges.items():
                cur = may.setdefault(key, set())
                for callee in callees:
                    extra = may.get(callee, set()) - cur
                    if extra:
                        cur |= extra
                        changed = True
        # calls under a lock into functions that may acquire a lower domain
        for key, calls in self._calls.items():
            for ref, line, col, held in calls:
                if not held:
                    continue
                callee = self._resolve(key, ref)
                if callee is None:
                    continue
                for acq in sorted(may.get(callee, ()),
                                  key=lambda a: (a[0], str(a[1]))):
                    for h in held:
                        if self._allowed(h, acq):
                            continue
                        name = ref[-1]
                        self._emit(key[0], line, col,
                                   f"call to {name!r} (may acquire the "
                                   f"{acq[0]} lock) while holding the "
                                   f"{h[0]} lock — order is cluster -> "
                                   f"drive -> leaf/hub")

    def _emit(self, path, line, col, message):
        dedup = (path, line, col, message)
        if dedup not in self._reported:
            self._reported.add(dedup)
            self.report_at(path, line, col, message)
