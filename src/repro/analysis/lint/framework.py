"""Core machinery of the invariant linter: checkers, registry, runner.

The linter is one AST walk per file feeding every registered checker.
A checker is a class with ``visit_<NodeType>`` methods (the walker
dispatches by node type name, like ``ast.NodeVisitor`` but with a
shared walk so N rules cost one traversal), plus three lifecycle
hooks:

  * ``start_file(ctx)`` / ``finish_file(ctx)`` — per-file state;
  * ``finish()`` — after ALL files, for cross-file rules (the
    lock-order checker builds its acquisition graph here).

``FileContext`` carries the parsed tree, the raw source, and the
*ancestor path* of the node currently being visited — checkers use it
for domination questions ("is this call inside an ``if hub.enabled``
body?", "which locks are lexically held here?") without maintaining
their own stacks.

Diagnostics are suppressible per line with ``# lint: disable=RULE`` (or
``RULE1,RULE2``).  Suppressions are first-class: every disable comment
is counted per rule (``Report.suppression_sites``) whether or not a
diagnostic fired on that line, and the committed ``LINT_BASELINE.json``
pins those counts — adding a suppression without updating the baseline
fails CI, so silencing a rule is always a reviewed decision.
"""
from __future__ import annotations

import ast
import io
import json
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Type

ERROR = "error"
WARNING = "warning"
SEVERITIES = (ERROR, WARNING)

_SUPPRESS_RE = re.compile(r"lint:\s*disable=([A-Za-z0-9_,\- ]+)")


@dataclass(frozen=True)
class Diagnostic:
    """One finding: file/line/col, the rule id, severity, message."""
    path: str
    line: int
    col: int
    rule: str
    severity: str
    message: str

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.severity}: "
                f"[{self.rule}] {self.message}")

    def to_json(self) -> dict:
        return {"path": self.path, "line": self.line, "col": self.col,
                "rule": self.rule, "severity": self.severity,
                "message": self.message}


class FileContext:
    """Parsed state of one file plus the live ancestor path of the walk."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=path)
        # {line -> set(rule ids)} from "# lint: disable=..." comments,
        # found via tokenize so string literals can't fake a suppression
        self.suppressions: Dict[int, set] = {}
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type != tokenize.COMMENT:
                continue
            m = _SUPPRESS_RE.search(tok.string)
            if m:
                rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
                self.suppressions.setdefault(tok.start[0], set()).update(rules)
        # maintained by the walker: ancestors[0] is the Module, the last
        # element is the direct parent of the node being visited
        self.ancestors: List[ast.AST] = []

    # -- ancestor conveniences (valid during visit_* callbacks) -----------

    def parent(self) -> Optional[ast.AST]:
        return self.ancestors[-1] if self.ancestors else None

    def enclosing(self, *types) -> Optional[ast.AST]:
        for node in reversed(self.ancestors):
            if isinstance(node, types):
                return node
        return None

    def enclosing_function(self):
        return self.enclosing(ast.FunctionDef, ast.AsyncFunctionDef)

    def enclosing_class(self) -> Optional[ast.ClassDef]:
        return self.enclosing(ast.ClassDef)

    def path_pairs(self) -> Iterable[Tuple[ast.AST, ast.AST]]:
        """(ancestor, child-on-path) pairs, outermost first.  The child of
        the last ancestor is the node currently being visited, which the
        caller appends itself."""
        return zip(self.ancestors, self.ancestors[1:])


@dataclass
class Report:
    """Everything one lint run produced."""
    diagnostics: List[Diagnostic] = field(default_factory=list)
    suppressed: List[Diagnostic] = field(default_factory=list)
    # rule id -> number of "# lint: disable" comment sites naming it,
    # counted whether or not a diagnostic fired there (the committed
    # baseline pins these, so they must be stable across clean runs)
    suppression_sites: Dict[str, int] = field(default_factory=dict)
    files: List[str] = field(default_factory=list)

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == ERROR]

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == WARNING]

    def to_json(self) -> dict:
        return {
            "files": len(self.files),
            "errors": len(self.errors),
            "warnings": len(self.warnings),
            "diagnostics": [d.to_json() for d in self.diagnostics],
            "suppressed": [d.to_json() for d in self.suppressed],
            "suppression_sites": dict(sorted(
                self.suppression_sites.items())),
        }


class Checker:
    """Base class for one lint rule.

    Subclasses set ``name`` (the rule id used in diagnostics, CLI
    ``--rules`` filters and ``# lint: disable=`` comments),
    ``description`` and ``contract`` (the documented invariant the rule
    enforces), define ``visit_<NodeType>`` methods, and call
    ``self.report_node(ctx, node, message)``.  Cross-file rules collect
    state during the walk and emit from ``finish()`` via
    ``self.report_at(path, line, col, message)``.
    """

    name: str = ""
    description: str = ""
    contract: str = ""
    severity: str = ERROR

    def __init__(self):
        self._sink = None          # bound by the runner

    # lifecycle ------------------------------------------------------------
    def start_file(self, ctx: FileContext) -> None:
        pass

    def finish_file(self, ctx: FileContext) -> None:
        pass

    def finish(self) -> None:
        pass

    # dispatch -------------------------------------------------------------
    def visit(self, node: ast.AST, ctx: FileContext) -> None:
        method = getattr(self, "visit_" + type(node).__name__, None)
        if method is not None:
            method(node, ctx)

    # reporting ------------------------------------------------------------
    def report_node(self, ctx: FileContext, node: ast.AST, message: str,
                    severity: Optional[str] = None) -> None:
        self.report_at(ctx.path, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), message, severity)

    def report_at(self, path: str, line: int, col: int, message: str,
                  severity: Optional[str] = None) -> None:
        self._sink.add(Diagnostic(path=path, line=line, col=col,
                                  rule=self.name,
                                  severity=severity or self.severity,
                                  message=message))


# -- registry ---------------------------------------------------------------

_REGISTRY: Dict[str, Type[Checker]] = {}


def register(cls: Type[Checker]) -> Type[Checker]:
    """Class decorator adding a checker to the default rule set."""
    if not cls.name:
        raise ValueError(f"checker {cls.__name__} has no rule name")
    if cls.name in _REGISTRY and _REGISTRY[cls.name] is not cls:
        raise ValueError(f"duplicate rule id {cls.name!r}")
    _REGISTRY[cls.name] = cls
    return cls


def all_rules() -> Dict[str, Type[Checker]]:
    # rule modules register on import; import here to avoid a cycle
    from . import banned_api, fault_purity, jit_purity  # noqa: F401
    from . import lock_order, telemetry_guard           # noqa: F401
    return dict(sorted(_REGISTRY.items()))


# -- runner -----------------------------------------------------------------

class _Sink:
    """Routes a diagnostic to the report, honoring line suppressions."""

    def __init__(self, report: Report):
        self.report = report
        self._supp: Dict[str, Dict[int, set]] = {}

    def register_file(self, ctx: FileContext) -> None:
        self._supp[ctx.path] = ctx.suppressions
        for rules in ctx.suppressions.values():
            for rule in rules:
                self.report.suppression_sites[rule] = \
                    self.report.suppression_sites.get(rule, 0) + 1

    def add(self, diag: Diagnostic) -> None:
        rules = self._supp.get(diag.path, {}).get(diag.line, set())
        if diag.rule in rules:
            self.report.suppressed.append(diag)
        else:
            self.report.diagnostics.append(diag)


def iter_python_files(paths: Sequence[str]) -> List[Path]:
    """Expand files/directories into a sorted, deduplicated .py list."""
    out = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(f for f in path.rglob("*.py")
                              if not any(part.startswith(".")
                                         for part in f.parts)))
        elif path.suffix == ".py":
            out.append(path)
    seen, files = set(), []
    for f in out:
        key = str(f)
        if key not in seen:
            seen.add(key)
            files.append(f)
    return files


def run_lint(paths: Sequence[str],
             rules: Optional[Sequence[str]] = None) -> Report:
    """Lint ``paths`` (files or directories) with the selected rules
    (default: every registered rule).  Returns the full ``Report``;
    callers decide the exit code (see ``cli.main``)."""
    registry = all_rules()
    if rules:
        unknown = sorted(set(rules) - set(registry))
        if unknown:
            raise ValueError(f"unknown rule ids: {unknown}; known: "
                             f"{sorted(registry)}")
        registry = {k: v for k, v in registry.items() if k in rules}
    report = Report()
    sink = _Sink(report)
    checkers = []
    for cls in registry.values():
        checker = cls()
        checker._sink = sink
        checkers.append(checker)

    for file in iter_python_files(paths):
        path = file.as_posix()
        try:
            source = file.read_text()
            ctx = FileContext(path, source)
        except (SyntaxError, UnicodeDecodeError) as e:
            line = getattr(e, "lineno", 1) or 1
            report.diagnostics.append(Diagnostic(
                path=path, line=line, col=0, rule="parse-error",
                severity=ERROR, message=f"cannot parse: {e}"))
            continue
        report.files.append(path)
        sink.register_file(ctx)
        for c in checkers:
            c.start_file(ctx)
        _walk(ctx.tree, ctx, checkers)
        for c in checkers:
            c.finish_file(ctx)
    for c in checkers:
        c.finish()
    report.diagnostics.sort(key=lambda d: (d.path, d.line, d.col, d.rule))
    return report


def _walk(node: ast.AST, ctx: FileContext, checkers: List[Checker]) -> None:
    for c in checkers:
        c.visit(node, ctx)
    ctx.ancestors.append(node)
    try:
        for child in ast.iter_child_nodes(node):
            _walk(child, ctx, checkers)
    finally:
        ctx.ancestors.pop()


# -- baseline ---------------------------------------------------------------

BASELINE_VERSION = 1


def baseline_payload(report: Report) -> dict:
    """The committed-baseline shape: per-rule suppression counts for
    EVERY registered rule (a rule with zero suppressions is pinned at 0,
    so the first suppression anyone adds shows up as a diff)."""
    rules = {}
    for name in all_rules():
        rules[name] = {
            "suppressions": int(report.suppression_sites.get(name, 0))}
    return {"version": BASELINE_VERSION, "rules": rules}


def check_baseline(report: Report, baseline: dict) -> List[str]:
    """Compare a run against a committed baseline.  Returns problem
    strings (empty = pass).  Fails on any suppression-count increase —
    decreases are fine (someone fixed a violation for real) but should
    be ratcheted into the baseline."""
    problems = []
    if not isinstance(baseline, dict) or "rules" not in baseline:
        return [f"baseline is not a {{'version', 'rules'}} payload"]
    pinned = baseline["rules"]
    for rule, n in sorted(report.suppression_sites.items()):
        allowed = int(pinned.get(rule, {}).get("suppressions", 0))
        if n > allowed:
            problems.append(
                f"rule {rule!r}: {n} suppression sites vs {allowed} in the "
                f"baseline — fix the violation or ratchet the baseline "
                f"with --write-baseline (reviewed)")
    return problems


def load_baseline(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
