"""jit-purity: functions handed to jax.jit / lax control flow / Pallas
must stay free of host-side effects.

A function traced by ``jax.jit``, ``lax.while_loop`` / ``scan`` /
``fori_loop``, or ``pl.pallas_call`` executes its Python body once at
trace time and never again — any host-side effect inside it (reading a
clock, printing, file I/O, taking a lock, emitting telemetry) either
silently runs once at trace time with a stale value baked into the
compiled graph, or crashes inside the Pallas lowering.  The serving
engines therefore keep all instrumentation OUTSIDE the jitted step
functions and pass data out through the carry.

The checker finds every traced-callable argument (lambda inline,
``functools.partial(f, ...)`` unwrapped, bare names resolved through
the enclosing scopes then module scope), walks it — recursing one
level into same-module callees — and flags calls to ``time.*``,
``print``/``open``/``input``/``breakpoint``, the ``os``/``io``/
``socket``/``subprocess``/``threading``/``random`` modules (NOT
``jax.random``), lock withs/acquires, and hub-ish telemetry
receivers.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .astutil import dotted
from .framework import Checker, FileContext, register
from .lock_order import classify_lock
from .telemetry_guard import HUB_NAMES

# dotted entry -> indices of traced callable arguments / keyword names
_ENTRIES: Dict[Tuple[str, ...], Tuple[Tuple[int, ...], Tuple[str, ...]]] = {
    ("jax", "jit"): ((0,), ("fun",)),
    ("jit",): ((0,), ("fun",)),
    ("jax", "pmap"): ((0,), ("fun",)),
    ("jax", "lax", "while_loop"): ((0, 1), ("cond_fun", "body_fun")),
    ("lax", "while_loop"): ((0, 1), ("cond_fun", "body_fun")),
    ("jax", "lax", "scan"): ((0,), ("f",)),
    ("lax", "scan"): ((0,), ("f",)),
    ("jax", "lax", "fori_loop"): ((2,), ("body_fun",)),
    ("lax", "fori_loop"): ((2,), ("body_fun",)),
    ("pl", "pallas_call"): ((0,), ("kernel",)),
    ("pallas_call",): ((0,), ("kernel",)),
    ("jax", "experimental", "pallas", "pallas_call"): ((0,), ("kernel",)),
}

_BANNED_BARE = {"print", "open", "input", "breakpoint"}
_BANNED_ROOTS = {"time", "os", "io", "socket", "subprocess", "threading",
                 "random"}


@register
class JitPurityChecker(Checker):
    name = "jit-purity"
    description = ("no host I/O, time.*, locks, or telemetry inside "
                   "functions traced by jax.jit/lax/*loop/pallas_call")
    contract = ("traced bodies run once at trace time; host effects bake "
                "stale values into the compiled graph or break lowering")

    def __init__(self):
        super().__init__()
        self._seen_sites: Set[Tuple] = set()

    def visit_Call(self, node: ast.Call, ctx: FileContext):
        parts = dotted(node.func)
        if parts is None or parts not in _ENTRIES:
            return
        arg_idx, kw_names = _ENTRIES[parts]
        entry = ".".join(parts)
        traced: List[ast.AST] = []
        for i in arg_idx:
            if i < len(node.args):
                traced.append(node.args[i])
        for kw in node.keywords:
            if kw.arg in kw_names:
                traced.append(kw.value)
        for expr in traced:
            fn = self._resolve(expr, ctx)
            if fn is not None:
                self._check_pure(fn, ctx, entry, node.lineno, visited=set())

    # -- resolution --------------------------------------------------------

    def _resolve(self, expr: ast.AST, ctx: FileContext,
                 scopes: Optional[List[ast.AST]] = None):
        """Traced arg expr -> a Lambda/FunctionDef node, or None."""
        if isinstance(expr, ast.Lambda):
            return expr
        if isinstance(expr, ast.Call):
            parts = dotted(expr.func)
            if parts in (("functools", "partial"), ("partial",)) \
                    and expr.args:
                return self._resolve(expr.args[0], ctx, scopes)
            return None
        if isinstance(expr, ast.Name):
            bound = self._lookup(expr.id, ctx, scopes)
            if isinstance(bound, ast.Call):
                # name bound to functools.partial(f, ...): unwrap
                return self._resolve(bound, ctx, scopes)
            return bound
        return None

    def _lookup(self, name: str, ctx: FileContext,
                scopes: Optional[List[ast.AST]] = None):
        """Find what ``name`` is bound to — a def, a lambda, or a
        partial(...) call — searching enclosing function bodies
        innermost-first, then module scope."""
        if scopes is None:
            scopes = [a for a in ctx.ancestors
                      if isinstance(a, (ast.FunctionDef,
                                        ast.AsyncFunctionDef))]
        bodies = [fn.body for fn in reversed(scopes)] + [ctx.tree.body]
        for body in bodies:
            for stmt in body:
                if isinstance(stmt, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)) \
                        and stmt.name == name:
                    return stmt
                if isinstance(stmt, ast.Assign) \
                        and len(stmt.targets) == 1 \
                        and isinstance(stmt.targets[0], ast.Name) \
                        and stmt.targets[0].id == name \
                        and isinstance(stmt.value, (ast.Lambda, ast.Call)):
                    return stmt.value
        return None

    # -- purity walk -------------------------------------------------------

    def _check_pure(self, fn, ctx: FileContext, entry: str, entry_line: int,
                    visited: Set[int]):
        if id(fn) in visited:
            return
        visited.add(id(fn))
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for stmt in body:
            for node in ast.walk(stmt):
                self._check_node(node, ctx, entry, entry_line, visited)

    def _check_node(self, node, ctx, entry, entry_line, visited):
        if isinstance(node, ast.With):
            for item in node.items:
                if classify_lock(ctx.path, item.context_expr) is not None:
                    self._flag(ctx, item.context_expr, entry, entry_line,
                               "takes a lock")
            return
        if not isinstance(node, ast.Call):
            return
        parts = dotted(node.func)
        if parts is None:
            return
        if len(parts) == 1 and parts[0] in _BANNED_BARE:
            self._flag(ctx, node, entry, entry_line,
                       f"calls {parts[0]}()")
        elif len(parts) >= 2 and parts[0] in _BANNED_ROOTS:
            self._flag(ctx, node, entry, entry_line,
                       f"calls {'.'.join(parts)}()")
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr == "acquire" \
                and classify_lock(ctx.path, node.func.value) is not None:
            self._flag(ctx, node, entry, entry_line, "takes a lock")
        elif len(parts) >= 2 and parts[-2] in HUB_NAMES:
            self._flag(ctx, node, entry, entry_line,
                       f"emits telemetry ({'.'.join(parts)})")
        elif len(parts) == 1:
            # one level of same-module recursion: f() inside the traced
            # body drags f's effects into the trace too
            callee = self._lookup(parts[0], ctx, scopes=[])
            if isinstance(callee, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.Lambda)):
                self._check_pure(callee, ctx, entry, entry_line, visited)

    def _flag(self, ctx, node, entry, entry_line, what):
        site = (ctx.path, node.lineno, node.col_offset)
        if site in self._seen_sites:
            return
        self._seen_sites.add(site)
        self.report_node(
            ctx, node,
            f"{what} inside a function traced by {entry} (line "
            f"{entry_line}) — traced bodies run once at trace time and "
            f"must stay free of host-side effects")
