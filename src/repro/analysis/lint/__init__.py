"""repro.analysis.lint — AST-based invariant checker for the serving
stack.

The concurrent runtime's correctness rests on contracts that used to
live only in ROADMAP prose: the cluster->drive lock order, the
one-clock-per-track rule, worker-side fault-predicate purity, the
NULL_HUB ``enabled`` guard on every instrumentation site, and the
purity of everything handed to ``jax.jit``/Pallas.  This package turns
each of those into a CI-failing lint rule.

Run it::

    python -m repro.analysis.lint src/repro            # human output
    python -m repro.analysis.lint src/repro --json     # machine output
    python -m repro.analysis.lint --list-rules
    scripts/ci.sh lint                                 # the CI tier

Suppress a single finding with a trailing comment naming the rule —
and say why, because the committed ``LINT_BASELINE.json`` pins the
per-rule suppression counts and CI fails when they grow::

    marker.write_text(str(time.time()))  # persisted wall-clock stamp; lint: disable=banned-api

Adding a checker
----------------

A rule is a ``Checker`` subclass with ``visit_<NodeType>`` methods,
registered with the ``@register`` decorator and imported from
``framework.all_rules``::

    from .framework import Checker, FileContext, register

    @register
    class NoSleepChecker(Checker):
        name = "no-sleep"                       # rule id in diagnostics,
        description = "no time.sleep on ..."    #   --rules filters and
        contract = "ROADMAP section ..."        #   disable= comments

        def visit_Call(self, node, ctx: FileContext):
            if ...:
                self.report_node(ctx, node, "why this is wrong")

The framework runs ONE walk per file and dispatches each node to every
checker, maintaining ``ctx.ancestors`` (the path from the module node
to the current node's parent) so rules can answer lexical questions —
enclosing function/class, dominating ``if``, locks held — without
their own traversal state.  Per-file hooks ``start_file``/
``finish_file`` bracket the walk; cross-file rules (lock-order) buffer
sites and emit from ``finish()`` after every file has been seen.
Then: add the module to the imports in ``framework.all_rules``, give
it a fixture test in ``tests/test_lint.py`` (one positive, one
negative, one suppressed), and regenerate the baseline with
``--write-baseline`` if the sweep added suppressions.
"""
from .framework import (Checker, Diagnostic, FileContext, Report, all_rules,
                        baseline_payload, check_baseline, load_baseline,
                        register, run_lint)

__all__ = [
    "Checker", "Diagnostic", "FileContext", "Report", "all_rules",
    "baseline_payload", "check_baseline", "load_baseline", "register",
    "run_lint",
]
