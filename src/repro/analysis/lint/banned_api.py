"""banned-api: wall-clock time, unseeded module-level RNG, bare except.

Three bans, all grounded in prior sweeps:

  * ``time.time()`` — the PR 6 clock-domain sweep moved every interval
    measurement to ``time.perf_counter()``; wall-clock reads drift
    against the monotonic telemetry timebase.  Persisted wall-clock
    timestamps (checkpoint markers) are the one legitimate use and get
    a per-line suppression with a justifying comment.
  * module-level RNG in ``core/``/``train/`` — replayability of the
    cluster runtime depends on every random draw coming from a seeded
    generator (``random.Random(seed)`` / ``np.random.default_rng(seed)``);
    ``random.random()`` or ``np.random.uniform()`` pull from process
    globals and break token-identical replay.
  * bare ``except:`` — swallows ``KeyboardInterrupt``/``SystemExit``
    and hides worker-thread failures the watchdog relies on seeing.
"""
from __future__ import annotations

import ast
from pathlib import Path

from .astutil import dotted
from .framework import Checker, FileContext, register

# constructors/seeding entry points that are allowed at module scope
_SEEDED_RANDOM = {"Random", "SystemRandom", "seed", "getstate", "setstate"}
_SEEDED_NP = {"default_rng", "Generator", "SeedSequence", "RandomState",
              "PCG64", "Philox", "bit_generator"}


def _in_seeded_scope(path: str) -> bool:
    parts = Path(path).parts
    return "core" in parts or "train" in parts


@register
class BannedApiChecker(Checker):
    name = "banned-api"
    description = ("time.time(), unseeded module-level random/np.random "
                   "in core//train/, and bare except:")
    contract = ("ROADMAP clock-domain rule: one timebase per track, "
                "perf_counter for intervals; seeded generators only on "
                "the replayable core/train paths")

    def visit_Call(self, node: ast.Call, ctx: FileContext):
        parts = dotted(node.func)
        if parts is None:
            return
        if parts == ("time", "time"):
            self.report_node(
                ctx, node,
                "time.time() is banned — use time.perf_counter() for "
                "intervals; a persisted wall-clock timestamp needs a "
                "justified '# lint: disable=banned-api'")
            return
        if not _in_seeded_scope(ctx.path):
            return
        if parts[0] == "random" and len(parts) == 2 \
                and parts[1] not in _SEEDED_RANDOM:
            self.report_node(
                ctx, node,
                f"module-level random.{parts[1]}() draws from the process "
                f"global RNG — use a seeded random.Random(seed) instance")
        elif parts[0] in ("np", "numpy") and len(parts) >= 3 \
                and parts[1] == "random" and parts[2] not in _SEEDED_NP:
            self.report_node(
                ctx, node,
                f"{parts[0]}.random.{parts[2]}() draws from the numpy "
                f"global RNG — use np.random.default_rng(seed)")

    def visit_ExceptHandler(self, node: ast.ExceptHandler, ctx: FileContext):
        if node.type is None:
            self.report_node(
                ctx, node,
                "bare 'except:' swallows KeyboardInterrupt/SystemExit and "
                "hides worker failures — catch Exception (or narrower)")
