"""fault-purity: worker code may only call pure FaultSchedule predicates.

``FaultSchedule`` exposes two kinds of query (core/faults.py):

  * pure predicates — ``crash_active`` / ``hangs`` / ``stalled`` /
    ``slowdown`` / ``clamp`` — read-only, callable from anywhere;
  * delivered-set-mutating queries — ``begins`` / ``crashes`` — which
    record that the coordinator has *observed* the fault (each fires
    once per fault).  These are coordinator-only: if a worker thread
    consumed the one-shot delivery, the coordinator would never see the
    fault begin, and the chaos tests' ground truth would silently leak
    into the data path (the ground-truth-leak rule).

The rule is scoped to ``core/runtime.py`` — the drive-worker thread
body.  Any ``*.begins(...)`` / ``*.crashes(...)`` call there is an
error, as is any other non-pure method reached through a ``faults``
receiver (``self.faults.save(...)`` etc. — workers must not construct,
persist, or mutate schedules).
"""
from __future__ import annotations

import ast
from pathlib import Path

from .astutil import dotted
from .framework import Checker, FileContext, register

PURE_PREDICATES = {"crash_active", "hangs", "stalled", "slowdown", "clamp"}
MUTATING_QUERIES = {"begins", "crashes"}


@register
class FaultPurityChecker(Checker):
    name = "fault-purity"
    description = ("only pure FaultSchedule predicates may run on the "
                   "worker thread (core/runtime.py)")
    contract = ("ground-truth-leak rule: begins()/crashes() mutate the "
                "delivered set and are coordinator-only")

    def _in_scope(self, ctx: FileContext) -> bool:
        return Path(ctx.path).name == "runtime.py"

    def visit_Call(self, node: ast.Call, ctx: FileContext):
        if not self._in_scope(ctx):
            return
        func = node.func
        if not isinstance(func, ast.Attribute):
            return
        if func.attr in MUTATING_QUERIES:
            self.report_node(
                ctx, node,
                f"{func.attr}() mutates the fault schedule's delivered set "
                f"and is coordinator-only — worker code may call the pure "
                f"predicates only ({', '.join(sorted(PURE_PREDICATES))})")
            return
        parts = dotted(func.value)
        if parts and parts[-1] == "faults" \
                and func.attr not in PURE_PREDICATES:
            self.report_node(
                ctx, node,
                f"faults.{func.attr}() is not a pure predicate — worker "
                f"code may call only "
                f"{', '.join(sorted(PURE_PREDICATES))}")
