"""Command-line front end: ``python -m repro.analysis.lint``.

Exit status is the CI contract: 0 when there are no error-severity
diagnostics and the suppression counts are within the baseline (when
``--baseline`` is given); 1 otherwise.  ``--json`` emits the full
machine-readable report on stdout for tooling; the default output is
one ``path:line:col: severity: [rule] message`` line per finding.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from .framework import (all_rules, baseline_payload, check_baseline,
                        load_baseline, run_lint)


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="AST-based invariant checker for the serving stack")
    p.add_argument("paths", nargs="*", default=["src/repro"],
                   help="files or directories to lint (default: src/repro)")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit the full report as JSON on stdout")
    p.add_argument("--rules", default=None,
                   help="comma-separated rule ids to run (default: all)")
    p.add_argument("--list-rules", action="store_true",
                   help="list registered rules and exit")
    p.add_argument("--baseline", default=None, metavar="FILE",
                   help="fail if per-rule suppression counts exceed FILE")
    p.add_argument("--write-baseline", default=None, metavar="FILE",
                   help="write the current suppression counts to FILE")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for name, cls in all_rules().items():
            print(f"{name}: {cls.description}")
        return 0
    rules = [r.strip() for r in args.rules.split(",")] if args.rules else None
    try:
        report = run_lint(args.paths or ["src/repro"], rules=rules)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    problems: List[str] = []
    baseline_ok = True
    if args.baseline:
        try:
            baseline = load_baseline(args.baseline)
        except (OSError, json.JSONDecodeError) as e:
            problems.append(f"cannot load baseline {args.baseline}: {e}")
            baseline_ok = False
        else:
            problems = check_baseline(report, baseline)
            baseline_ok = not problems

    if args.write_baseline:
        with open(args.write_baseline, "w") as f:
            json.dump(baseline_payload(report), f, indent=2, sort_keys=True)
            f.write("\n")

    failed = bool(report.errors) or not baseline_ok
    if args.as_json:
        payload = report.to_json()
        payload["baseline_ok"] = baseline_ok
        payload["baseline_problems"] = problems
        payload["ok"] = not failed
        print(json.dumps(payload, indent=2))
    else:
        for d in report.diagnostics:
            print(d.format())
        for p in problems:
            print(f"baseline: {p}")
        n_err, n_warn = len(report.errors), len(report.warnings)
        print(f"{len(report.files)} files, {n_err} errors, {n_warn} "
              f"warnings, {len(report.suppressed)} suppressed")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
