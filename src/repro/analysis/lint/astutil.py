"""Small AST helpers shared by the checkers."""
from __future__ import annotations

import ast
from typing import Iterable, Optional, Tuple


def dotted(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """The segments of a Name/Attribute chain: ``self.tele.counter`` ->
    ('self', 'tele', 'counter').  None for anything that isn't a plain
    dotted chain (subscripts, calls in the middle, ...)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def dotted_str(node: ast.AST) -> Optional[str]:
    parts = dotted(node)
    return ".".join(parts) if parts else None


def call_name(call: ast.Call) -> Optional[str]:
    """The rightmost segment of the called expression, or None."""
    if isinstance(call.func, ast.Attribute):
        return call.func.attr
    if isinstance(call.func, ast.Name):
        return call.func.id
    return None


def contains_attr(node: ast.AST, attr: str) -> bool:
    """True if any Attribute access named ``attr`` appears under node."""
    return any(isinstance(n, ast.Attribute) and n.attr == attr
               for n in ast.walk(node))


def on_body_path(ancestors, node: ast.AST, owner: ast.If) -> bool:
    """True if ``node`` sits inside ``owner.body`` (not orelse/test),
    given the walk's ancestor path.  ``ancestors`` must contain
    ``owner``; the element after it (or ``node`` itself) is the child
    the path descends through."""
    try:
        i = ancestors.index(owner)
    except ValueError:
        return False
    child = ancestors[i + 1] if i + 1 < len(ancestors) else node
    return any(child is stmt or _contains(stmt, child)
               for stmt in owner.body)


def _contains(tree: ast.AST, node: ast.AST) -> bool:
    return any(n is node for n in ast.walk(tree))


def func_params(fn) -> set:
    """All parameter names of a FunctionDef/AsyncFunctionDef/Lambda."""
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return set(names)


def iter_withitems(node: ast.With) -> Iterable[ast.withitem]:
    return node.items
