"""Recompute roofline terms for existing dry-run JSONs from their cached
HLO text (used when the analyzer improves — no recompilation needed).

  PYTHONPATH=src python -m repro.analysis.reanalyze [results/dryrun]
"""
from __future__ import annotations

import json
import pathlib
import sys

from repro.analysis.roofline import from_hlo_text, model_flops_for
from repro.analysis.top_ops import load_hlo
from repro.config import get_config, get_shape


def main():
    root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
    for j in sorted(root.glob("*.json")):
        h = j.with_suffix("").with_suffix("")  # strip .json
        hlo = root / (j.stem + ".hlo.zst")
        if not hlo.exists():
            continue
        d = json.loads(j.read_text())
        if d.get("status") != "ok":
            continue
        cfg = get_config(d["arch"])
        shape = get_shape(d["shape"])
        rf = from_hlo_text(load_hlo(hlo), d["chips"],
                           model_flops_for(cfg, shape))
        d["roofline"] = rf.as_dict()
        j.write_text(json.dumps(d, indent=2, default=str))
        print(f"{j.stem}: compute={rf.compute_s:.3f}s memory={rf.memory_s:.3f}s "
              f"collective={rf.collective_s:.3f}s dom={rf.dominant} MFU={rf.mfu:.1%}")


if __name__ == "__main__":
    main()
