"""Hillclimbing diagnostics: rank ops by trip-weighted HBM traffic /
collective bytes / dot flops from a cached dry-run HLO.

  PYTHONPATH=src python -m repro.analysis.top_ops \
      results/dryrun/llama3-405b__train_4k__pod.hlo.zst --kind mem -n 20
"""
from __future__ import annotations

import argparse
import pathlib
import re
import sys
from collections import defaultdict

import zstandard

from repro.analysis.hlo import parse_module, _weights


def load_hlo(path) -> str:
    raw = pathlib.Path(path).read_bytes()
    if str(path).endswith(".zst"):
        return zstandard.ZstdDecompressor().decompress(raw, max_output_size=2_000_000_000).decode()
    return raw.decode()


def top_ops(hlo_text: str, kind: str = "mem", n: int = 20):
    comps = parse_module(hlo_text)
    weights = _weights(comps)
    rows = []
    for name, comp in comps.items():
        wt = weights.get(name, 1.0)
        if wt == 0:
            continue
        agg = defaultdict(lambda: [0.0, 0])   # opkind -> [value, count]
        for op in comp.ops:
            if kind == "mem":
                val = wt * (op.result_bytes + op.operand_bytes)
                if op.kind in ("parameter", "constant", "get-tuple-element",
                               "tuple", "bitcast", "while", "iota"):
                    continue
            elif kind == "coll":
                val = wt * op.wire_bytes
                if not op.coll_kind:
                    continue
            else:
                val = wt * op.flops
                if not op.flops:
                    continue
            agg[op.kind][0] += val
            agg[op.kind][1] += 1
        for k, (v, c) in agg.items():
            if v:
                rows.append((v, name, k, c, wt))
    rows.sort(reverse=True)
    return rows[:n]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--kind", default="mem", choices=["mem", "coll", "flops"])
    ap.add_argument("-n", type=int, default=20)
    args = ap.parse_args()
    txt = load_hlo(args.path)
    unit = {"mem": "GB", "coll": "GB", "flops": "GFLOP"}[args.kind]
    for v, comp, opkind, cnt, wt in top_ops(txt, args.kind, args.n):
        print(f"{v/1e9:12.2f} {unit:6s} {opkind:20s} x{cnt:<5d} w={wt:<8.0f} {comp[:70]}")


if __name__ == "__main__":
    main()
