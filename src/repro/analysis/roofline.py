"""Three-term roofline from a compiled dry-run artifact.

All terms are PER-DEVICE, derived from the post-SPMD per-device HLO module
via the trip-aware parser in ``repro.analysis.hlo`` (XLA's own
``cost_analysis`` ignores while-loop trip counts — verified; we keep its
numbers in the JSON for reference but never use them):

  compute term    = dot FLOPs / peak MXU FLOP/s   (+ elementwise / VPU)
  memory term     = HBM bytes (fusion granularity) / HBM bandwidth
  collective term = collective wire bytes / ICI link bandwidth

Hardware model: TPU v5e — 197 TFLOP/s bf16 MXU, 819 GB/s HBM, ~50 GB/s/link.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.analysis.hlo import HloStats, analyze

PEAK_FLOPS = 197e12          # bf16 MXU per chip
PEAK_VPU = 12e12             # rough VPU elementwise ops/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


@dataclass
class Roofline:
    dot_flops: float                 # per-device
    elementwise_flops: float         # per-device
    hbm_bytes: float                 # per-device
    collective_bytes: float          # per-device wire bytes
    chips: int
    model_flops: float = 0.0         # 6·N·D (analytic, useful work, GLOBAL)
    bytes_by_kind: Dict[str, float] = field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.dot_flops / PEAK_FLOPS + self.elementwise_flops / PEAK_VPU

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step time (max of terms — perfectly-overlapped model)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO dot FLOPs — remat/redundancy waste detector."""
        total = self.dot_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model flops utilization at the roofline step time."""
        if not self.model_flops or not self.step_s:
            return 0.0
        return self.model_flops / (self.step_s * self.chips * PEAK_FLOPS)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, dominant=self.dominant,
                 step_s=self.step_s, useful_flops_ratio=self.useful_flops_ratio,
                 mfu=self.mfu)
        return d


def from_hlo_text(hlo_text: str, chips: int, model_flops: float = 0.0) -> Roofline:
    st = analyze(hlo_text)
    return Roofline(dot_flops=st.flops, elementwise_flops=st.elementwise_flops,
                    hbm_bytes=st.hbm_bytes, collective_bytes=st.collective_bytes,
                    chips=chips, model_flops=model_flops,
                    bytes_by_kind=dict(st.bytes_by_kind))


def from_compiled(compiled, chips: int, model_flops: float = 0.0,
                  hlo_text: Optional[str] = None) -> Roofline:
    text = hlo_text if hlo_text is not None else compiled.as_text()
    return from_hlo_text(text, chips, model_flops)


def model_flops_for(cfg, shape) -> float:
    """6·N·D for training, 2·N·D for inference (per step over `tokens`)."""
    from repro.models.model import count_flops_params
    n = count_flops_params(cfg, active_only=True)
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
