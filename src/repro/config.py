"""Configuration system for the repro framework.

Every architecture is described by a frozen ``ModelConfig`` dataclass; input
shapes by ``ShapeConfig``.  Configs are registered into a global registry so
launchers can select them with ``--arch <id> --shape <name>``.

The reduced ("smoke") variant of every architecture keeps the *family
structure* (block pattern, attention kind, MoE/SSM wiring) while shrinking
width/depth/vocab so a single CPU device can run a forward/train step.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttnConfig:
    """Attention flavour for a block.

    kind: "full" (causal), "local" (sliding window causal), "mla"
    (DeepSeek-style multi-head latent attention with compressed KV).
    """

    kind: str = "full"
    window: int = 1024            # sliding window (kind == "local")
    rope_base: float = 10_000.0
    rope_base_local: float = 10_000.0   # gemma3 uses a different base for local layers
    kv_lora_rank: int = 512       # MLA: compressed KV dim
    qk_rope_dim: int = 64         # MLA: rope sub-dim carried uncompressed
    qk_nope_dim: int = 128        # MLA: non-rope head dim
    v_head_dim: int = 128         # MLA: value head dim
    q_lora_rank: int = 0          # MLA: 0 = full-rank Q projection
    softmax_scale: Optional[float] = None


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 16
    num_shared_experts: int = 0
    top_k: int = 1
    d_ff_expert: int = 8192
    d_ff_shared: int = 0          # per shared expert; 0 → same as d_ff_expert
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Parameters shared by mamba-style SSM and xLSTM blocks."""

    state_dim: int = 16           # N: per-channel SSM state (mamba) / ignored by xlstm
    conv_width: int = 4           # depthwise conv width (mamba)
    expand: int = 2               # inner dim = expand * d_model (mamba, mLSTM)
    num_heads: int = 4            # recurrence heads (xlstm / hymba ssm heads)
    dt_rank: int = 0              # 0 → ceil(d_model / 16)
    chunk_size: int = 128         # chunked-parallel scan block (mLSTM / mamba train)
    slstm_proj_factor: float = 4.0 / 3.0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 → d_model // num_heads
    # Block pattern, tiled to num_layers.  Entries:
    #   "attn"   full attention + MLP
    #   "local"  sliding-window attention + MLP
    #   "mla"    MLA attention + MLP (dense or moe FFN per moe_layer_pattern)
    #   "moe"    attention + MoE FFN
    #   "hybrid" parallel attention + mamba heads, then MLP
    #   "mlstm" / "slstm"  xLSTM blocks
    block_pattern: Tuple[str, ...] = ("attn",)
    attn: AttnConfig = field(default_factory=AttnConfig)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    frontend: Optional[str] = None    # None | "audio" | "vlm"
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # training-time knobs
    remat: str = "dots"           # none | dots | full
    optimizer_state_dtype: str = "float32"
    grad_accum: int = 1           # microbatch accumulation (activation memory / N)
    attn_chunk: int = 512         # flash attention q/kv chunk (loop trip count)
    scan_group: int = 0           # 0 → len(block_pattern); layers scanned in groups
    # long-context capability: archs whose decode memory/compute stays bounded
    # (SSM/hybrid/local-attention).  Pure full-attention archs skip long_500k.
    subquadratic: bool = False
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def layer_pattern(self) -> Tuple[str, ...]:
        """block_pattern tiled to num_layers."""
        p = self.block_pattern
        reps = -(-self.num_layers // len(p))
        return (p * reps)[: self.num_layers]

    @property
    def group_size(self) -> int:
        g = self.scan_group or len(self.block_pattern)
        assert self.num_layers % g == 0, (self.name, self.num_layers, g)
        return g

    def param_count(self) -> int:
        """Analytic parameter count (exact for our parameterization)."""
        from repro.models.model import count_params  # local import, avoids cycle
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params
        return count_params(self, active_only=True)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}
_REDUCERS: Dict[str, Callable[[ModelConfig], ModelConfig]] = {}


def register(cfg: ModelConfig, reducer: Optional[Callable[[ModelConfig], ModelConfig]] = None) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch config {cfg.name!r}")
    _REGISTRY[cfg.name] = cfg
    if reducer is not None:
        _REDUCERS[cfg.name] = reducer
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is a live cell; see DESIGN.md §5."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "skip(full-attn): long_500k requires sub-quadratic attention"
    return True, ""


def reduced_config(name_or_cfg) -> ModelConfig:
    """Smoke-test variant: same family wiring, tiny dims."""
    cfg = name_or_cfg if isinstance(name_or_cfg, ModelConfig) else get_config(name_or_cfg)
    if cfg.name in _REDUCERS:
        return _REDUCERS[cfg.name](cfg)
    return default_reducer(cfg)


def default_reducer(cfg: ModelConfig) -> ModelConfig:
    n_heads = min(cfg.num_heads, 4)
    n_kv = max(1, min(cfg.num_kv_heads, n_heads))
    head_dim = 16
    d_model = n_heads * head_dim
    moe = cfg.moe
    if moe is not None:
        moe = replace(
            moe,
            num_experts=min(moe.num_experts, 8),
            top_k=min(moe.top_k, 2),
            d_ff_expert=32,
            d_ff_shared=32 if moe.num_shared_experts else 0,
        )
    ssm = cfg.ssm
    if ssm is not None:
        ssm = replace(ssm, state_dim=min(ssm.state_dim, 8), num_heads=min(ssm.num_heads, 2),
                      chunk_size=16)
    pat = cfg.block_pattern
    num_layers = len(pat) if len(pat) > 1 else 2
    return replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=num_layers,
        d_model=d_model,
        num_heads=n_heads,
        num_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=4 * d_model if cfg.d_ff else 0,
        vocab_size=256,
        moe=moe,
        ssm=ssm,
        attn=replace(cfg.attn, window=32, kv_lora_rank=16, qk_rope_dim=8,
                     qk_nope_dim=head_dim, v_head_dim=head_dim),
        scan_group=0,
        remat="none",
        grad_accum=1,          # perf knobs don't survive reduction
        attn_chunk=32,
    )


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if not _LOADED:
        _LOADED = True
        from repro import configs  # noqa: F401  (registers everything)


# convenience for dataclass printing
def as_dict(cfg) -> dict:
    return dataclasses.asdict(cfg)
