"""Production training loop: data → jitted step → metrics → checkpoints.

Fault tolerance: restart-exact resume from the latest committed checkpoint
(params, optimizer, data step); async checkpoint every ``ckpt_every``;
SIGTERM/KeyboardInterrupt triggers a final synchronous save (preemption
handling).  Straggler mitigation: per-host step-time EMA feeds the paper's
batch-ratio rebalancer (``core.scheduler.rebalance_shares``) through the
loader's ``set_shares``.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.config import ModelConfig
from repro.core.scheduler import rebalance_shares
from repro.data import DataConfig, ShardedLoader, SyntheticTokenSource
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init
from repro.launch import steps as S
from repro.sharding import make_plan, make_recipe
from repro.config import ShapeConfig


@dataclass
class TrainConfig:
    steps: int = 100
    microbatch: int = 0              # 0 = no accumulation
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    seed: int = 0
    lr: float = 3e-4
    warmup: int = 20
    rebalance_every: int = 0         # 0 = off (single host)


@dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int


def build_state(cfg: ModelConfig, recipe, opt_cfg: AdamWConfig, seed: int):
    if recipe.mesh is not None:
        pspec = S.to_named(recipe, S.params_sharding(recipe, cfg))
        params = jax.jit(lambda k: M.init_params(cfg, k),
                         out_shardings=pspec)(jax.random.PRNGKey(seed))
        ospec = S.to_named(recipe, S.opt_sharding(recipe, cfg))
        opt = jax.jit(lambda p: adamw_init(p, opt_cfg),
                      out_shardings=ospec)(params)
    else:
        params = M.init_params(cfg, jax.random.PRNGKey(seed))
        opt = adamw_init(params, opt_cfg)
    return TrainState(params=params, opt_state=opt, step=0)


def train(cfg: ModelConfig, data_cfg: DataConfig, tcfg: TrainConfig,
          mesh=None, source=None,
          metrics_cb: Optional[Callable[[int, Dict], None]] = None) -> TrainState:
    shape = ShapeConfig("train", data_cfg.seq_len, data_cfg.global_batch, "train")
    plan = make_plan(mesh, cfg)
    recipe = make_recipe(plan, cfg, shape)
    opt_cfg = AdamWConfig(lr=tcfg.lr, state_dtype=cfg.optimizer_state_dtype)
    step_fn, _ = S.build_train_step(
        cfg, recipe, opt_cfg, schedule_kwargs={"warmup": tcfg.warmup,
                                               "total": tcfg.steps})
    if recipe.mesh is not None:
        pspec = S.params_sharding(recipe, cfg)
        step_fn = jax.jit(step_fn, in_shardings=S.to_named(
            recipe, (pspec, S.opt_sharding(recipe, cfg),
                     S.batch_sharding(recipe, cfg, shape))),
            donate_argnums=(0, 1))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    source = source or SyntheticTokenSource(data_cfg.vocab_size, data_cfg.seed)
    loader = ShardedLoader(source, data_cfg)
    state = build_state(cfg, recipe, opt_cfg, tcfg.seed)

    mgr = None
    if tcfg.ckpt_dir:
        mgr = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep_ckpts)
        from repro.checkpoint import latest_step
        last = latest_step(tcfg.ckpt_dir)
        if last is not None:
            tree, man = mgr.restore({"params": state.params,
                                     "opt": state.opt_state})
            state = TrainState(params=tree["params"], opt_state=tree["opt"],
                               step=int(man["step"]))
            print(f"[train] resumed from step {state.step}")

    stop = {"now": False}

    def on_term(sig, frame):
        stop["now"] = True

    old = signal.signal(signal.SIGTERM, on_term)
    step_times: Dict[str, float] = {}
    try:
        while state.step < tcfg.steps and not stop["now"]:
            batch_np = loader.global_batch_at(state.step)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            t0 = time.perf_counter()
            params, opt, metrics = step_fn(state.params, state.opt_state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            state = TrainState(params=params, opt_state=opt, step=state.step + 1)

            # straggler rebalancing (multi-host: times come from peers)
            if tcfg.rebalance_every and state.step % tcfg.rebalance_every == 0:
                step_times["host0"] = dt
                if len(loader.shares) > 1:
                    loader.set_shares(rebalance_shares(
                        step_times, loader.shares, data_cfg.global_batch))

            if metrics_cb:
                metrics_cb(state.step, {**metrics, "step_time_s": dt})
            if state.step % tcfg.log_every == 0:
                print(f"[train] step {state.step} loss={metrics['loss']:.4f} "
                      f"({dt:.2f}s)")
            if mgr and state.step % tcfg.ckpt_every == 0:
                mgr.save_async(state.step, {"params": state.params,
                                            "opt": state.opt_state})
        if mgr:
            mgr.wait()
            mgr.save_async(state.step, {"params": state.params,
                                        "opt": state.opt_state})
            mgr.wait()
    finally:
        signal.signal(signal.SIGTERM, old)
    return state
