"""Multi-drive cluster serving: N replica ``ServeEngine``s — each modeling
one CSD drive with its own paged-KV pool, scheduler, and transfer ledger —
behind ONE shared request queue with locality-aware routing.

This is the paper's storage server (36 Solana drives in one box) applied to
LM serving: the host keeps a single queue, a router decides which drive
pulls each request (``core.cluster.Router``: round_robin / least_loaded /
data_local), and the cluster's stats merge every drive's ledger plus the
live energy integral (``core.energy.server_power`` over per-tick
active-drive counts — Table I's wall-power accounting, finally wired into
serving instead of only the offline benchmarks).

Mechanics:
  * one global FIFO queue; dispatch happens at tick start, at most one
    request per free slot per drive, never reordering around a blocked head
    (deterministic replay — a cluster serves exactly the tokens one engine
    would);
  * requests optionally carry a ``shard_id``.  ``data_local`` pins them to
    the drive holding the shard; serving a sharded request anywhere else
    (a data_local spill, or any placement by the locality-oblivious
    policies) charges ``shard_spill_bytes`` to the cluster's spill ledger —
    the bytes that had to cross the drive-to-drive link because compute did
    not come to the data;
  * every tick steps each drive that has work; each drive's measured step
    time advances its own *virtual clock* (drives are independent
    hardware; in-process they run serially), and the cluster tick costs
    the LEADING clock's advance — the async parallel-wall-clock model —
    plus the active-drive count for the energy integral;
  * ``drain(d)`` stops routing to a drive and re-queues its un-prefilled
    (still drive-queued) requests; ``fail(d)`` additionally restarts its
    in-flight requests from their prompts on the surviving drives (greedy
    decode is deterministic, so a restarted request still yields identical
    tokens) and keeps the dead drive's stats merged into the cluster view;
  * replicas share one set of jitted callables (``jit_donor``), so an
    N-drive cluster costs one XLA compile, not N;
  * a cluster-wide pull scheduler (``core.scheduler.ClusterAdmission``)
    learns every drive's service rate from per-tick observations
    (``ServeEngine.last_tick``); ``rate_aware`` routing consumes the live
    estimates and the scheduler's quotas cap each drive's in-flight share
    ∝ its rate — the paper's host-vs-CSD batch-ratio rule applied
    drive-vs-drive, so a ``speed_factor``-slowed drive pulls
    proportionally less instead of straggling the cluster;
  * per-drive measured tick times have the engine-reported lazy-compile
    delta subtracted before they reach the wall-clock/energy accounting
    (XLA compiles happen once per process, not once per drive tick);
  * shards homed on a drained/failed drive are re-placed onto survivors,
    each migration charged ONCE to the spill ledger (``shard_bytes``),
    instead of every future request re-fetching the shard over the link;
  * ``concurrent=True`` replaces the serial drive loop with the real
    thing: one ``core.runtime.DriveWorker`` thread per drive, fed tick
    commands over per-drive queues by the coordinator (the ``step()``
    caller), replying with heartbeats on a shared monitor queue.  Drive
    steps genuinely overlap (engine steps and service-time sleeps release
    the GIL), the cluster wall clock is MEASURED join time instead of the
    virtual-clock model (the virtual clocks are kept as the model's
    prediction — fig9 gates measured against predicted), and failure
    detection runs on the real channel: a ``HeartbeatWatchdog`` drives
    the same HEALTHY→SUSPECT→DEAD machine from missed heartbeats and
    wall-clock silence, so a crashed or hung worker is discovered from
    its silence, never from ground truth.  ``drain``/``fail``/``close``
    are race-safe and idempotent: ``fail()`` bumps the drive's epoch
    under its lock, stale commands/heartbeats are discarded on both
    sides, and workers join cleanly even when killed mid-tick.
"""
from __future__ import annotations

import math
import queue as queue_mod
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence

import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core.cluster import (ClusterExhaustedError, ClusterStats,
                                DriveLoad, Placement, Router,
                                shard_spill_bytes)
from repro.core.faults import (DEAD, HEALTHY, SUSPECT, FailureDetector,
                               FaultSchedule)
from repro.core.latency import LatencyRecord
from repro.core.runtime import (DriveWorker, Heartbeat, HeartbeatWatchdog,
                                WorkerCommand)
from repro.core.scheduler import ClusterAdmission
from repro.core.telemetry import NULL_HUB
from repro.train.serve_loop import GenResult, ServeEngine, collect_results


@dataclass
class ClusterRequest:
    rid: int                      # cluster-global request id
    prompt: List[int]
    max_new: int
    shard_id: Optional[int] = None
    spilled_bytes: float = 0.0    # spill charge of the current dispatch
    priority: int = 0
    deadline_s: Optional[float] = None  # absolute TTFT deadline (cluster clock)
    # retry budget: fail()-restarts granted so far, and the earliest
    # cluster-clock time the next dispatch may happen (exponential backoff
    # — a request bouncing between sick drives must not hammer the queue)
    retries: int = 0
    not_before_s: float = 0.0


@dataclass
class _Drive:
    drive_id: int
    engine: ServeEngine
    speed: float = 1.0            # modeled hardware speed (0.5 = half rate)
    draining: bool = False
    failed: bool = False
    # hidden ground truth of an injected crash: the drive stops responding
    # (never steps again) but the CLUSTER is not told — only the
    # FailureDetector can notice the silence and trigger fail()
    crashed: bool = False
    # engine-local rid -> cluster-global rid (a request re-queued by
    # drain/fail gets a fresh local rid on whichever drive takes it next)
    rid_map: Dict[int, int] = field(default_factory=dict)
    # concurrent runtime: the drive lock serializes this drive's engine
    # between its worker thread and the coordinator (dispatch submits,
    # hedge cancels, fail's slot release); epoch is bumped by fail()
    # under the lock so in-flight commands/heartbeats from before the
    # failure are recognizably stale and discarded on both sides
    lock: threading.RLock = field(default_factory=threading.RLock,
                                  repr=False, compare=False)
    epoch: int = 0

    @property
    def accepting(self) -> bool:
        return not (self.draining or self.failed)

    @property
    def has_work(self) -> bool:
        return not self.failed and \
            (self.engine.pending > 0 or self.engine.num_active > 0)

    def load(self, clock: float = 0.0, service_s: float = math.nan,
             quota: Optional[int] = None,
             accepting: Optional[bool] = None) -> DriveLoad:
        """``accepting`` overrides the drain/fail view — the engine passes
        False for SUSPECT drives so the router quarantines them from new
        dispatch without the drive being administratively down."""
        eng = self.engine
        fill = 0.0
        if eng.pager is not None and eng.pager.num_pages > 0:
            fill = eng.pager.num_in_use / eng.pager.num_pages
        return DriveLoad(drive_id=self.drive_id, num_slots=eng.num_slots,
                         active=eng.num_active, pending=eng.pending,
                         page_fill=fill,
                         accepting=self.accepting if accepting is None
                         else accepting,
                         clock=clock, service_s=service_s, quota=quota)


class ClusterEngine:
    """N replica serve engines behind one queue with pluggable routing."""

    def __init__(self, cfg: ModelConfig, params, n_drives: int = 2,
                 routing: str = "least_loaded", placement: Placement = None,
                 spill: bool = True, jit_donor: Optional[ServeEngine] = None,
                 admission_factory=None,
                 speed_factor: Optional[Sequence[float]] = None,
                 rate_alpha: float = 0.15,
                 quota_gate: bool = False,
                 shard_replacement: bool = True,
                 shard_bytes: Optional[float] = None,
                 admission_order: str = "fifo",
                 shed_expired: bool = True,
                 faults: Optional[FaultSchedule] = None,
                 detector: Optional[FailureDetector] = None,
                 max_retries: int = 3,
                 retry_backoff_s: float = 0.05,
                 hedge: bool = False,
                 concurrent: bool = False,
                 dispatch_timeout_s: float = 0.25,
                 min_tick_s: float = 0.0,
                 tick_jitter_s: float = 0.0,
                 jitter_seed: int = 0,
                 watchdog: Optional[HeartbeatWatchdog] = None,
                 telemetry=None,
                 **engine_kw):
        if n_drives < 1:
            raise ValueError("need at least one drive")
        self.cfg = cfg
        self.router = Router(routing, n_drives, placement=placement,
                             spill=spill)
        # speed_factor models heterogeneous hardware in one process: a
        # drive's measured tick time is divided by its factor (0.5 = an
        # ARM-class drive twice as slow as its peers), which flows into the
        # wall-clock model, the energy integral, and the learned rates
        if speed_factor is None:
            speed_factor = [1.0] * n_drives
        speed_factor = [float(s) for s in speed_factor]
        if len(speed_factor) != n_drives:
            raise ValueError(f"speed_factor needs {n_drives} entries, "
                             f"got {len(speed_factor)}")
        if any(not (s > 0.0) or not math.isfinite(s) for s in speed_factor):
            raise ValueError(f"speed_factor entries must be finite and "
                             f"positive, got {speed_factor}")
        # telemetry: the coordinator owns request spans and the
        # "coordinator" track (cluster wall clock); each drive engine gets
        # the same hub pointed at its own f"drive{d}" track (per-drive
        # virtual clock) with request spans OFF — drive-local rids are not
        # cluster-global rids, and mixing clock domains inside one span
        # would make durations meaningless
        self.tele = telemetry if telemetry is not None else NULL_HUB
        self.drives: List[_Drive] = []
        # an AdmissionController is mutable pull state — replicas must not
        # share one; pass admission_factory to configure per-drive admission
        if "admission" in engine_kw:
            raise ValueError("pass admission_factory (one controller per "
                             "drive), not a shared admission instance")
        if concurrent and not engine_kw.get("prewarm"):
            # a cold drive's first tick is one long jit compile — real
            # wall-clock silence the heartbeat watchdog cannot tell from
            # death (and would punish with SUSPECT/DEAD).  The worker
            # runtime therefore never starts cold: compile here, before
            # any worker thread exists (drive 0 pays once; the rest
            # share its cache via the donor chain below)
            engine_kw["prewarm"] = True
        for d in range(n_drives):
            donor = jit_donor if jit_donor is not None else \
                (self.drives[0].engine if self.drives else None)
            kw = dict(engine_kw)
            if admission_factory is not None:
                kw["admission"] = admission_factory()
            eng = ServeEngine(cfg, params, jit_donor=donor, **kw)
            eng.tele = self.tele
            eng.tele_track = f"drive{d}"
            eng.tele_requests = False
            self.drives.append(_Drive(drive_id=d, engine=eng,
                                      speed=speed_factor[d]))
        # the cluster-wide pull scheduler: one controller learns every
        # drive's service rate from tick observations (the paper's
        # batch-ratio rule lifted from host-vs-CSD to drive-vs-drive).
        # rate_aware routing consumes the live estimates via expected-
        # completion deferral (the quota in continuous form);
        # quota_gate=True additionally applies the discrete quotas as hard
        # in-flight caps — off by default because one engine tick costs the
        # same at any slot occupancy, so a sub-slot cap wastes whole ticks
        # on partial batches (measured in the fig6 hetero benchmark)
        self.pull = ClusterAdmission(n_drives, alpha=rate_alpha)
        self.quota_gate = bool(quota_gate)
        # shard re-placement: on drain/fail, move the dead drive's shards
        # to survivors ONCE (charged below) instead of paying a per-request
        # spill forever; shard_bytes models one shard's resident footprint
        # (default: one full max_len context of d_model rows)
        self.shard_replacement = bool(shard_replacement)
        if shard_bytes is None:
            shard_bytes = float(self.drives[0].engine.max_len * cfg.d_model
                                * jnp.dtype(cfg.dtype).itemsize)
        self.shard_bytes = float(shard_bytes)
        self._seen_shards: set = set()
        self.queue: Deque[ClusterRequest] = deque()
        self.stats = ClusterStats(
            drives=[d.engine.stats for d in self.drives])
        self._inflight: Dict[int, ClusterRequest] = {}
        self._next_rid = 0
        self._finished: List[GenResult] = []
        self._spill_bytes_per_el = jnp.dtype(cfg.dtype).itemsize
        # per-drive virtual clocks for the async parallel-drives model:
        # drives are independent hardware with no tick barrier (the paper's
        # pull protocol), so the cluster wall clock is the LEADING drive's
        # cumulative busy time, and work done in the leader's shadow is
        # free — which is exactly why sizing each drive's share to its
        # rate (instead of a straggler-bound per-tick max) pays off
        self._clocks = [0.0] * n_drives
        self._lead = 0.0              # leading clock at the last tick
        # SLO layer: the cluster wall clock (tick advances + idle
        # fast-forwards via advance_clock) is the ONE clock all per-request
        # timestamps live on — per-drive virtual clocks never leak into
        # LatencyRecords, so TTFT/e2e cannot go negative across drives.
        # "edf" sorts the SHARED queue by deadline before routing (drives
        # themselves stay FIFO: a deadline on the cluster clock means
        # nothing on a drive's busy-time clock, so deadlines are not
        # propagated down); shed_expired drops queued requests whose
        # deadline already passed instead of dispatching hopeless work.
        if admission_order not in ("fifo", "edf"):
            raise ValueError(f"admission_order must be 'fifo' or 'edf', "
                             f"got {admission_order!r}")
        self.admission_order = admission_order
        self.shed_expired = bool(shed_expired)
        self.clock = 0.0
        self.records: Dict[int, LatencyRecord] = {}
        # fault tolerance (PR 7): an optional seeded FaultSchedule injects
        # stalls/slowdowns/crashes/pool clamps per tick (hidden ground
        # truth); the FailureDetector watches the cluster-VISIBLE signals
        # (virtual clocks + per-tick progress) and auto-fail()s drives it
        # declares DEAD.  Requests restarted by fail() carry a retry
        # budget with exponential backoff; past max_retries they finish
        # status="failed" instead of requeueing forever.  hedge=True
        # additionally duplicates the oldest SUSPECT-stranded request onto
        # a healthy drive — first finisher wins, the loser is canceled and
        # its serving time booked as hedge_wasted_s.
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        if retry_backoff_s < 0 or not math.isfinite(retry_backoff_s):
            raise ValueError(f"retry_backoff_s must be finite and >= 0, "
                             f"got {retry_backoff_s}")
        self.faults = faults
        self.detector = detector if detector is not None \
            else FailureDetector(n_drives)
        if self.detector.n_drives != n_drives:
            raise ValueError(f"detector tracks {self.detector.n_drives} "
                             f"drives, cluster has {n_drives}")
        self.max_retries = int(max_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.hedge = bool(hedge)
        self._tick = 0                 # fault-schedule tick index
        # grid -> (primary_drive_id, hedge_drive_id) for in-flight hedges
        self._hedges: Dict[int, tuple] = {}
        # status="failed" results produced outside a step (operator fail())
        # wait here until the next step()/run_until_complete() delivers them
        self._failout: List[GenResult] = []
        self._stuck = False
        self._idle_grace = 0           # consecutive idle ticks granted to
        # dispatch after a same-tick fail() requeue (see _idle_advance)
        # hedge copies whose cancel() found the copy already finished
        # (both copies completed in one joined tick): the duplicate
        # result is still pending absorption — drop it AND book its burn
        self._hedge_drops: Dict[tuple, bool] = {}
        # -- concurrent worker runtime (core.runtime) ------------------------
        self.concurrent = bool(concurrent)
        if not (dispatch_timeout_s > 0.0 and math.isfinite(dispatch_timeout_s)):
            raise ValueError(f"dispatch_timeout_s must be finite and > 0, "
                             f"got {dispatch_timeout_s}")
        if min_tick_s < 0 or not math.isfinite(min_tick_s):
            raise ValueError(f"min_tick_s must be finite and >= 0, "
                             f"got {min_tick_s}")
        if tick_jitter_s < 0 or not math.isfinite(tick_jitter_s):
            raise ValueError(f"tick_jitter_s must be finite and >= 0, "
                             f"got {tick_jitter_s}")
        self.dispatch_timeout_s = float(dispatch_timeout_s)
        self.min_tick_s = float(min_tick_s)
        self.tick_jitter_s = float(tick_jitter_s)
        self.jitter_seed = int(jitter_seed)
        if watchdog is not None and watchdog.n_drives != n_drives:
            raise ValueError(f"watchdog tracks {watchdog.n_drives} drives, "
                             f"cluster has {n_drives}")
        if self.concurrent and watchdog is None:
            # default watchdog mirrors the detector's thresholds: ticks
            # become missed heartbeats, clock lag becomes wall silence
            watchdog = HeartbeatWatchdog(
                n_drives,
                suspect_after_s=self.detector.suspect_after_s,
                suspect_misses=self.detector.suspect_ticks,
                dead_after_s=self.detector.dead_after_s,
                dead_misses=self.detector.dead_ticks)
        self.watchdog = watchdog
        # cluster lock: every mutation of shared state (queue, admission,
        # router, ledgers, stats, rid maps, hedges) happens under it —
        # workers never take it (they only hold their drive lock), so
        # coordinator->drive lock acquisition cannot deadlock
        self._lock = threading.RLock()
        self._close_lock = threading.Lock()
        self._closed = False
        self._stop = threading.Event()
        self._monitor: "queue_mod.Queue[Heartbeat]" = queue_mod.Queue()
        self._commands: List["queue_mod.Queue[WorkerCommand]"] = []
        self._workers: Optional[List[DriveWorker]] = None
        self._outstanding = [0] * n_drives   # unanswered commands per drive
        self.stats.health = list(self._health)

    # -- intake --------------------------------------------------------------

    def submit(self, prompt: Sequence[int], max_new: int = 32,
               shard_id: Optional[int] = None, priority: int = 0,
               deadline_s: Optional[float] = None) -> int:
        """Enqueue a request; ``deadline_s`` is an ABSOLUTE first-token
        deadline on the CLUSTER wall clock (None = best-effort)."""
        prompt = list(prompt)
        # reject at enqueue time what no drive can ever serve — a deferred
        # ValueError inside _dispatch would tear down the whole run
        self.drives[0].engine.validate_request(prompt, max_new)
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            req = ClusterRequest(rid, prompt, max_new, shard_id,
                                 priority=priority, deadline_s=deadline_s)
            if shard_id is not None:
                self._seen_shards.add(shard_id)
            self._inflight[rid] = req
            self.queue.append(req)
            self.records[rid] = LatencyRecord(rid=rid, priority=priority,
                                              deadline_s=deadline_s,
                                              submit_t=self.clock)
            if self.tele.enabled:
                self.tele.open_request(rid, self.clock, priority=priority,
                                       prompt_len=len(prompt),
                                       max_new=max_new, shard=shard_id)
            return rid

    def advance_clock(self, to_t: float) -> None:
        """Fast-forward the cluster wall clock across an idle gap (open-loop
        replay).  Only the wall clock moves — the per-drive virtual clocks
        track busy time and idle is not busy."""
        with self._lock:
            self.clock = max(self.clock, to_t)

    @property
    def pending(self) -> int:
        return len(self.queue)

    @property
    def num_active(self) -> int:
        """Slots mid-flight across live drives (same semantics as
        ``ServeEngine.num_active``; drive-queued requests count under
        ``in_flight``, not here)."""
        return sum(d.engine.num_active for d in self.drives if not d.failed)

    @property
    def in_flight(self) -> int:
        """Everything dispatched but unfinished: active slots plus requests
        waiting in per-drive queues."""
        return sum(d.engine.num_active + d.engine.pending
                   for d in self.drives if not d.failed)

    # -- drive lifecycle -----------------------------------------------------

    def drain(self, drive_id: int) -> int:
        """Stop routing to a drive and pull its un-prefilled requests back
        into the shared queue (front, original order — they were dispatched
        earliest).  In-flight slots finish normally.  Shards homed on the
        drive are re-placed onto survivors (one migration charge each).
        Idempotent and race-safe: a second drain finds an empty drive
        queue and re-queues nothing.  Returns the number re-queued."""
        with self._lock:
            d = self.drives[drive_id]
            with d.lock:
                d.draining = True
                n = self._requeue_unprefilled(d)
            self._replace_shards_of(drive_id)
            return n

    def fail(self, drive_id: int) -> int:
        """Hard drive failure: re-queue its un-prefilled requests AND
        restart its in-flight ones from their prompts (partial output is
        lost; greedy decode is deterministic so the retry reproduces the
        same tokens).  The dead drive's stats stay merged in the cluster
        view — the work it did (and the energy it burned) happened.

        Recovery semantics (PR 7): each restart consumes one unit of the
        request's retry budget and arms an exponential backoff; a request
        already at ``max_retries`` finishes ``status="failed"`` instead of
        requeueing.  A hedged request whose primary died is NOT restarted
        — its hedge copy on the healthy drive simply becomes the primary.
        The dead engine's slots and pages are released (a failed drive
        mid-chunked-prefill would otherwise leak its partially spliced KV
        pages forever), and if this was the LAST healthy drive every
        queued request finishes ``status="failed"`` — conservation
        (``submitted == ok + shed + failed``) holds even at total loss.

        Race-safe under the concurrent runtime: the whole teardown runs
        under the cluster lock AND the drive lock — a worker mid-step
        holds the drive lock, so fail() waits for the step to finish
        before touching slots, then bumps the drive's epoch so the step's
        late heartbeat (and any command still in the worker's queue) is
        recognizably stale and discarded.  Idempotent: a second fail()
        (operator + watchdog racing) returns 0.
        Returns the number of requests re-queued."""
        with self._lock:
            d = self.drives[drive_id]
            if d.failed:
                return 0
            retry: List[ClusterRequest] = []
            failed_out: List[ClusterRequest] = []
            with d.lock:
                d.epoch += 1
                if self.tele.enabled:
                    self.tele.point("coordinator", "drive_failed",
                                    self.clock, drive=drive_id,
                                    epoch=d.epoch)
                    self.tele.counter("cluster.drive_failures")
                n = self._requeue_unprefilled(d)
                self.detector.mark_dead(drive_id)
                if self.watchdog is not None:
                    self.watchdog.mark_dead(drive_id)
                self.pull.unquarantine(drive_id)  # dead ≠ suspect: refit
                # everything still mapped after _requeue_unprefilled is
                # in-flight in a slot OR finished-but-unabsorbed (its
                # result rode a heartbeat the epoch bump just made stale
                # — from the coordinator's view that output never
                # existed).  Both are lost with the drive: scanning only
                # active slots would orphan the unabsorbed ones, silently
                # breaking submitted == ok + shed + failed
                for local in sorted(d.rid_map,
                                    key=lambda l: d.rid_map[l]):
                    grid = d.rid_map.pop(local)
                    req = self._inflight.get(grid)
                    if req is None:
                        continue
                    pair = self._hedges.get(grid)
                    if pair is not None and pair[0] == drive_id:
                        # the hedge copy outlived the primary: promote
                        # it (it keeps running; no restart, no retry)
                        self._hedges.pop(grid)
                        self.stats.hedges_won += 1
                        if self.tele.enabled:
                            self.tele.close_span(("hedge", grid),
                                                 self.clock, "promoted")
                        continue
                    if pair is not None and pair[1] == drive_id:
                        # the hedge copy died with this drive; the
                        # primary is still serving — abandon the hedge
                        self._hedges.pop(grid)
                        self.stats.hedges_lost += 1
                        if self.tele.enabled:
                            self.tele.close_span(("hedge", grid),
                                                 self.clock, "canceled",
                                                 reason="hedge drive died")
                        continue
                    if req.retries >= self.max_retries:
                        failed_out.append(req)
                        continue
                    req.retries += 1
                    self.stats.retries += 1
                    if self.tele.enabled:
                        self.tele.request_point(grid, "retry", self.clock,
                                                attempt=req.retries,
                                                from_drive=drive_id)
                        self.tele.counter("cluster.retries")
                    if self.retry_backoff_s > 0.0:
                        req.not_before_s = self.clock + \
                            self.retry_backoff_s * \
                            (2.0 ** (req.retries - 1))
                    retry.append(req)
                    rec = self.records.get(grid)
                    if rec is not None:
                        # the retry replays from the prompt:
                        # admit/first-token re-stamp on the surviving
                        # drive, but queue wait keeps the ORIGINAL
                        # submit — the user has been waiting since
                        # then, whatever the cluster did in between
                        rec.restart()
                # slots are scanned in pool order, which is refill order,
                # not submission order — restore FIFO by global rid before
                # requeueing (in-flight requests go ahead of the
                # drive-queued ones _requeue_unprefilled just put back:
                # they were dispatched earlier)
                for req in sorted(retry, key=lambda r: r.rid, reverse=True):
                    self.queue.appendleft(req)
                # free the dead engine's slots and their KV pages:
                # in-flight requests (including mid-chunked-prefill ones
                # with partially spliced pages) were restarted or failed
                # out above — without this release the dead drive's page
                # pool leaks its live pages forever (pager.check_balanced()
                # is the regression gate)
                for slot in d.engine.slots:
                    if slot.active:
                        d.engine._release_slot(slot)
                d.engine.records.clear()
                # drop finished-but-undelivered results too: their
                # requests were just restarted (or failed out) above, so
                # absorbing a stale copy later would deliver twice
                d.engine._finished.clear()
                d.failed = True
                d.draining = True
            self._outstanding[drive_id] = 0   # silent commands died with it
            self._replace_shards_of(drive_id)
            for req in failed_out:
                self._fail_request(req)
            if not any(x.accepting for x in self.drives):
                # the LAST drive died with requests still queued: nothing
                # can ever serve them — fail them out now, not deadlock
                while self.queue:
                    self._fail_request(self.queue.popleft())
            return n + len(retry)

    def _fail_request(self, req: ClusterRequest) -> None:
        """Terminal failure: the request is out of retries (or out of
        drives).  Emits a ``status="failed"`` GenResult and closes the
        latency record — the original submit timestamp is kept, so the
        record's e2e covers every retry the budget paid for."""
        self._inflight.pop(req.rid, None)
        self.stats.failed_requests += 1
        res = GenResult(tokens=[], prefill_s=0.0, decode_s=0.0, rid=req.rid,
                        status="failed", priority=req.priority)
        rec = self.records.pop(req.rid, None)
        if rec is not None:
            rec.finish_t = self.clock
            rec.status = "failed"
            self.stats.latency.add(rec)
            res.e2e_s = rec.e2e_s
        if self.tele.enabled:
            self.tele.close_request(req.rid, self.clock, "failed",
                                    retries=req.retries)
        self._failout.append(res)

    def _requeue_unprefilled(self, d: _Drive) -> int:
        """Pull everything still sitting in the drive's own queue back into
        the shared queue's head.  These requests never touched the drive, so
        a spill charged at their dispatch never actually crossed the link —
        refund it (in-flight requests keep their charge: their shard bytes
        did move)."""
        backed: List[ClusterRequest] = []
        while d.engine.queue:
            local = d.engine.queue.popleft()
            grid = d.rid_map.pop(local.rid)
            pair = self._hedges.get(grid)
            if pair is not None and pair[1] == d.drive_id:
                # a still-queued hedge copy on a draining/failing drive:
                # drop it (the primary is serving) instead of re-queueing
                # a duplicate into the shared queue
                self._hedges.pop(grid)
                self.stats.hedges_lost += 1
                d.engine.records.pop(local.rid, None)
                if self.tele.enabled:
                    self.tele.close_span(("hedge", grid), self.clock,
                                         "canceled",
                                         reason="hedge still queued on "
                                                "draining drive")
                continue
            backed.append(self._inflight[grid])
        for req in reversed(backed):
            if req.spilled_bytes:
                self.stats.spill_ledger.add("link", -req.spilled_bytes,
                                            "remote shard spill")
                self.stats.remote_requests -= 1
                req.spilled_bytes = 0.0
            self.queue.appendleft(req)
        return len(backed)

    # -- shard re-placement ----------------------------------------------------

    def _replace_shards_of(self, drive_id: int) -> int:
        """Re-home every seen shard living on ``drive_id`` onto a surviving
        drive, paying each shard's bytes over the link exactly once —
        instead of re-fetching them on every future request (the
        no-replacement behavior, which charges a spill per request
        forever).  Returns the number of shards migrated."""
        if not self.shard_replacement:
            return 0
        moved = 0
        for shard in sorted(self._seen_shards):
            if self.router.home(shard) == drive_id:
                moved += int(self._migrate_shard(shard))
        return moved

    def _migrate_shard(self, shard_id: int) -> bool:
        """Move one shard to the least-loaded accepting drive and charge
        the migration to the spill ledger."""
        survivors = [d for d in self.drives if d.accepting]
        if not survivors:
            return False
        target = min(survivors, key=lambda d: (d.load().load, d.drive_id))
        self.router.replace_shard(shard_id, target.drive_id)
        self.stats.spill_ledger.add("link", self.shard_bytes,
                                    "shard migration")
        self.stats.migrated_shards += 1
        return True

    # -- dispatch + tick -----------------------------------------------------

    def _pull_quotas(self) -> Dict[int, int]:
        """Per-drive in-flight quotas from the cluster pull scheduler,
        refit over the accepting drives (share ∝ learned rate).  SUSPECT
        drives are quarantined out — a stalled drive must not keep a
        share it cannot serve (the scheduler also drops their ticks)."""
        live = [d.drive_id for d in self.drives if d.accepting
                and self._health[d.drive_id] != SUSPECT]
        if not live:
            live = [d.drive_id for d in self.drives if d.accepting]
        if not live:
            return {}
        total = sum(self.drives[i].engine.num_slots for i in live)
        return self.pull.quotas(total, live)

    def _shed_queue(self) -> List[GenResult]:
        """Drop shared-queue requests whose deadline already passed — even
        an instant dispatch could not produce their first token in time, so
        routing them only steals capacity from requests that can still make
        their SLO.  Queued sheds cost nothing beyond their queue wait (no
        serving time was spent); each produces a ``status='shed'``
        GenResult so the submitter hears back."""
        if not self.shed_expired or not any(
                r.deadline_s is not None and r.deadline_s < self.clock
                for r in self.queue):
            return []
        out: List[GenResult] = []
        keep: Deque[ClusterRequest] = deque()
        for req in self.queue:
            if req.deadline_s is None or req.deadline_s >= self.clock:
                keep.append(req)
                continue
            self._inflight.pop(req.rid, None)
            self.stats.shed_requests += 1
            res = GenResult(tokens=[], prefill_s=0.0, decode_s=0.0,
                            rid=req.rid, status="shed",
                            priority=req.priority)
            rec = self.records.pop(req.rid, None)
            if rec is not None:
                rec.finish_t = self.clock
                rec.status = "shed"
                self.stats.latency.add(rec)
                res.e2e_s = rec.e2e_s
            if self.tele.enabled:
                self.tele.close_request(req.rid, self.clock, "shed")
                self.tele.counter("cluster.shed")
            out.append(res)
        self.queue = keep
        return out

    def _dispatch(self) -> None:
        """Route queued requests to drives, at most one per free slot, FIFO
        (a blocked head waits; nothing is reordered around it).  Under EDF
        the shared queue is deadline-sorted FIRST (stable: FIFO preserved
        within a class), then the same no-reorder dispatch runs.  Under
        quota gating each drive's in-flight share is additionally capped by
        the pull scheduler's rate-proportional quota."""
        if self.admission_order == "edf" and len(self.queue) > 1:
            self.queue = deque(sorted(
                self.queue,
                key=lambda r: (r.deadline_s if r.deadline_s is not None
                               else math.inf, r.priority, r.rid)))
        quotas = self._pull_quotas() if self.quota_gate else {}
        # expected seconds to serve one request on drive d: mean observed
        # tokens per completed request / the drive's learned token rate
        mean_items = (self.stats.tokens / self.stats.completed) \
            if self.stats.completed > 0 else math.nan
        # retry backoff: a request whose not_before hasn't arrived is
        # INELIGIBLE (not blocked) — dispatch steps around it, which is
        # the one sanctioned reorder: token identity is per-request under
        # greedy decode, so skipping a cooling-down retry cannot change
        # anyone's output, only who waits
        deferred: List[ClusterRequest] = []
        while self.queue:
            head = self.queue[0]
            if head.not_before_s > self.clock:
                deferred.append(self.queue.popleft())
                continue
            if self.shard_replacement and head.shard_id is not None and \
                    not self.drives[self.router.home(head.shard_id)].accepting:
                # lazy re-placement: the head's shard still points at a
                # drained/failed drive (a shard first seen after the drain)
                self._migrate_shard(head.shard_id)
            loads = [d.load(clock=self._clocks[d.drive_id],
                            service_s=mean_items / self.pull.rate(d.drive_id),
                            quota=quotas.get(d.drive_id),
                            accepting=d.accepting and
                            self._health[d.drive_id] != SUSPECT)
                     for d in self.drives]
            route = self.router.pick(head.shard_id, loads)
            if route is None:
                break
            req = self.queue.popleft()
            drive = self.drives[route.drive_id]
            # under the drive lock: a late worker may still be stepping
            # this engine (previous tick overran the dispatch timeout)
            with drive.lock:
                local = drive.engine.submit(req.prompt, max_new=req.max_new)
                drive.rid_map[local] = req.rid
            req.spilled_bytes = 0.0
            if route.remote:
                self.stats.remote_requests += 1
                req.spilled_bytes = shard_spill_bytes(
                    len(req.prompt), req.max_new, self.cfg.d_model,
                    self._spill_bytes_per_el)
                self.stats.spill_ledger.add("link", req.spilled_bytes,
                                            "remote shard spill")
            if self.tele.enabled:
                self.tele.request_point(
                    req.rid, "route", self.clock, drive=route.drive_id,
                    policy=self.router.policy, remote=bool(route.remote),
                    spill_bytes=req.spilled_bytes)
        if deferred:
            # cooling-down retries go back to the FRONT in original order
            # (they are the oldest requests; their backoff, not their
            # place in line, is what delays them)
            self.queue.extendleft(reversed(deferred))

    def step(self) -> List[GenResult]:
        """One cluster tick.  Serial mode steps every drive in-process
        under the virtual-clock model; ``concurrent=True`` forks the tick
        to the per-drive worker threads and joins on their heartbeats —
        see ``_step_serial`` / ``_step_concurrent``."""
        if self.concurrent:
            return self._step_concurrent()
        return self._step_serial()

    @property
    def _health(self) -> List[str]:
        """The cluster's health authority: the heartbeat watchdog when the
        concurrent runtime is live, else the virtual-clock detector."""
        if self.concurrent and self.watchdog is not None:
            return self.watchdog.health
        return self.detector.health

    def _absorb_tick(self, d: _Drive, finished: List[GenResult], obs,
                     dt: float, out: List[GenResult],
                     admit_events: List[int],
                     first_tok_events: List[int]) -> None:
        """Fold one drive tick's observations into the shared cluster
        state: virtual clock, pull-scheduler rates, admit/first-token
        event mapping, finished results, and hedge settlement.  The
        winner-commit and loser-cancel of a hedge are decided HERE, under
        the one cluster lock in concurrent mode — the both-finish race
        resolves to exactly one delivered result with the loser's burn
        booked as hedge waste."""
        self._clocks[d.drive_id] += dt
        self.pull.observe(d.drive_id, dt, obs.per_step_items)
        # map engine-local events to global rids BEFORE the finished
        # loop pops rid_map (a request can admit, emit its first token
        # and finish in the same tick)
        for local in obs.admitted_rids:
            if local in d.rid_map:
                admit_events.append(d.rid_map[local])
        for local in obs.first_token_rids:
            if local in d.rid_map:
                first_tok_events.append(d.rid_map[local])
        for r in finished:
            if r.rid not in d.rid_map:
                # abandoned by an earlier fail(), or the losing copy of a
                # hedge whose winner was absorbed first — the loser's
                # serving time is the availability premium, book it
                if self._hedge_drops.pop((d.drive_id, r.rid), None):
                    self.stats.hedge_wasted_s += r.prefill_s + r.decode_s
                    self.stats.hedge_wasted_s = max(
                        self.stats.hedge_wasted_s, 0.0)
                continue
            grid = d.rid_map.pop(r.rid)
            pair = self._hedges.pop(grid, None)
            if pair is not None:
                self._settle_hedge(grid, winner=d.drive_id, pair=pair)
            self._inflight.pop(grid, None)
            r.rid = grid
            r.drive = d.drive_id
            out.append(r)
            self.stats.completed += 1

    def _deliver(self, shed: List[GenResult], out: List[GenResult],
                 admit_events: List[int],
                 first_tok_events: List[int]) -> List[GenResult]:
        """Stamp per-request latency at the post-tick cluster clock and
        hand back the tick's results (sheds + completions + failouts)."""
        for grid in admit_events:
            rec = self.records.get(grid)
            if rec is not None and not math.isfinite(rec.admit_t):
                rec.admit_t = self.clock
                if self.tele.enabled:
                    self.tele.request_point(grid, "admit", self.clock)
        for grid in first_tok_events:
            rec = self.records.get(grid)
            if rec is not None and not math.isfinite(rec.first_token_t):
                rec.first_token_t = self.clock
                if self.tele.enabled:
                    self.tele.request_point(grid, "first_token", self.clock)
        for r in out:
            rec = self.records.pop(r.rid, None)
            if rec is None:
                continue
            rec.finish_t = self.clock
            rec.n_tokens = len(r.tokens)
            rec.status = "ok"
            self.stats.latency.add(rec)
            if self.tele.enabled:
                self.tele.close_request(r.rid, self.clock, "ok",
                                        drive=r.drive,
                                        tokens=len(r.tokens))
            r.priority = rec.priority
            r.queue_wait_s = rec.queue_wait_s
            r.ttft_s = rec.ttft_s
            r.tpot_s = rec.tpot_s
            r.e2e_s = rec.e2e_s
        if self._failout:
            # terminal failures produced this tick (retry budget / last
            # drive death) ride the tick's result list like sheds do
            out = out + self._failout
            self._failout = []
        out = shed + out
        self._finished.extend(out)
        return out

    def _step_serial(self) -> List[GenResult]:
        """One cluster tick: dispatch, then step every drive that has work.
        Each drive's step time advances its virtual clock; the tick costs
        the leading clock's advance (async parallel hardware), and the
        active-drive count feeds the live energy integral.

        Two corrections are applied to each drive's measured wall time:
        the engine-reported lazy-compile delta is subtracted (an XLA
        compile happens once per process, not once per replica tick —
        charging it would inflate ``cluster_s``/``serial_s`` and the
        ``server_power·dt`` energy integral on a cold cluster), and the
        remainder is divided by the drive's ``speed_factor`` (modeled
        heterogeneous hardware).  The corrected time also feeds the pull
        scheduler's per-drive rate estimate.

        Per-request latency is stamped at TICK granularity on the cluster
        wall clock: admissions and first tokens observed during the tick
        are stamped at the post-tick clock (the event completed somewhere
        inside the tick; the cluster cannot see sub-tick drive time
        without mixing clock domains, and a post-tick stamp is the
        conservative, monotone choice).

        Fault injection (PR 7) wraps the tick: the schedule's ground truth
        is applied FIRST (crashes silence drives, clamps shrink admissible
        pools, stalls skip a drive's step, slowdowns inflate its measured
        time), then the FailureDetector reads the tick's cluster-visible
        evidence and may auto-``fail()`` a DEAD drive; SUSPECT drives are
        quarantined from dispatch/quotas and optionally hedged around."""
        tick = self._tick
        self._tick += 1
        if self.faults is not None:
            begun = self.faults.begins(tick, self.clock)
            self.stats.faults_injected += len(begun)
            if self.tele.enabled:
                for ev in begun:
                    self.tele.fault_injected(ev.drive_id, ev.kind,
                                             self.clock, tick)
            for did in self.faults.crashes(tick, self.clock):
                if not self.drives[did].failed:
                    self.drives[did].crashed = True
            for d in self.drives:
                if not d.failed:
                    d.engine.pool_clamp_frac = \
                        self.faults.clamp(d.drive_id, tick, self.clock)
        shed = self._shed_queue()
        self._dispatch()
        out: List[GenResult] = []
        dts: List[float] = []
        admit_events: List[int] = []
        first_tok_events: List[int] = []
        n_active = 0
        progressed: set = set()
        for d in self.drives:
            if not d.has_work:
                continue
            if d.crashed or (self.faults is not None and self.faults.stalled(
                    d.drive_id, tick, self.clock)):
                # the drive does not respond this tick: its work sits, its
                # virtual clock stands still — exactly the silence the
                # detector is watching for
                continue
            t0 = time.perf_counter()
            finished = d.engine.step()
            raw = time.perf_counter() - t0
            if self.min_tick_s > 0.0:
                # emulated drive service-time floor (fig9: makes the
                # serial-vs-concurrent comparison hardware-independent);
                # really slept so measured wall time includes it
                pad = self.min_tick_s - raw
                if pad > 0.0:
                    time.sleep(pad)
                    raw += pad
            obs = d.engine.last_tick
            dt = max(raw - obs.compile_s, 0.0) / d.speed
            if self.faults is not None:
                dt *= self.faults.slowdown(d.drive_id, tick, self.clock)
            dts.append(dt)
            progressed.add(d.drive_id)
            n_active += 1
            self._absorb_tick(d, finished, obs, dt, out, admit_events,
                              first_tok_events)
            # the cluster owns result delivery: drop the engine's internal
            # copy so a long-running server doesn't accumulate one
            # GenResult per request per drive forever
            d.engine._finished.clear()
        if dts:
            # async parallel model: the cluster advances only when the
            # LEADING virtual clock advances; a slower/lagging drive's step
            # overlaps the leader and adds no wall time (no tick barrier)
            lead = max(self._clocks)
            tick_s = max(lead - self._lead, 0.0)
            self._lead = lead
            self.stats.record_tick(n_active, tick_s, sum(dts))
            self.clock += tick_s
            self._idle_grace = 0
            if self.tele.enabled and tick_s > 0.0:
                self.tele.phase("coordinator", "tick",
                                self.clock - tick_s, tick_s,
                                tick=tick, active=n_active)
        # failure detection on cluster-VISIBLE evidence only: which drives
        # progressed, and how far the leading clock ran since each drive's
        # last productive tick (ground-truth crash flags never leak here)
        lead_clock = max(self._clocks)
        dead_now: List[int] = []
        for d in self.drives:
            if d.failed:
                continue
            old, new = self.detector.observe(
                d.drive_id, lead_clock,
                progressed=d.drive_id in progressed,
                has_work=d.has_work)
            if old != new and self.tele.enabled:
                self.tele.health_transition("detector", d.drive_id,
                                            old, new, self.clock)
            if new == DEAD and old != DEAD:
                dead_now.append(d.drive_id)
            elif new == SUSPECT and old != SUSPECT:
                self.pull.quarantine(d.drive_id)
            elif new == HEALTHY and old == SUSPECT:
                self.pull.unquarantine(d.drive_id)
        for did in dead_now:
            self.stats.auto_failed_drives += 1
            self.fail(did)
        if self.hedge:
            self._launch_hedges()
        self.stats.health = list(self.detector.health)
        if self.tele.enabled:
            self._publish_tick_metrics(tick)
        if not dts:
            self._idle_advance(tick)
        return self._deliver(shed, out, admit_events, first_tok_events)

    # -- concurrent worker runtime -------------------------------------------

    def _make_step_fn(self, d: _Drive):
        """The engine-specific half of a worker's tick, run on the worker
        thread UNDER the drive lock (so fail() and hedge-cancel exclude a
        mid-step worker).  Shared cluster state is never touched here —
        the payload is absorbed by the coordinator under the cluster
        lock."""
        def run(tick: int, clock: float) -> Optional[dict]:
            with d.lock:
                if d.failed or self._stop.is_set() or not d.has_work:
                    return None
                if self.faults is not None:
                    d.engine.pool_clamp_frac = \
                        self.faults.clamp(d.drive_id, tick, clock)
                t0 = time.perf_counter()
                finished = list(d.engine.step())
                raw = time.perf_counter() - t0
                obs = d.engine.last_tick
                # the worker owns result hand-off: clear the engine's
                # internal copy (same contract as the serial loop)
                d.engine._finished.clear()
                return {"finished": finished, "obs": obs, "raw_s": raw}
        return run

    def _ensure_workers(self) -> None:
        if self._workers is not None:
            return
        if self._closed:
            raise RuntimeError("cluster engine is closed")
        self._commands = []
        self._workers = []
        for d in self.drives:
            cq: "queue_mod.Queue[WorkerCommand]" = queue_mod.Queue()
            w = DriveWorker(
                d.drive_id, self._make_step_fn(d), cq, self._monitor,
                self._stop, epoch_of=(lambda dd=d: dd.epoch),
                faults=self.faults, speed=d.speed,
                min_tick_s=self.min_tick_s, jitter_s=self.tick_jitter_s,
                seed=self.jitter_seed * 1009 + d.drive_id,
                telemetry=self.tele)
            self._commands.append(cq)
            self._workers.append(w)
            w.start()

    def close(self) -> None:
        """Stop and join every worker thread.  Idempotent and race-safe:
        concurrent close() calls join once; a worker blocked in an
        injected hang (or sleeping out its service-time pad) is woken by
        the stop event and joins cleanly mid-tick."""
        with self._close_lock:
            self._closed = True
            workers, self._workers = self._workers, None
        if not workers:
            return
        self._stop.set()
        for cq in self._commands:
            cq.put(WorkerCommand("stop"))
        for w in workers:
            w.join(timeout=10.0)
        alive = [w.name for w in workers if w.is_alive()]
        if alive:
            raise RuntimeError(f"worker threads failed to join: {alive}")

    # shutdown is close by its production name; the context-manager form
    # guarantees the join even when a test body raises
    shutdown = close

    def __enter__(self) -> "ClusterEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def predicted_parallel_s(self) -> float:
        """The virtual-clock model's prediction of the parallel makespan
        (leading per-drive clock).  In concurrent mode the clocks advance
        by each drive's measured busy time while ``stats.cluster_s``
        accrues MEASURED join wall time — fig9 gates one against the
        other."""
        return max(self._clocks)

    def _step_concurrent(self) -> List[GenResult]:
        """One concurrent cluster tick (fork-join):

        1. under the cluster lock: deliver fault begins, shed, dispatch,
           then send one tick command to every non-failed drive with work
           and no unanswered command;
        2. join: drain the monitor queue until every outstanding command
           (including stragglers from earlier ticks) is answered or
           ``dispatch_timeout_s`` of real wall time elapses.  Payloads
           are absorbed under the cluster lock as they arrive;
        3. account the tick: the cluster wall clock advances by MEASURED
           join time (minus the largest reported lazy-compile delta) —
           overlap is real now, not modeled;
        4. the watchdog observes reply/progress per drive — silence from
           a crashed or hung worker accrues real wall time here, so
           wall-threshold detection converges even while the cluster
           clock stands still — and DEAD edges run the same fail() path
           as the serial detector.

        A drive whose command is unanswered is NOT re-dispatched (its
        ``_outstanding`` stays up), so a straggler can never be stepped
        twice concurrently; a late same-epoch reply is absorbed next
        tick and counts as progress."""
        self._ensure_workers()
        tick = self._tick
        self._tick += 1
        with self._lock:
            if self.faults is not None:
                begun = self.faults.begins(tick, self.clock)
                self.stats.faults_injected += len(begun)
                if self.tele.enabled:
                    for ev in begun:
                        self.tele.fault_injected(ev.drive_id, ev.kind,
                                                 self.clock, tick)
            shed = self._shed_queue()
            self._dispatch()
            sent = 0
            for d in self.drives:
                if d.failed or self._outstanding[d.drive_id] > 0 \
                        or not d.has_work:
                    continue
                self._commands[d.drive_id].put(
                    WorkerCommand("tick", tick, self.clock, d.epoch))
                self._outstanding[d.drive_id] += 1
                sent += 1
            waiting = sum(self._outstanding[d.drive_id]
                          for d in self.drives if not d.failed)
        out: List[GenResult] = []
        dts: List[float] = []
        admit_events: List[int] = []
        first_tok_events: List[int] = []
        n_active = 0
        progressed: set = set()
        replied: set = set()
        comp = 0.0
        t0 = time.perf_counter()
        deadline = t0 + self.dispatch_timeout_s
        while waiting > 0:
            remain = deadline - time.perf_counter()
            if remain <= 0.0:
                break
            try:
                hb = self._monitor.get(timeout=remain)
            except queue_mod.Empty:
                break
            with self._lock:
                d = self.drives[hb.drive_id]
                if d.failed or hb.epoch != d.epoch:
                    continue        # emitted before a fail(): stale
                if self._outstanding[hb.drive_id] > 0:
                    self._outstanding[hb.drive_id] -= 1
                    waiting -= 1
                replied.add(hb.drive_id)
                if hb.kind != "tick_done" or hb.payload is None:
                    continue        # liveness only (stall / hang wakeup)
                obs = hb.payload["obs"]
                dt = max(hb.busy_s - obs.compile_s, 0.0)
                comp = max(comp, obs.compile_s)
                self._absorb_tick(d, hb.payload["finished"], obs, dt, out,
                                  admit_events, first_tok_events)
                dts.append(dt)
                n_active += 1
                progressed.add(hb.drive_id)
        wall = time.perf_counter() - t0
        with self._lock:
            if progressed:
                # measured parallel wall clock: the join time IS the tick
                # cost (compiles happen once per process — subtract the
                # largest reported delta, mirroring the serial model)
                tick_s = max(wall - comp, 0.0)
                self._lead = max(self._clocks)
                self.stats.record_tick(n_active, tick_s, sum(dts))
                self.clock += tick_s
                self._idle_grace = 0
                if self.tele.enabled and tick_s > 0.0:
                    self.tele.phase("coordinator", "tick",
                                    self.clock - tick_s, tick_s,
                                    tick=tick, active=n_active)
            dead_now: List[int] = []
            for d in self.drives:
                if d.failed:
                    continue
                old, new = self.watchdog.observe(
                    d.drive_id, replied=d.drive_id in replied,
                    progressed=d.drive_id in progressed,
                    has_work=d.has_work)
                if old != new and self.tele.enabled:
                    self.tele.health_transition("watchdog", d.drive_id,
                                                old, new, self.clock)
                if new == DEAD and old != DEAD:
                    dead_now.append(d.drive_id)
                elif new == SUSPECT and old != SUSPECT:
                    self.pull.quarantine(d.drive_id)
                elif new == HEALTHY and old == SUSPECT:
                    self.pull.unquarantine(d.drive_id)
            for did in dead_now:
                self.stats.auto_failed_drives += 1
                self.fail(did)
            if self.hedge:
                self._launch_hedges()
            self.stats.health = list(self._health)
            if self.tele.enabled:
                self._publish_tick_metrics(tick)
            if not progressed and waiting == 0:
                # nothing stepped and nothing is pending on the channel:
                # fast-forward stall windows / backoffs / deadlines like
                # the serial loop (a silent drive keeps waiting > 0, so
                # real join timeouts — not this path — cover it)
                self._idle_advance(tick)
            return self._deliver(shed, out, admit_events, first_tok_events)

    def _publish_tick_metrics(self, tick: int) -> None:
        """End-of-tick snapshot into the telemetry registry: cluster wall
        clock, energy integral, queue depth, per-drive busy time and
        join-wall-vs-busy utilization.  Only finite values are published
        (NaN would poison the JSON export and the NaN bench gates)."""
        t = self.tele
        if not t.enabled:
            return
        t.counter("cluster.ticks")
        t.gauge("cluster.clock_s", self.clock)
        t.gauge("cluster.queue_depth", len(self.queue))
        t.gauge("cluster.in_flight", self.in_flight)
        if math.isfinite(self.stats.energy_j):
            t.gauge("cluster.energy_j", self.stats.energy_j)
        t.counter_sample("coordinator", "queue_depth", self.clock,
                         len(self.queue))
        wall = max(self.clock, 1e-9)
        for d in self.drives:
            busy = self._clocks[d.drive_id]
            t.gauge(f"drive.{d.drive_id}.busy_s", busy)
            # busy time on the drive's virtual clock over the cluster
            # join wall: >1 means the model claims more busy time than
            # wall passed (overlapped compile), <1 is idle/straggle
            t.gauge(f"drive.{d.drive_id}.utilization", busy / wall)

    def _settle_hedge(self, grid: int, winner: int, pair: tuple) -> None:
        """First finisher wins: cancel the losing copy, free its slot, and
        book the serving time it burned as hedge waste (the availability
        premium, priced like shed work).

        Called with the winner's rid_map entry already popped, under the
        cluster lock in concurrent mode — winner-commit and loser-cancel
        are one atomic decision.  The both-finish-same-instant race (both
        copies complete inside one joined tick) lands in ``cancel()``
        returning None because the loser's engine already finished the
        copy: the loser's rid_map entry is popped here, so when its
        result arrives it is dropped by ``_absorb_tick`` and its burn is
        booked via ``_hedge_drops``."""
        primary, hedger = pair
        loser = hedger if winner == primary else primary
        if winner == hedger:
            self.stats.hedges_won += 1
        else:
            self.stats.hedges_lost += 1
        ld = self.drives[loser]
        if ld.failed:
            if self.tele.enabled:
                self.tele.close_span(("hedge", grid), self.clock,
                                     "ok" if winner == hedger
                                     else "canceled", hedge_wasted_s=0.0)
            return                    # its copy died with the drive
        local = next((l for l, g in ld.rid_map.items() if g == grid), None)
        if local is None:
            if self.tele.enabled:
                self.tele.close_span(("hedge", grid), self.clock,
                                     "ok" if winner == hedger
                                     else "canceled", hedge_wasted_s=0.0)
            return
        ld.rid_map.pop(local)
        with ld.lock:                 # exclude the loser's mid-step worker
            wasted = ld.engine.cancel(local)
        if self.tele.enabled:
            # the hedge span closes at settlement: "ok" when the hedge
            # copy won the race, "canceled" when it lost — the loser's
            # burn is attributed on the span either way
            self.tele.close_span(("hedge", grid), self.clock,
                                 "ok" if winner == hedger else "canceled",
                                 hedge_wasted_s=float(wasted or 0.0))
        if wasted:
            self.stats.hedge_wasted_s += wasted
        elif wasted is None:
            # the copy had ALREADY finished on the loser's engine: its
            # duplicate result is pending absorption — mark it so the
            # drop books the loser's serving time as hedge waste
            self._hedge_drops[(loser, local)] = True

    def _launch_hedges(self) -> None:
        """Duplicate the oldest slot-stranded request of each SUSPECT
        drive onto the healthiest drive with capacity.  At most one hedge
        per stranded request; the copy pays no spill accounting (it is an
        availability bet, not a placement decision)."""
        for d in self.drives:
            if d.failed or self._health[d.drive_id] != SUSPECT:
                continue
            stranded = sorted(
                d.rid_map[s.rid] for s in d.engine.slots
                if s.active and s.rid in d.rid_map)
            stranded = [g for g in stranded if g not in self._hedges]
            if not stranded:
                continue
            grid = stranded[0]
            req = self._inflight.get(grid)
            if req is None:
                continue
            targets = [x for x in self.drives
                       if x.drive_id != d.drive_id and x.accepting
                       and self._health[x.drive_id] == HEALTHY
                       and x.load().capacity > 0]
            if not targets:
                continue
            t = min(targets, key=lambda x: (x.load().load, x.drive_id))
            with t.lock:
                local = t.engine.submit(req.prompt, max_new=req.max_new)
                t.rid_map[local] = grid
            self._hedges[grid] = (d.drive_id, t.drive_id)
            self.stats.hedges += 1
            if self.tele.enabled:
                self.tele.open_span(("hedge", grid), self.clock,
                                    "requests", f"hedge{grid}", rid=grid,
                                    primary=d.drive_id,
                                    hedge_drive=t.drive_id)
                self.tele.counter("cluster.hedges")

    def _idle_advance(self, tick: int) -> None:
        """A tick where nothing stepped: time must still move, or stall
        windows, retry backoffs, and deadlines would never elapse
        (graceful degradation instead of deadlock).  Tick-based events
        expire as ``step()`` calls pass, so they need no clock help;
        clock-based boundaries and backoffs fast-forward the wall clock
        (idle time, integrated at zero-active power).  When no progress
        is possible at all, the engine marks itself stuck and
        ``run_until_complete`` raises ``ClusterExhaustedError``."""
        if not (self.queue or any(d.has_work for d in self.drives)):
            return
        if self.faults is not None and \
                self.faults.next_tick_boundary(tick) is not None:
            return
        waits: List[float] = []
        if self.faults is not None:
            b = self.faults.next_clock_boundary(self.clock)
            if b is not None:
                waits.append(b)
        waits += [r.not_before_s for r in self.queue
                  if r.not_before_s > self.clock]
        if waits:
            to = min(waits)
            dt = max(to - self.clock, 0.0)
            self.clock = to
            self.stats.record_tick(0, dt, 0.0)
            self._idle_grace = 0
            return
        if any(not d.failed and d.has_work for d in self.drives):
            self._idle_grace = 0
            return       # the detector will declare them DEAD in bounded ticks
        if self._idle_grace < 1 and \
                any(r.not_before_s <= self.clock for r in self.queue) and \
                any(not d.failed and d.accepting
                    and self._health[d.drive_id] != SUSPECT
                    and d.load().capacity > 0 for d in self.drives):
            # a fail() THIS tick requeued work after dispatch already ran
            # (detection happens post-dispatch by design: dispatch uses
            # last tick's health) — give the next tick's dispatch one
            # chance before declaring the cluster exhausted
            self._idle_grace += 1
            return
        self._stuck = True

    def run_until_complete(self) -> List[GenResult]:
        while self.queue or any(d.has_work for d in self.drives):
            if self.queue and not any(d.accepting for d in self.drives) \
                    and not any(d.has_work for d in self.drives):
                raise ClusterExhaustedError(
                    f"{len(self.queue)} queued requests but every drive is "
                    f"draining/failed — nothing can serve them")
            if self._stuck:
                raise ClusterExhaustedError(
                    f"{len(self.queue)} queued requests cannot make "
                    f"progress: no drive can admit them (page pools "
                    f"clamped?) and no fault/backoff boundary is pending "
                    f"— the cluster is effectively draining/failed")
            self.step()
        if self._failout:
            self._finished.extend(self._failout)
            self._failout = []
        out, self._finished = self._finished, []
        return sorted(out, key=lambda r: r.rid)

    def generate(self, prompts: Sequence[Sequence[int]], max_new: int = 32,
                 shard_ids: Optional[Sequence[Optional[int]]] = None
                 ) -> List[GenResult]:
        """Greedy generation for a batch of prompts.  Drains the whole
        queue; results of requests queued earlier via ``submit()`` are kept
        for their caller, not discarded (same contract as
        ``ServeEngine.generate``)."""
        if shard_ids is None:
            shard_ids = [None] * len(prompts)
        if len(shard_ids) != len(prompts):
            raise ValueError("shard_ids must match prompts 1:1")
        rids = [self.submit(p, max_new=max_new, shard_id=s)
                for p, s in zip(prompts, shard_ids)]
        return collect_results(self, rids)

    # -- reporting -----------------------------------------------------------

    def kv_stats(self) -> List[Dict[str, float]]:
        return [d.engine.kv_stats() for d in self.drives]

    def drive_rates(self) -> List[float]:
        """The pull scheduler's live per-drive service-rate estimates
        (items/s; NaN until a drive has been observed)."""
        return self.pull.rates()

    def summary(self) -> str:
        rates = ", ".join("cold" if math.isnan(r) else f"{r:.1f}"
                          for r in self.drive_rates())
        speeds = ", ".join(f"{d.speed:g}" for d in self.drives)
        return (self.stats.summary()
                + f"\npull rates (items/s): [{rates}] at speed factors "
                  f"[{speeds}]"
                + (f"; quota gate on" if self.quota_gate else ""))
