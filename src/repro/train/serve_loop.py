"""Serving engine: batched prefill + greedy decode with resident KV caches.

The engine holds a fixed pool of batch slots (continuous-batching lite):
requests fill slots, prefill builds per-slot caches, decode steps run the
whole pool; finished sequences free their slots.  The caches never leave
their shards — decode attention runs the ISP path (core.decode_attention).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import model as M


@dataclass
class GenResult:
    tokens: List[int]
    prefill_s: float
    decode_s: float


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, recipe=None,
                 max_len: int = 256, eos_id: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.recipe = recipe if recipe is not None else M.LOCAL
        self.max_len = max_len
        self.eos_id = eos_id
        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_fn(p, c, t, pos, cfg, self.recipe))

    def generate(self, prompts: Sequence[Sequence[int]], max_new: int = 32) -> List[GenResult]:
        """Greedy generation for a batch of equal-length prompts."""
        b = len(prompts)
        plen = len(prompts[0])
        assert all(len(p) == plen for p in prompts), "engine pads per pool"
        tokens = jnp.asarray(np.array(prompts, np.int32))

        t0 = time.time()
        caches = M.init_caches(self.cfg, b, self.max_len)
        # teacher-forced prefill: feed the prompt through decode steps if the
        # prompt is short, else full prefill
        if plen > 8:
            nxt, pre_caches = jax.jit(
                lambda p, batch: M.prefill_fn(p, batch, self.cfg, self.recipe)
            )(self.params, {"tokens": tokens})
            # splice prefill caches into the (larger) decode cache layout
            caches = _splice_caches(caches, pre_caches, plen)
            pos = plen
        else:
            nxt = None
            pos = 0
            for i in range(plen):
                nxt, caches = self._decode(self.params, caches,
                                           tokens[:, i: i + 1], jnp.int32(i))
                pos = i + 1
        prefill_s = time.time() - t0

        t0 = time.time()
        out = [[] for _ in range(b)]
        cur = nxt[:, None].astype(jnp.int32)
        done = np.zeros(b, bool)
        for j in range(max_new):
            for i, t in enumerate(np.asarray(cur[:, 0])):
                if not done[i]:
                    out[i].append(int(t))
                    if self.eos_id is not None and int(t) == self.eos_id:
                        done[i] = True
            if done.all() or pos + j >= self.max_len - 1:
                break
            nxt, caches = self._decode(self.params, caches, cur,
                                       jnp.int32(pos + j))
            cur = nxt[:, None].astype(jnp.int32)
        decode_s = time.time() - t0
        return [GenResult(tokens=o, prefill_s=prefill_s, decode_s=decode_s)
                for o in out]


def _splice_caches(decode_caches, prefill_caches, plen: int):
    """Copy prefill cache contents into the decode-sized cache buffers."""

    def splice(path, dst, src):
        names = [str(p.key) for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        if name in ("k", "v", "ckv", "krope"):
            n = min(src.shape[2], dst.shape[2])
            return dst.at[:, :, :n].set(src[:, :, :n].astype(dst.dtype))
        if name == "kpos":
            n = min(src.shape[1], dst.shape[1])
            return dst.at[:, :n].set(src[:, :n])
        return src.astype(dst.dtype) if src.shape == dst.shape else dst

    return jax.tree_util.tree_map_with_path(splice, decode_caches, prefill_caches)
