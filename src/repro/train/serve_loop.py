"""Continuous-batching serve engine with scheduler-driven admission.

The paper's serving story (§IV-A) is a *pull* pipeline: resident state stays
on the storage side, the scheduler decides who pulls the next batch, and
only queries/results cross the link.  This engine is that story applied to
LM serving:

  request queue ──▶ admission (PullScheduler.tick + rebalance_shares)
               ──▶ slot pool (per-slot position/length tracks)
               ──▶ plan chooser (choose_embedding_plan / choose_decode_plan)
               ──▶ TransferLedger ("bytes that never crossed the link")

Mechanics:
  * the decode inner loop is device-resident (``k_block`` > 1, default):
    one jitted ``lax.while_loop`` runs up to ``k_block`` greedy steps per
    engine tick — on-device sampling, per-slot position increments,
    EOS/max-new/cache-full termination masks and KV writes — and returns a
    single (K, num_slots) token block to the host.  Cache pools are
    donated (in-place on accelerators), and tokens/positions/page-table
    live as persistent device arrays mutated with ``.at[]`` instead of
    being re-uploaded per step.  ``k_block=1`` keeps the per-step host
    loop as the reference the fused path is property-tested against;
  * KV lives in a paged pool by default (``core.kv_pages``): prefill
    allocates ``ceil(len/page_size)`` fixed-size pages per slot, decode
    pre-reserves the pages a whole K-block can touch (a host-side lookup
    before the dispatch — growth inside the scan is a pure page-table
    read), and EOS/eviction frees the slot's pages back to the free list
    in the same tick — peak KV memory and decode reads track live tokens,
    not ``num_slots * max_len``.  Admission reserves each request's
    worst-case page count, so a full pool backpressures the queue instead
    of failing mid-decode (``kv_layout="strip"`` keeps the dense per-slot
    reference layout);
  * chunked prefill (``chunk_prefill=N``): prompts longer than N are
    spliced into the paged pool one fixed-size chunk per tick, interleaved
    with decode blocks, so a long admission never stalls in-flight
    requests and the scheduler observes bounded per-tick service times;
  * variable-length prompts are admitted into a fixed pool of batch slots;
  * prefill is length-bucketed — prompts padded to a common bucket length
    batch together; pad positions are masked out of the per-slot kpos track
    afterwards, so the padded prefill is numerically exact (padding is only
    used for architectures where that holds: pure-attention stacks, window
    not exceeded — recurrent stacks fall back to exact-length buckets);
  * decode steps run the whole pool with per-slot positions — the paged
    layout walks each slot's page table in one fused pass
    (``kernels.paged_decode``: Pallas on TPU, jnp reference elsewhere);
    the strip layout uses per-slot kpos (B,S) masking (see
    ``models.attention``).  EOS / max-len finishes free the slot (and its
    pages), which is refilled from the queue on the next step, mid-decode;
  * every prefill/decode step consults the host-vs-ISP plan chooser and
    records both the chosen and the host-baseline link bytes, so
    ``stats().link_reduction`` reproduces the paper's Fig. 5 accounting
    live.
"""
from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core.isp import choose_decode_plan, choose_embedding_plan
from repro.core.kv_pages import PageAllocator, pages_for
from repro.core.latency import NAN, LatencyRecord, LatencyStats
from repro.core.scheduler import (PullScheduler, SchedulerState, make_cluster,
                                  optimal_batch_ratio, rebalance_shares,
                                  split_block_service)
from repro.core.telemetry import NULL_HUB
from repro.core.transfer import TransferLedger
from repro.models import model as M


@dataclass
class GenResult:
    tokens: List[int]
    prefill_s: float
    decode_s: float
    rid: int = 0
    tier: str = "host"
    drive: int = 0               # cluster serving: which replica served it
    status: str = "ok"           # "ok" | "shed" (deadline-expired, dropped)
                                 # | "failed" (retry budget exhausted /
                                 #   the last drive died under it)
    priority: int = 0
    # per-request latency on the serving clock (NaN until measurable):
    # queue wait (submit -> slot), TTFT (submit -> first token), TPOT
    # (inter-token cadence after the first), end-to-end (submit -> done)
    queue_wait_s: float = NAN
    ttft_s: float = NAN
    tpot_s: float = NAN
    e2e_s: float = NAN


@dataclass
class ServeStats:
    requests: int = 0
    tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    decode_steps: int = 0        # inner decode steps actually executed
    compile_s: float = 0.0       # jit pre-warm time (kept out of decode_s)
    tier_tokens: Dict[str, int] = field(default_factory=dict)
    tier_requests: Dict[str, int] = field(default_factory=dict)
    ledger: TransferLedger = field(default_factory=TransferLedger)     # chosen
    baseline: TransferLedger = field(default_factory=TransferLedger)  # host-only
    # SLO accounting: per-request latency records (serving clock) plus the
    # load-shedding tally — shed_wasted_s is serving time already spent on
    # requests that were then dropped (the energy the shed cost anyway)
    latency: LatencyStats = field(default_factory=LatencyStats)
    shed_requests: int = 0
    shed_wasted_s: float = 0.0

    @property
    def link_bytes(self) -> float:
        return self.ledger.link_bytes

    @property
    def host_link_bytes(self) -> float:
        return self.baseline.link_bytes

    @property
    def bytes_never_crossed(self) -> float:
        """Link bytes the ISP plans kept resident vs the host baseline."""
        return max(self.host_link_bytes - self.link_bytes, 0.0)

    @property
    def link_reduction(self) -> float:
        if self.host_link_bytes <= 0:
            return 0.0
        return self.bytes_never_crossed / self.host_link_bytes

    @property
    def kv_bytes_touched(self) -> float:
        """KV rows the decode kernel actually walked (paged: live pages)."""
        return self.ledger.kv_bytes

    @property
    def kv_reduction(self) -> float:
        """Fractional KV-traffic reduction vs the dense per-slot strips the
        baseline decode reads every step (0.0 for the strip layout)."""
        if self.baseline.kv_bytes <= 0:
            return 0.0
        return max(1.0 - self.ledger.kv_bytes / self.baseline.kv_bytes, 0.0)

    def tier_throughput(self, tier: str) -> float:
        dt = max(self.decode_s + self.prefill_s, 1e-9)
        return self.tier_tokens.get(tier, 0) / dt

    @property
    def steps_per_s(self) -> float:
        return self.decode_steps / max(self.decode_s, 1e-9)

    def metrics(self) -> Dict[str, float]:
        """Flat metric dict — the single source ``summary()`` renders from
        and ``launch/serve.py --metrics-out`` exports, so the printed and
        the exported numbers can never disagree."""
        m = {
            "requests": self.requests,
            "tokens": self.tokens,
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
            "decode_steps": self.decode_steps,
            "steps_per_s": self.steps_per_s,
            "compile_s": self.compile_s,
            "link_bytes": self.link_bytes,
            "host_link_bytes": self.host_link_bytes,
            "link_reduction": self.link_reduction,
            "kv_bytes": self.kv_bytes_touched,
            "kv_dense_bytes": self.baseline.kv_bytes,
            "kv_reduction": self.kv_reduction,
            "shed_requests": self.shed_requests,
            "shed_wasted_s": self.shed_wasted_s,
        }
        for tier in sorted(self.tier_tokens):
            m[f"tier.{tier}.requests"] = self.tier_requests.get(tier, 0)
            m[f"tier.{tier}.tokens"] = self.tier_tokens[tier]
            m[f"tier.{tier}.tok_per_s"] = self.tier_throughput(tier)
        return m

    def summary(self) -> str:
        m = self.metrics()
        lines = [f"requests={m['requests']} tokens={m['tokens']} "
                 f"prefill={m['prefill_s']:.2f}s "
                 f"decode={m['decode_s']:.2f}s "
                 f"({m['decode_steps']} steps, {m['steps_per_s']:.1f} "
                 f"steps/s; compile {m['compile_s']:.2f}s separate)"]
        for tier in sorted(self.tier_tokens):
            lines.append(
                f"tier[{tier}]: {m[f'tier.{tier}.requests']} reqs, "
                f"{m[f'tier.{tier}.tokens']} tok, "
                f"{m[f'tier.{tier}.tok_per_s']:.1f} tok/s")
        lines.append(
            f"link bytes: {m['link_bytes'] / 1e6:.2f} MB vs host-only "
            f"{m['host_link_bytes'] / 1e6:.2f} MB "
            f"({m['link_reduction']:.0%} never crossed the link)")
        if m["kv_dense_bytes"] > 0:
            lines.append(
                f"KV bytes touched: {m['kv_bytes'] / 1e6:.2f} MB vs "
                f"dense {m['kv_dense_bytes'] / 1e6:.2f} MB "
                f"({m['kv_reduction']:.0%} fewer KV reads)")
        if self.latency.records:
            lines.append(self.latency.summary())
        if m["shed_requests"]:
            lines.append(f"shed: {m['shed_requests']} requests "
                         f"({m['shed_wasted_s']:.3f}s serving time wasted)")
        return "\n".join(lines)


@dataclass
class TickObservation:
    """What one ``ServeEngine.step()`` actually did — the per-tick signal
    the cluster pull scheduler (``core.scheduler.ClusterAdmission``) and the
    cluster wall-clock/energy accounting consume.

    ``busy_s`` is serving wall time only; ``compile_s`` is the lazy-XLA
    share of the tick (first call at a new shape), reported separately so
    callers timing the whole tick can subtract it — compile happens once
    per process, not once per replica drive, and must not pollute the
    cluster's parallel wall-clock model or the energy integral.
    """
    busy_s: float = 0.0          # serving wall time this tick
    compile_s: float = 0.0       # lazy jit/eager-shape compile time
    tokens: int = 0              # tokens emitted this tick
    steps: int = 0               # inner decode steps executed
    per_step_items: List[int] = field(default_factory=list)
    admitted_rids: List[int] = field(default_factory=list)
    first_token_rids: List[int] = field(default_factory=list)


@dataclass
class _Request:
    rid: int
    prompt: List[int]
    max_new: int
    priority: int = 0
    deadline_s: Optional[float] = None   # absolute TTFT deadline (engine clock)


@dataclass
class _Slot:
    index: int
    active: bool = False
    rid: int = -1
    tier: str = "host"
    pos: int = 0                 # next cache position to write
    cur_token: int = 0           # input token of the next decode step
    max_new: int = 0
    out: List[int] = field(default_factory=list)
    prefill_s: float = 0.0
    decode_s: float = 0.0
    reserved_pages: int = 0      # paged layout: admission-time reservation
    prefilling: bool = False     # chunked prefill still in flight
    prefill_done_tokens: int = 0  # prompt tokens already spliced

    @property
    def decoding(self) -> bool:
        return self.active and not self.prefilling


class AdmissionController:
    """Scheduler-driven admission: which tier pulls the next requests.

    The paper's pull protocol decides, per ack, whether the host or a CSD
    gets the next batch; here each admitted request is tagged with the tier
    whose pull it rode in on (the tag drives the ledger/throughput split).
    ``rebalance_shares`` periodically refits the host:CSD batch ratio from
    observed per-tier service times — the batch-ratio rule applied online.
    In-process serving runs both tiers in one jitted batch, so observed
    per-token times are equal and the configured ratio is kept; the refit
    engages when genuinely different per-tier timings are fed to
    ``observe`` (separate devices / real CSD workers).
    """

    def __init__(self, num_slots: int, host_rate: float = 20.0,
                 csd_rate: float = 1.0, n_csds: int = 1, batch_size: int = 1,
                 poll_interval: float = 0.0, rebalance_every: int = 16):
        self.num_slots = max(num_slots, 2)
        nodes = make_cluster(host_rate, csd_rate, max(n_csds, 1),
                             host_overhead=0.0, csd_overhead=0.0)
        ratio = optimal_batch_ratio(host_rate, csd_rate)
        self.sched = PullScheduler(nodes, batch_size, ratio,
                                   poll_interval=poll_interval)
        self.state: Optional[SchedulerState] = None
        self._pending: Deque[str] = deque()
        self.shares = {"host": max(self.num_slots - 1, 1), "csd": 1}
        self._busy = {"host": 0.0, "csd": 0.0}
        self._tok = {"host": 0, "csd": 0}
        self._since_rebalance = 0
        self.rebalance_every = rebalance_every

    def tiers_for(self, n: int, queued: int) -> List[str]:
        """Tier tags for the next ``n`` admissions, in scheduler pull order."""
        out: List[str] = []
        while len(out) < n:
            if self._pending:
                out.append(self._pending.popleft())
                continue
            if self.state is None or self.state.done:
                self.state = self.sched.start(max(queued, n, 1))
            a = self.sched.tick(self.state)
            if a is None:                      # stream outlived this window
                self.state = None
                continue
            tier = "host" if a.node.is_host else "csd"
            self._pending.extend([tier] * a.n_items)
        return out

    def observe(self, tier: str, busy_s: float, tokens: int) -> None:
        """Feed measured service back; refit the batch ratio periodically.

        Negative / non-finite intervals are dropped whole: even with
        monotonic timers a caller bug (or a restored checkpoint replaying
        stale observations) must not poison the EWMA-style busy windows —
        one negative sample can flip a refit's host:CSD ratio.
        """
        if busy_s < 0.0 or not math.isfinite(busy_s):
            return
        self._busy[tier] += busy_s
        self._tok[tier] += tokens
        self._since_rebalance += 1
        if self._since_rebalance < self.rebalance_every:
            return
        if min(self._tok.values()) == 0:
            return
        self._since_rebalance = 0
        step_times = {t: self._busy[t] / self._tok[t] for t in self._tok}
        tput = {t: self._tok[t] / max(self._busy[t], 1e-9) for t in self._tok}
        # fresh window per rebalance so the refit tracks *recent* service
        # times instead of a lifetime average
        self._busy = {t: 0.0 for t in self._busy}
        self._tok = {t: 0 for t in self._tok}
        if max(step_times.values()) <= 1.10 * min(step_times.values()):
            return       # no observable tier difference: keep configured ratio
        self.shares = rebalance_shares(step_times, self.shares,
                                       self.num_slots)
        # the paper's rule, online: ratio = measured host/CSD throughput
        self.sched.batch_ratio = max(tput["host"] / max(tput["csd"], 1e-9),
                                     1e-3)


class ServeEngine:
    """Continuous-batching greedy-decode engine over a fixed slot pool."""

    def __init__(self, cfg: ModelConfig, params, recipe=None,
                 max_len: int = 256, eos_id: Optional[int] = None,
                 num_slots: int = 8, bucket_quantum: int = 8,
                 shards: int = 16,
                 admission: Optional[AdmissionController] = None,
                 kv_layout: str = "paged", page_size: int = 16,
                 num_pages: Optional[int] = None, k_block: int = 8,
                 chunk_prefill: Optional[int] = None, prewarm: bool = False,
                 jit_donor: Optional["ServeEngine"] = None,
                 admission_order: str = "fifo", chunk_budget: int = 1,
                 shed_expired: bool = True, telemetry=None):
        if kv_layout not in ("paged", "strip"):
            raise ValueError(f"kv_layout must be 'paged' or 'strip', "
                             f"got {kv_layout!r}")
        if admission_order not in ("fifo", "edf"):
            raise ValueError(f"admission_order must be 'fifo' or 'edf', "
                             f"got {admission_order!r}")
        self.cfg = cfg
        self.params = params
        self.recipe = recipe if recipe is not None else M.LOCAL
        self.max_len = max_len
        self.eos_id = eos_id
        self.num_slots = num_slots
        self.bucket_quantum = max(bucket_quantum, 1)
        self.shards = shards
        self.admission = admission if admission is not None else \
            AdmissionController(num_slots)
        # k_block: decode steps per engine tick that run device-resident in
        # ONE jitted dispatch (lax.while_loop with on-device sampling and
        # termination masks).  k_block=1 is the per-step host reference loop
        # every fused configuration is property-tested against.
        self.k_block = max(int(k_block), 1)
        if jit_donor is not None:
            # Cluster replicas share one set of jitted callables: the
            # closures only capture static wiring (cfg/recipe/k_block/
            # eos/max_len) and every mutable piece is an argument, so N
            # drives cost one XLA compile instead of N — but only if the
            # wiring is byte-identical.
            same = (jit_donor.cfg == cfg and jit_donor.recipe is self.recipe
                    and jit_donor.k_block == self.k_block
                    and jit_donor.eos_id == eos_id
                    and jit_donor.max_len == max_len)
            if not same:
                raise ValueError(
                    "jit_donor wiring (cfg/recipe/k_block/eos_id/max_len) "
                    "differs from this engine; replicas must be identical")
            self._decode = jit_donor._decode
            self._prefill = jit_donor._prefill
            self._decode_block = jit_donor._decode_block
            self._prefill_chunk = jit_donor._prefill_chunk
        else:
            self._decode = jax.jit(
                lambda p, c, t, pos: M.decode_fn(p, c, t, pos, cfg,
                                                 self.recipe))
            self._prefill = jax.jit(
                lambda p, b: M.prefill_fn(p, b, cfg, self.recipe))
            # Donate the cache pools (and the per-slot decode state) to the
            # fused block so strips/pages update in place instead of being
            # copied every call; CPU has no donation support, so skip the
            # warning noise there.
            donate = (1, 2, 3, 4, 5) if jax.default_backend() != "cpu" else ()
            self._decode_block = jax.jit(
                lambda p, c, t, pos, alive, rem: M.decode_block_fn(
                    p, c, t, pos, alive, rem, cfg, self.recipe,
                    k_steps=self.k_block, eos_id=eos_id, max_len=max_len),
                donate_argnums=donate)
            self._prefill_chunk = jax.jit(
                lambda p, c, t, qpos, last: M.prefill_chunk_fn(
                    p, c, t, qpos, last, cfg, self.recipe),
                donate_argnums=(1,) if donate else ())
        # KV layout: "paged" (default) keeps full-attention KV in fixed-size
        # pages handed out by a free-list allocator — memory and decode
        # reads track live tokens; "strip" is the dense per-slot reference
        # layout (one max_len strip per slot).
        self.kv_layout = kv_layout if self._has_paged_layers() else "strip"
        self.page_size = max(page_size, 1)
        self._maxp = pages_for(max_len, self.page_size)
        # chunk_prefill: split prompts longer than this into chunk-sized
        # pieces spliced into the paged pool one chunk per engine tick, so a
        # long admission never stalls in-flight decodes for more than one
        # chunk's worth of work.  Incremental splice needs the paged layout
        # and a pure full-attention stack (window rings and recurrent state
        # would have to carry chunk-crossing state).
        self.chunk_prefill: Optional[int] = None
        if chunk_prefill and self.kv_layout == "paged" and \
                all(k in ("attn", "moe") for k in cfg.layer_pattern):
            self.chunk_prefill = max(int(chunk_prefill), 1)
        if self.kv_layout == "paged":
            if num_pages is None:
                num_pages = num_slots * self._maxp        # dense worst case
            self.pager: Optional[PageAllocator] = PageAllocator(
                num_pages, self.page_size)
            self.page_table = np.full((num_slots, self._maxp), -1, np.int32)
            self.caches = M.init_caches(cfg, num_slots, max_len, paged=True,
                                        page_size=self.page_size,
                                        num_pages=num_pages)
            # device-resident page table: the single device copy, mutated
            # with .at[] sets as slots are admitted/grown/finished — never
            # re-uploaded wholesale (mid-prefill slots keep -1 rows so
            # decode writes route to the scratch page until splice is done)
            self._pages_dev = jnp.full((num_slots, self._maxp), -1,
                                       jnp.int32)
            self._sync_pages_leaves()
        else:
            self.pager = None
            self.page_table = None
            self._pages_dev = None
            self.caches = M.init_caches(cfg, num_slots, max_len, per_slot=True)
        # per-slot decode state for the fused block: persistent device
        # arrays mutated with .at[] at admission/finish, round-tripped
        # through the block — never rebuilt/re-uploaded per step
        self._tok_dev = jnp.zeros((num_slots,), jnp.int32)
        self._pos_dev = jnp.zeros((num_slots,), jnp.int32)
        self._alive_dev = jnp.zeros((num_slots,), bool)
        self._rem_dev = jnp.zeros((num_slots,), jnp.int32)
        self.slots = [_Slot(index=i) for i in range(num_slots)]
        self.queue: Deque[_Request] = deque()
        self.stats = ServeStats()
        self.ledger = self.stats.ledger          # chosen-plan link bytes
        self.baseline = self.stats.baseline      # everything-to-host baseline
        self._next_rid = 0
        self._finished: List[GenResult] = []
        # SLO-aware admission: "edf" stable-sorts the queue by absolute
        # deadline (earliest first; no-deadline requests last, FIFO within
        # each (deadline, priority) class); chunk_budget is the number of
        # prefill chunks one tick may run — >1 accelerates admission at the
        # cost of decode TTFT/TPOT in the same tick; shed_expired drops
        # requests whose deadline already passed (queued ones for free,
        # mid-prefill ones counting their spent serving time as waste)
        self.admission_order = admission_order
        self.chunk_budget = max(int(chunk_budget), 1)
        self.shed_expired = shed_expired
        # fault injection (page_pool_clamp): only this fraction of the KV
        # page pool is admissible — NEW admissions backpressure against the
        # clamped capacity, while in-flight requests keep their full
        # worst-case reservation (a clamp degrades, it never fails a
        # flying batch).  1.0 = unclamped; the cluster tier sets it per
        # tick from the active fault schedule.
        self.pool_clamp_frac = 1.0
        # virtual serving clock: advances by measured serving time (compile
        # excluded) and fast-forwards across idle via advance_clock() — all
        # LatencyRecord timestamps live on it
        self.clock = 0.0
        self.records: Dict[int, LatencyRecord] = {}
        # telemetry: events stamp this engine's virtual clock on
        # ``tele_track``; the cluster re-points the track per drive and
        # turns ``tele_requests`` off (drive-local rids would collide with
        # cluster-global ones — the coordinator owns request spans there)
        self.tele = telemetry if telemetry is not None else NULL_HUB
        self.tele_track = "engine"
        self.tele_requests = True
        # lazy-compile attribution: the first call at a new (site, shape)
        # key is XLA compile, not serving — its wall time goes to
        # stats.compile_s (and the tick observation) instead of
        # prefill_s/decode_s.  prewarm() registers its keys here so a
        # pre-warmed engine's first real calls count as serving, and
        # replicas SHARE their donor's live set (jit executables are
        # cached per shared callable and eager ones process-wide, so a
        # shape any replica has run is warm for all of them).
        self._warm_keys: set = set() if jit_donor is None \
            else jit_donor._warm_keys
        self._tick_compile_s = 0.0
        self.last_tick = TickObservation()
        if prewarm:
            self.prewarm()

    # -- paged KV bookkeeping ------------------------------------------------

    def _has_paged_layers(self) -> bool:
        """Paged pools exist only for full-attention GQA layers; a model with
        none (pure window/recurrent/MLA stacks) serves on the strip layout."""
        return any(k in ("attn", "moe") for k in self.cfg.layer_pattern)

    def _sync_pages_leaves(self) -> None:
        """Point every group's ``pages`` cache leaf at the device page
        table.  Called only when the table actually changed (admission,
        block-granular growth, finish) — the per-step full re-push of the
        host table is gone; ``_pages_dev`` is mutated with .at[] sets."""
        for g, cache in self.caches.items():
            if isinstance(cache, dict) and "pages" in cache:
                ng = cache["pages"].shape[0]
                self.caches[g] = dict(cache, pages=jnp.broadcast_to(
                    self._pages_dev[None], (ng,) + self._pages_dev.shape))

    def _set_pages_rows(self, slot_ids: List[int]) -> None:
        """Copy the host table's rows for ``slot_ids`` to the device table."""
        t0 = time.perf_counter()
        idx = jnp.asarray(slot_ids, jnp.int32)
        rows = jnp.asarray(self.page_table[np.asarray(slot_ids)])
        self._pages_dev = self._pages_dev.at[idx].set(rows)
        self._sync_pages_leaves()
        # first call per row count: the eager scatter/broadcast executables
        # compile — attribute that to compile_s, not the serving tick
        self._serving_time(("set_rows", len(slot_ids)), time.perf_counter() - t0)

    def _sync_slot_dev(self, slots: List[_Slot]) -> None:
        """Refresh the device-side decode state of ``slots`` (post-prefill /
        post-finish) with .at[] scatters — the only host→device traffic the
        fused loop needs between blocks."""
        t0 = time.perf_counter()
        idx = jnp.asarray([s.index for s in slots], jnp.int32)
        self._tok_dev = self._tok_dev.at[idx].set(
            jnp.asarray([s.cur_token for s in slots], jnp.int32))
        self._pos_dev = self._pos_dev.at[idx].set(
            jnp.asarray([s.pos for s in slots], jnp.int32))
        self._alive_dev = self._alive_dev.at[idx].set(
            jnp.asarray([s.decoding for s in slots], bool))
        self._rem_dev = self._rem_dev.at[idx].set(
            jnp.asarray([max(s.max_new - len(s.out), 0) for s in slots],
                        jnp.int32))
        self._serving_time(("sync_slot", len(slots)), time.perf_counter() - t0)

    def _reservation(self, prompt_len: int, max_new: int) -> int:
        """Pages a request can ever need: prompt + generated tokens, capped
        at max_len.  Reserving (not allocating) this at admission makes
        mid-decode allocation infallible — the pool backpressures at
        admission instead of failing a flying batch."""
        return pages_for(min(prompt_len + max_new, self.max_len),
                         self.page_size)

    def _reservable_pages(self) -> int:
        """Free pages not spoken for by active slots' unallocated tail.

        Under a ``pool_clamp_frac`` fault only that fraction of the pool
        is admissible: the clamp shrinks what NEW admissions may reserve
        (possibly below what is already live — then nothing is admissible
        until the clamp lifts or slots free), but never touches in-flight
        reservations, so mid-decode allocation stays infallible."""
        outstanding = sum(
            s.reserved_pages - int((self.page_table[s.index] >= 0).sum())
            for s in self.slots if s.active)
        free = self.pager.num_free
        if self.pool_clamp_frac < 1.0:
            cap = int(self.pager.num_pages * self.pool_clamp_frac)
            free = min(free, cap - self.pager.num_in_use)
        return free - outstanding

    def _kv_bytes_per_token(self) -> int:
        """K+V bytes one token row costs across all paged-eligible (full
        GQA) layers — the single source for kv_stats and the step ledger."""
        n_kv_layers = sum(k in ("attn", "moe") for k in self.cfg.layer_pattern)
        return 2 * self.cfg.num_kv_heads * self.cfg.resolved_head_dim \
            * jnp.dtype(self.cfg.dtype).itemsize * n_kv_layers

    def kv_stats(self) -> Dict[str, float]:
        """Live/peak KV footprint vs the dense per-slot baseline (bytes)."""
        per_token = self._kv_bytes_per_token()
        dense_tokens = self.num_slots * self.max_len
        if self.kv_layout == "paged":
            live = self.pager.num_in_use * self.page_size
            peak = self.pager.peak_pages * self.page_size
            pool = self.pager.num_pages * self.page_size
        else:
            live = peak = pool = dense_tokens
        return {"layout": self.kv_layout, "page_size": self.page_size,
                "live_kv_bytes": live * per_token,
                "peak_kv_bytes": peak * per_token,
                "pool_kv_bytes": pool * per_token,
                "dense_kv_bytes": dense_tokens * per_token}

    # -- compile attribution -------------------------------------------------

    def _serving_time(self, key, dt: float) -> float:
        """Split a measured call between serving and lazy compile.

        The first call at a new (site, shape) key triggers an XLA compile
        that dwarfs the actual run (seconds vs milliseconds), so the whole
        first-call wall time is booked as ``compile_s`` and the call
        contributes zero serving time — undercounting one warm run per
        shape, which is noise next to attributing a compile to serving.
        Returns the serving time to account (``dt`` once the key is warm).
        """
        if key in self._warm_keys:
            return dt
        self._warm_keys.add(key)
        self.stats.compile_s += dt
        self._tick_compile_s += dt
        return 0.0

    # -- jit pre-warm --------------------------------------------------------

    def prewarm(self) -> float:
        """Compile every jitted entry point this engine can hit before the
        first request: the decode block (or the K=1 step), every prefill
        bucket shape up to ``max_len`` (the batch dimension is fixed at
        ``num_slots``, so each bucket length is exactly one compile) and the
        chunk-prefill shape.  First-request latency and ``decode_s`` then
        measure serving, not compilation; the compile time is reported
        separately as ``ServeStats.compile_s``.  Returns total compile_s.
        """
        t0 = time.perf_counter()
        if self.k_block > 1:
            # all slots start dead, so the while_loop compiles fully but
            # executes zero steps — caches stay untouched
            self._warm_keys.add(("decode_block",))
            out = self._decode_block(self.params, self.caches, self._tok_dev,
                                     self._pos_dev, self._alive_dev,
                                     self._rem_dev)
            jax.block_until_ready(out)
            (_, _, self._tok_dev, self._pos_dev, self._alive_dev,
             self._rem_dev, self.caches) = out
        else:
            # an all-inactive step: paged writes land in the scratch page;
            # strip writes stamp position 0, which every admission splice
            # resets before it is ever read
            self._warm_keys.add(("decode",))
            nxt, caches = self._decode(
                self.params, self.caches,
                jnp.zeros((self.num_slots, 1), jnp.int32),
                jnp.zeros((self.num_slots,), jnp.int32))
            jax.block_until_ready(nxt)
            self.caches = caches
        buckets = sorted({self._bucket_len(n)
                          for n in range(1, self.max_len)})
        if len(buckets) <= self.max_len // self.bucket_quantum + 2:
            # bounded bucket set (padding-safe archs); exact-length
            # bucketing (recurrent stacks) would mean max_len compiles —
            # those engines warm lazily per length instead
            for padded in buckets:
                batch = {"tokens": jnp.zeros((self.num_slots, padded),
                                             jnp.int32),
                         "lengths": jnp.ones((self.num_slots,), jnp.int32)}
                self._warm_keys.add(("prefill", padded))
                jax.block_until_ready(self._prefill(self.params, batch)[0])
        if self.chunk_prefill is not None:
            # an all-pad chunk against an empty page row: every write routes
            # to the scratch page.  The pool view is donated, so keep the
            # returned kp/vp leaves (only scratch rows changed).
            self._warm_keys.add(("chunk",))
            view = self._chunk_view(np.full((self._maxp,), -1, np.int32))
            tokens = jnp.zeros((1, self.chunk_prefill), jnp.int32)
            qpos = jnp.full((1, self.chunk_prefill), -1, jnp.int32)
            nxt, new_view = self._prefill_chunk(
                self.params, view, tokens, qpos, jnp.zeros((1,), jnp.int32))
            jax.block_until_ready(nxt)
            for g, cache in new_view.items():
                self.caches[g] = dict(self.caches[g], kp=cache["kp"],
                                      vp=cache["vp"])
        dt = time.perf_counter() - t0
        self.stats.compile_s += dt
        return dt

    # -- request intake ------------------------------------------------------

    def validate_request(self, prompt: Sequence[int],
                         max_new: int = 32) -> None:
        """Raise ValueError if this engine can never serve the request —
        shared by ``submit`` and the cluster dispatcher (which must reject
        a bad request at enqueue time, not mid-dispatch)."""
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) >= self.max_len:
            raise ValueError(f"prompt ({len(prompt)}) must fit below "
                             f"max_len ({self.max_len})")
        if self.kv_layout == "paged" and \
                self._reservation(len(prompt), max_new) > self.pager.num_pages:
            raise ValueError(
                f"request needs {self._reservation(len(prompt), max_new)} KV "
                f"pages but the pool only has {self.pager.num_pages}")

    def submit(self, prompt: Sequence[int], max_new: int = 32,
               priority: int = 0,
               deadline_s: Optional[float] = None) -> int:
        """Enqueue a request; ``deadline_s`` is an ABSOLUTE first-token
        deadline on the engine's serving clock (None = best-effort)."""
        prompt = list(prompt)
        self.validate_request(prompt, max_new)
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(_Request(rid, prompt, max_new, priority,
                                   deadline_s))
        self.records[rid] = LatencyRecord(rid=rid, priority=priority,
                                          deadline_s=deadline_s,
                                          submit_t=self.clock)
        if self.tele.enabled and self.tele_requests:
            self.tele.open_request(rid, self.clock, priority=priority,
                                   prompt_len=len(prompt), max_new=max_new)
        return rid

    def cancel(self, rid: int) -> Optional[float]:
        """Abort a request WITHOUT producing a result — the cluster's
        hedged dispatch uses this to retire the losing copy once the other
        drive finished first.  Returns the serving seconds already burned
        on the copy (0.0 if it was still queued), or None if the rid is
        unknown (already finished — the caller lost the race).  The
        latency record is dropped too: the surviving copy owns it."""
        for i, req in enumerate(self.queue):
            if req.rid == rid:
                del self.queue[i]
                self.records.pop(rid, None)
                if self.tele.enabled and self.tele_requests:
                    self.tele.close_request(rid, self.clock, "canceled",
                                            wasted_s=0.0)
                return 0.0
        for s in self.slots:
            if s.active and s.rid == rid:
                wasted = s.prefill_s + s.decode_s
                was_decoding = s.decoding
                self.records.pop(rid, None)
                if self.tele.enabled and self.tele_requests:
                    self.tele.close_request(rid, self.clock, "canceled",
                                            wasted_s=wasted)
                self._release_slot(s)
                if was_decoding and self.k_block > 1:
                    # the fused block keeps liveness on device; a released
                    # slot must be marked dead there or the next block
                    # would keep decoding into freed (re-allocatable) pages
                    self._sync_slot_dev([s])
                return wasted
        self.records.pop(rid, None)
        return None

    # -- serving clock + shedding --------------------------------------------

    def advance_clock(self, to_t: float) -> None:
        """Fast-forward the serving clock across an idle gap (open-loop
        replay: wall time passes even when no work is in flight).  The
        clock never moves backwards."""
        self.clock = max(self.clock, to_t)

    def _shed_expired(self) -> None:
        """Drop requests whose deadline already passed — they cannot make
        their SLO even if served right now, so serving them only burns
        capacity others need.  Queued requests shed for free; a mid-prefill
        slot sheds with its spent serving time booked as waste."""
        if not self.shed_expired:
            return
        if any(r.deadline_s is not None and r.deadline_s < self.clock
               for r in self.queue):
            keep: Deque[_Request] = deque()
            for req in self.queue:
                if req.deadline_s is not None and req.deadline_s < self.clock:
                    self._shed(req.rid, req.priority, wasted_s=0.0)
                else:
                    keep.append(req)
            self.queue = keep
        for s in self.slots:
            if not (s.active and s.prefilling):
                continue
            rec = self.records.get(s.rid)
            if rec is not None and rec.deadline_s is not None \
                    and rec.deadline_s < self.clock:
                self._shed(s.rid, rec.priority, wasted_s=s.prefill_s,
                           prefill_s=s.prefill_s)
                self._release_slot(s)

    def _shed(self, rid: int, priority: int, wasted_s: float,
              prefill_s: float = 0.0) -> None:
        """Record one shed request: a 'shed' GenResult for the caller, its
        latency record closed out, and the waste tallied."""
        self.stats.shed_requests += 1
        self.stats.shed_wasted_s += wasted_s
        rec = self.records.pop(rid, None)
        res = GenResult(tokens=[], prefill_s=prefill_s, decode_s=0.0,
                        rid=rid, status="shed", priority=priority)
        if rec is not None:
            rec.finish_t = self.clock
            rec.status = "shed"
            self.stats.latency.add(rec)
            res.e2e_s = rec.e2e_s
            res.queue_wait_s = rec.queue_wait_s
        if self.tele.enabled:
            self.tele.counter("engine.shed")
            if self.tele_requests:
                self.tele.close_request(rid, self.clock, "shed",
                                        wasted_s=wasted_s)
        self._finished.append(res)

    # -- bucketing -----------------------------------------------------------

    def _padding_safe(self, padded_len: int) -> bool:
        """Padded prefill is exact iff no recurrent state integrates pad
        tokens and no sliding-window ring evicts real prompt positions."""
        kinds = set(self.cfg.layer_pattern)
        if kinds & {"hybrid", "mlstm", "slstm"}:
            return False
        if "local" in kinds and self.cfg.attn.window is not None \
                and padded_len > self.cfg.attn.window:
            return False
        return True

    def _bucket_len(self, n: int) -> int:
        q = self.bucket_quantum
        padded = min(-(-n // q) * q, self.max_len - 1)
        return padded if padded > n and self._padding_safe(padded) else n

    # -- engine steps --------------------------------------------------------

    @property
    def num_active(self) -> int:
        return sum(s.active for s in self.slots)

    @property
    def pending(self) -> int:
        return len(self.queue)

    @property
    def bytes_never_crossed(self) -> float:
        """Live counter: link bytes kept resident so far (paper Fig. 5)."""
        return self.stats.bytes_never_crossed

    def step(self) -> List[GenResult]:
        """One engine tick: admit into free slots, advance one chunk of any
        in-flight chunked prefill, then run one decode block (``k_block``
        fused steps on device; ``k_block=1`` is the per-step host reference
        loop).  Returns the requests that finished during this tick;
        ``last_tick`` describes the tick for the cluster scheduler."""
        n_before = len(self._finished)
        self.last_tick = obs = TickObservation()
        self._tick_compile_s = 0.0
        tok0, steps0 = self.stats.tokens, self.stats.decode_steps
        busy0 = self.stats.prefill_s + self.stats.decode_s
        self._shed_expired()
        self._admit()
        if self.chunk_prefill is not None:
            self._chunk_prefill_tick()
        if any(s.decoding for s in self.slots):
            if self.k_block > 1:
                self._decode_block_step()
            else:
                self._decode_step()
        obs.compile_s = self._tick_compile_s
        obs.tokens = self.stats.tokens - tok0
        obs.steps = self.stats.decode_steps - steps0
        obs.busy_s = self.stats.prefill_s + self.stats.decode_s - busy0
        if not obs.per_step_items and obs.tokens:
            # prefill-only / K=1 ticks: one aggregate sample
            obs.per_step_items = [obs.tokens]
        if self.tele.enabled:
            self.tele.counter(f"{self.tele_track}.ticks")
            self.tele.counter(f"{self.tele_track}.tokens", obs.tokens)
            self.tele.gauge(f"{self.tele_track}.clock_s", self.clock)
            self.tele.counter_sample(self.tele_track, "queue_depth",
                                     self.clock, len(self.queue))
            if obs.busy_s > 0:
                self.tele.observe("tick_busy_s", obs.busy_s)
        return self._finished[n_before:]

    def run_until_complete(self) -> List[GenResult]:
        while self.queue or self.num_active:
            self.step()
        out, self._finished = self._finished, []
        return sorted(out, key=lambda r: r.rid)

    def generate(self, prompts: Sequence[Sequence[int]],
                 max_new: int = 32) -> List[GenResult]:
        """Greedy generation for a batch of (possibly mixed-length) prompts.

        Drains the whole queue; results of requests queued earlier via
        ``submit()`` are kept for their caller, not discarded.
        """
        rids = [self.submit(p, max_new) for p in prompts]
        return collect_results(self, rids)

    # -- admission + prefill -------------------------------------------------

    def _admit(self) -> None:
        free = [s for s in self.slots if not s.active]
        n = min(len(free), len(self.queue))
        if n == 0:
            return
        if self.admission_order == "edf" and len(self.queue) > 1:
            # earliest deadline first; no-deadline requests last.  The sort
            # is stable and ties break on rid, so FIFO order is preserved
            # within a (deadline, priority) class.
            self.queue = deque(sorted(
                self.queue,
                key=lambda r: (r.deadline_s if r.deadline_s is not None
                               else math.inf, r.priority, r.rid)))
        if self.kv_layout == "paged":
            # Backpressure at the pool: admit (FIFO) only while the pool can
            # still reserve each request's worst case — a request that does
            # not fit waits queued, it never fails mid-flight.
            budget = self._reservable_pages()
            fits = 0
            for req in list(self.queue)[:n]:
                need = self._reservation(len(req.prompt), req.max_new)
                if need > budget:
                    break
                budget -= need
                fits += 1
            n = fits
            if n == 0:
                return
        tiers = self.admission.tiers_for(n, queued=len(self.queue))
        admitted: List[_Slot] = []
        for slot, tier in zip(free, tiers):
            req = self.queue.popleft()
            slot.active = True
            slot.rid = req.rid
            slot.tier = tier
            slot.pos = len(req.prompt)
            slot.max_new = req.max_new
            slot.out = []
            slot.prefill_s = 0.0
            slot.decode_s = 0.0
            slot.prefilling = self.chunk_prefill is not None and \
                len(req.prompt) > self.chunk_prefill
            slot.prefill_done_tokens = 0
            slot._prompt = req.prompt          # consumed by the bucket pass
            if self.kv_layout == "paged":
                slot.reserved_pages = self._reservation(len(req.prompt),
                                                        req.max_new)
                pages = self.pager.alloc(pages_for(len(req.prompt),
                                                   self.page_size))
                self.page_table[slot.index, :] = -1
                self.page_table[slot.index, : len(pages)] = pages
            admitted.append(slot)
            self.last_tick.admitted_rids.append(req.rid)
            rec = self.records.get(req.rid)
            if rec is not None:
                rec.admit_t = self.clock
            if self.tele.enabled and self.tele_requests:
                self.tele.request_point(req.rid, "admit", self.clock,
                                        tier=tier)
            self.stats.requests += 1
            self.stats.tier_requests[tier] = \
                self.stats.tier_requests.get(tier, 0) + 1
        oneshot = [s for s in admitted if not s.prefilling]
        if self.kv_layout == "paged" and oneshot:
            # mid-prefill slots keep their device row -1 (decode writes hit
            # the scratch page) until their last chunk is spliced
            self._set_pages_rows([s.index for s in oneshot])

        buckets: Dict[int, List[_Slot]] = {}
        for slot in oneshot:
            buckets.setdefault(self._bucket_len(len(slot._prompt)),
                               []).append(slot)
        for padded, group in sorted(buckets.items()):
            self._prefill_bucket(group, padded)

    def _prefill_bucket(self, group: List[_Slot], padded: int) -> None:
        b = len(group)
        lengths = [len(s._prompt) for s in group]
        # fixed batch dimension: pad the bucket with dummy length-1 rows so
        # each bucket length compiles exactly once (pre-warmable) instead of
        # once per admission group size; rows are independent, so the pads
        # cost compute but never touch the real rows' math
        tokens = np.zeros((self.num_slots, padded), np.int32)
        lens = np.ones((self.num_slots,), np.int32)
        for i, s in enumerate(group):
            tokens[i, : lengths[i]] = s._prompt
            lens[i] = lengths[i]
        t0 = time.perf_counter()
        batch = {"tokens": jnp.asarray(tokens),
                 "lengths": jnp.asarray(lens)}
        nxt, pre_caches = self._prefill(self.params, batch)
        jax.block_until_ready(nxt)
        t1 = time.perf_counter()
        # prefill jit is keyed by the bucket length; the splice runs eager
        # gather/scatter executables keyed by the total token count — both
        # compile lazily on first sight, and that wall time is XLA, not
        # serving (see _serving_time)
        dt = self._serving_time(("prefill", padded), t1 - t0)
        self.caches = _splice_slots(self.caches, pre_caches,
                                    [s.index for s in group], lengths,
                                    self.page_table, self.page_size)
        # the eager splice executables are shaped by BOTH the gathered src
        # leaves (padded) and the index arrays (total tokens) — a new
        # padded length with a previously seen total is still a fresh
        # compile, so the key needs both
        splice_key = ("splice", padded, sum(lengths)) \
            if self.kv_layout == "paged" else ("splice", b, padded)
        dt += self._serving_time(splice_key, time.perf_counter() - t1)
        self._account_prefill(sum(lengths))
        self.clock += dt               # first tokens are stamped post-prefill
        if self.tele.enabled:
            self.tele.phase(self.tele_track, "prefill", self.clock - dt, dt,
                            batch=b, padded=padded)
        for i, s in enumerate(group):
            s.prefill_s = dt
            s.cur_token = int(nxt[i])
            self.stats.prefill_s += dt / b
            del s._prompt
            # the prefill-sampled token is the first generated token
            self._push_token(s, s.cur_token)
        if self.k_block > 1:
            self._sync_slot_dev(group)

    def _chunk_prefill_tick(self) -> None:
        """Advance up to ``chunk_budget`` prefill chunks this tick.

        Long prompts no longer monopolize a tick: each tick splices a
        bounded number of fixed-size chunks into the paged pool and then
        still runs a decode block for everyone else, so the scheduler's
        ``observe()`` samples stay bounded by the budget + one block
        instead of one whole prompt.  ``chunk_budget=1`` (default) is the
        decode-protecting setting: in-flight TPOT/TTFT see at most one
        chunk of prefill interference per tick; larger budgets admit long
        prompts faster at the decode tail's expense.
        """
        for _ in range(self.chunk_budget):
            slot = next((s for s in self.slots if s.active and s.prefilling),
                        None)
            if slot is None:
                return
            self._advance_chunk(slot)

    def _advance_chunk(self, slot: _Slot) -> None:
        chunk = self.chunk_prefill
        prompt = slot._prompt
        c0 = slot.prefill_done_tokens
        real = min(chunk, len(prompt) - c0)
        tokens = np.zeros((1, chunk), np.int32)
        tokens[0, :real] = prompt[c0: c0 + real]
        qpos = np.full((1, chunk), -1, np.int32)
        qpos[0, :real] = np.arange(c0, c0 + real, dtype=np.int32)
        view = self._chunk_view(self.page_table[slot.index])
        t0 = time.perf_counter()
        nxt, new_view = self._prefill_chunk(
            self.params, view, jnp.asarray(tokens), jnp.asarray(qpos),
            jnp.asarray([real - 1], jnp.int32))
        jax.block_until_ready(nxt)
        dt = self._serving_time(("chunk",), time.perf_counter() - t0)
        self.clock += dt
        if self.tele.enabled:
            self.tele.phase(self.tele_track, "prefill_chunk",
                            self.clock - dt, dt, rid=slot.rid, tokens=real)
        for g, cache in new_view.items():
            if isinstance(cache, dict) and "kp" in cache:
                self.caches[g] = dict(self.caches[g], kp=cache["kp"],
                                      vp=cache["vp"])
        slot.prefill_done_tokens = c0 + real
        slot.prefill_s += dt
        self.stats.prefill_s += dt
        self._account_prefill(real)
        if slot.prefill_done_tokens == len(prompt):
            slot.prefilling = False
            slot.cur_token = int(nxt[0])
            del slot._prompt
            self._set_pages_rows([slot.index])
            self._push_token(slot, slot.cur_token)
            if self.k_block > 1:
                self._sync_slot_dev([slot])

    def _chunk_view(self, table_row: np.ndarray):
        """B=1 view of the paged caches for one slot: the shared kp/vp
        pools under the slot's own page-table row — the chunk splices into
        the pool without the other slots' batch dimension in the program."""
        row = jnp.asarray(table_row[None])            # (1, maxp)
        view = {}
        for g, cache in self.caches.items():
            ng = cache["pages"].shape[0]
            view[g] = dict(cache, pages=jnp.broadcast_to(
                row[None], (ng,) + row.shape))
        return view

    # -- decode --------------------------------------------------------------

    def _decode_step(self) -> None:
        """K=1 host reference loop: one decode step, one token readback per
        slot.  The fused block (``_decode_block_step``) must stay
        token-identical to this path."""
        tokens = np.zeros((self.num_slots, 1), np.int32)
        positions = np.zeros((self.num_slots,), np.int32)
        for s in self.slots:
            if s.decoding:
                tokens[s.index, 0] = s.cur_token
                positions[s.index] = s.pos
        if self.kv_layout == "paged":
            self._grow_pages(1)
        t0 = time.perf_counter()
        nxt, self.caches = self._decode(self.params, self.caches,
                                        jnp.asarray(tokens),
                                        jnp.asarray(positions))
        nxt = np.asarray(nxt)
        dt = self._serving_time(("decode",), time.perf_counter() - t0)
        self.stats.decode_s += dt
        self.stats.decode_steps += 1
        self.clock += dt
        if self.tele.enabled:
            self.tele.phase(self.tele_track, "decode", self.clock - dt, dt,
                            steps=1)

        active = [s for s in self.slots if s.decoding]
        self._observe_step(active, dt)
        for s in active:
            s.decode_s += dt
            s.pos += 1
            s.cur_token = int(nxt[s.index])
            self._push_token(s, s.cur_token)

    def _observe_step(self, live: List[_Slot], step_s: float) -> None:
        """Per-decode-step ledger + scheduler bookkeeping — the single
        accounting path shared by the K=1 loop and the fused block's
        replay, so stats/rebalance behavior cannot drift between them."""
        self._account_decode(len(live), int(max(s.pos for s in live)) + 1)
        tier_counts: Dict[str, int] = {}
        for s in live:
            tier_counts[s.tier] = tier_counts.get(s.tier, 0) + 1
        for tier, cnt in tier_counts.items():
            self.admission.observe(tier, step_s * cnt / len(live), cnt)

    def _decode_block_step(self) -> None:
        """Fused device-resident tick: up to ``k_block`` decode steps in one
        jitted dispatch.  The only per-block host↔device traffic is the
        (K, num_slots) token block coming back; sampling, positions and
        termination masks live on device, and the cache pools are donated so
        they update in place.  The host then *replays* the block's per-step
        bookkeeping (stats, ledger, scheduler observations, page frees)
        from the token block alone."""
        if self.kv_layout == "paged":
            # pre-reserve the whole block's pages so growth inside the scan
            # is a pure page-table lookup (reservation makes this infallible)
            self._grow_pages(self.k_block)
        t0 = time.perf_counter()
        out = self._decode_block(self.params, self.caches, self._tok_dev,
                                 self._pos_dev, self._alive_dev,
                                 self._rem_dev)
        block, n_steps, tok, pos, alive, rem, caches = out
        self.caches = caches
        self._tok_dev, self._pos_dev = tok, pos
        self._alive_dev, self._rem_dev = alive, rem
        block = np.asarray(block)                 # ONE readback per block
        n_steps = int(n_steps)
        dt = self._serving_time(("decode_block",), time.perf_counter() - t0)
        self.stats.decode_s += dt
        self.stats.decode_steps += n_steps

        active = [s for s in self.slots if s.decoding]
        # a slot emitted at step i iff its token row is >= 0 — the live
        # counts drive the proportional split of the block's wall time
        emitted = block[:n_steps, [s.index for s in active]] >= 0
        self.last_tick.per_step_items = emitted.sum(axis=1).tolist()
        per_step = split_block_service(dt, self.last_tick.per_step_items)
        clock_end = self.clock + dt
        for i in range(n_steps):
            live = [s for s in active if s.decoding]
            if not live:
                break
            # the clock advances per replayed step so first-token /
            # completion stamps land at the step's share of the block, not
            # all at the block boundary
            self.clock += per_step[i]
            self._observe_step(live, per_step[i])
            for s in live:
                t = int(block[i, s.index])
                assert t >= 0, "device/host liveness diverged"
                s.decode_s += per_step[i]
                s.pos += 1
                s.cur_token = t
                self._push_token(s, t)
        # per_step sums to dt; pin the block end exactly (fp drift, early
        # break when every slot finished mid-block)
        self.clock = max(self.clock, clock_end)
        if self.tele.enabled:
            self.tele.phase(self.tele_track, "decode_block", clock_end - dt,
                            dt, steps=n_steps)

    def _push_token(self, slot: _Slot, tok: int) -> None:
        """Record a generated token and finish/evict the slot if done."""
        if slot.max_new <= 0:
            self._finish(slot)
            return
        slot.out.append(tok)
        if len(slot.out) == 1:
            rec = self.records.get(slot.rid)
            if rec is not None and not math.isfinite(rec.first_token_t):
                rec.first_token_t = self.clock
            self.last_tick.first_token_rids.append(slot.rid)
            if self.tele.enabled and self.tele_requests:
                self.tele.request_point(slot.rid, "first_token", self.clock)
        self.stats.tokens += 1
        self.stats.tier_tokens[slot.tier] = \
            self.stats.tier_tokens.get(slot.tier, 0) + 1
        eos = self.eos_id is not None and tok == self.eos_id
        full = slot.pos >= self.max_len - 1
        if eos or full or len(slot.out) >= slot.max_new:
            self._finish(slot)

    def _grow_pages(self, steps: int = 1) -> None:
        """Allocate every page the next ``steps`` decode writes can touch —
        at most ``min(steps, tokens left)`` positions per slot, so a K-block
        never reserves past a slot's own max-new budget.  Admission reserved
        the worst case, so this never exhausts the pool
        (``_reservable_pages`` accounts for the unallocated tail)."""
        t0 = time.perf_counter()
        grew = False
        ps = self.page_size
        for s in self.slots:
            if not s.decoding:
                continue
            e = min(steps, max(s.max_new - len(s.out), 1))
            last = min(s.pos + e - 1, self.max_len - 1)
            for lp in range(s.pos // ps, last // ps + 1):
                if self.page_table[s.index, lp] < 0:
                    page = self.pager.alloc(1)[0]
                    self.page_table[s.index, lp] = page
                    self._pages_dev = self._pages_dev.at[s.index, lp].set(
                        page)
                    grew = True
        if grew:
            self._sync_pages_leaves()
            self._serving_time(("grow_pages",), time.perf_counter() - t0)

    def _finish(self, slot: _Slot) -> None:
        res = GenResult(tokens=slot.out, rid=slot.rid, tier=slot.tier,
                        prefill_s=slot.prefill_s, decode_s=slot.decode_s)
        rec = self.records.pop(slot.rid, None)
        if rec is not None:
            rec.finish_t = self.clock
            rec.n_tokens = len(slot.out)
            rec.status = "ok"
            self.stats.latency.add(rec)
            res.priority = rec.priority
            res.queue_wait_s = rec.queue_wait_s
            res.ttft_s = rec.ttft_s
            res.tpot_s = rec.tpot_s
            res.e2e_s = rec.e2e_s
        if self.tele.enabled and self.tele_requests:
            self.tele.close_request(slot.rid, self.clock, "ok",
                                    tokens=len(slot.out))
        self._finished.append(res)
        self._release_slot(slot)

    def _release_slot(self, slot: _Slot) -> None:
        """Return a slot (and its pages) to the pool — shared by normal
        completion and mid-prefill shedding."""
        slot.active = False
        slot.prefilling = False
        slot.out = []
        slot.rid = -1
        if hasattr(slot, "_prompt"):          # shed mid-prefill
            del slot._prompt
        if self.kv_layout == "paged":
            # eager release: the pages (and the reservation tail) return to
            # the pool in the same step EOS/max-len fired, so a queued
            # request can be admitted at the very next tick
            row = self.page_table[slot.index]
            live = [int(p) for p in row[row >= 0]]
            if live:
                self.pager.free(live)
            self.page_table[slot.index, :] = -1
            slot.reserved_pages = 0
            self._set_pages_rows([slot.index])

    # -- transfer accounting -------------------------------------------------

    def _account_prefill(self, n_tokens: int) -> None:
        """Embedding lookups for the prompt tokens: host plan ships table
        shards, ISP plan ships indexes (the paper's protocol)."""
        c = choose_embedding_plan(n_tokens, self.cfg.vocab_size,
                                  self.cfg.d_model, tp=self.shards)
        chosen = c.isp_link_bytes if c.plan == "isp" else c.host_link_bytes
        self.ledger.add("link", chosen, "prefill")
        self.baseline.add("link", c.host_link_bytes, "prefill")

    def _account_decode(self, batch: int, seq: int) -> None:
        """One decode step: embedding lookup of the step tokens plus the
        per-layer decode attention over the resident KV span."""
        e = choose_embedding_plan(batch, self.cfg.vocab_size,
                                  self.cfg.d_model, tp=self.shards)
        d = choose_decode_plan(batch, self.cfg.num_heads,
                               self.cfg.resolved_head_dim, seq,
                               self.cfg.num_kv_heads, shards=self.shards)
        layers = self.cfg.num_layers
        chosen = (e.isp_link_bytes if e.plan == "isp" else e.host_link_bytes) \
            + layers * (d.isp_link_bytes if d.plan == "isp"
                        else d.host_link_bytes)
        base = e.host_link_bytes + layers * d.host_link_bytes
        self.ledger.add("link", chosen, "decode")
        self.baseline.add("link", base, "decode")
        self._account_kv_step()

    def _account_kv_step(self) -> None:
        """KV rows this decode step walks, chosen layout vs the dense
        baseline (the strip path reads every slot's full strip every step;
        the paged kernel reads only live pages)."""
        per_token = self._kv_bytes_per_token()
        if per_token == 0:
            return
        dense = self.num_slots * self.max_len * per_token
        if self.kv_layout == "paged":
            touched = self.pager.num_in_use * self.page_size * per_token
        else:
            touched = dense
        self.ledger.add("kv", touched, "decode KV rows")
        self.baseline.add("kv", dense, "decode KV rows")


def collect_results(engine, rids: List[int]) -> List[GenResult]:
    """Drain ``engine`` and return ``rids``'s results in submission order,
    re-appending other submitters' finished results for *their* caller —
    the generate() contract shared by ServeEngine and ClusterEngine."""
    mine = set(rids)
    by_rid = {}
    for r in engine.run_until_complete():
        if r.rid in mine:
            by_rid[r.rid] = r
        else:                             # someone else's submit(): keep it
            engine._finished.append(r)
    return [by_rid[r] for r in rids]


def _splice_slots(pool, pre, slot_ids: List[int], lengths: List[int],
                  page_table=None, page_size: int = 0):
    """Scatter a bucket's prefill caches into the per-slot pool.

    Dispatches per layer group: paged groups (kp/vp pools + page table)
    scatter prompt rows into their allocated pages; strip groups keep the
    dense per-slot tree splice.
    """
    out = {}
    for gname, dst in pool.items():
        src = pre[gname]
        if isinstance(dst, dict) and "pages" in dst:
            out[gname] = _splice_paged_group(dst, src, slot_ids, lengths,
                                             page_table, page_size)
        else:
            out[gname] = _splice_strip_group(dst, src, slot_ids, lengths)
    return out


def _splice_paged_group(dst, src, slot_ids: List[int], lengths: List[int],
                        page_table, page_size: int):
    """Scatter prefill rows into the paged pool.

    ``src`` leaves are dense (ng, b, padded, ...); only the first
    ``lengths[i]`` rows of each sequence are real — pad rows are never
    scattered, so the pool only ever holds live tokens (positions past the
    current one are invisible to the kernel until their decode step
    overwrites them).
    """
    src_b, src_pos, dst_page, dst_off = [], [], [], []
    for i, (sid, n) in enumerate(zip(slot_ids, lengths)):
        p = np.arange(n)
        src_b.append(np.full(n, i))
        src_pos.append(p)
        dst_page.append(page_table[sid, p // page_size])
        dst_off.append(p % page_size)
    sb, sp = np.concatenate(src_b), np.concatenate(src_pos)
    pages_np = np.concatenate(dst_page)
    assert (pages_np >= 0).all(), "prefill splice into unallocated page"
    dp = jnp.asarray(pages_np)
    do = jnp.asarray(np.concatenate(dst_off))
    return dict(
        dst,
        kp=dst["kp"].at[:, dp, do].set(src["k"][:, sb, sp].astype(dst["kp"].dtype)),
        vp=dst["vp"].at[:, dp, do].set(src["v"][:, sb, sp].astype(dst["vp"].dtype)),
    )


def _splice_strip_group(pool, pre, slot_ids: List[int], lengths: List[int]):
    """Dense per-slot splice: ``pool`` leaves are (num_groups, num_slots,
    ...); ``pre`` leaves are (num_groups, bpad, ...) for the prefill batch
    (the bucket's ``b`` real sequences first, dummy pad rows after — see
    ``_prefill_bucket``'s fixed batch).  kpos rows become per-slot tracks:
    prefill positions >= the true prompt length (padding) are masked to -1,
    everything past the copied span stays -1.
    """
    b = len(slot_ids)
    slots = jnp.asarray(slot_ids)
    lens = jnp.asarray(lengths)

    def splice(path, dst, src):
        names = [str(p.key) for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        if name == "kpos":
            # src (ng, n) shared track -> per-slot rows (ng, b, n)
            n = min(src.shape[1], dst.shape[2])
            row = jnp.broadcast_to(src[:, None, :n],
                                   (src.shape[0], b, n))
            row = jnp.where((row >= 0) & (row < lens[None, :, None]), row, -1)
            dst = dst.at[:, slots, :].set(-1)
            return dst.at[:, slots, :n].set(row)
        if name in ("k", "v", "ckv", "krope"):
            n = min(src.shape[2], dst.shape[2])
            return dst.at[:, slots, :n].set(src[:, :b, :n].astype(dst.dtype))
        # recurrent / stateful leaves: whole per-sequence rows
        return dst.at[:, slots].set(src[:, :b].astype(dst.dtype))

    return jax.tree_util.tree_map_with_path(splice, pool, pre)
