"""Sharded, async, restart-safe checkpointing.

Layout (one directory per step):
    <dir>/step_000123/
        manifest.json        # pytree structure, shapes, dtypes, file map
        shard_<host>.npz     # this host's param/optimizer shards
    <dir>/step_000123.done   # commit marker (atomic rename)

Properties needed at 1000-node scale, implemented here at CPU scale:
  * every host writes only its own shard file (no coordinator traffic —
    the ISP rule applied to checkpoints);
  * two-phase commit: the .done marker is renamed into place only after
    all shard files are fsync'd, so a crash mid-save never corrupts the
    latest checkpoint;
  * async: `save(...)` snapshots to host RAM (device_get) and writes on a
    background thread, overlapping the next training steps;
  * elastic restore: arrays are re-sharded to whatever mesh the restoring
    job uses (load full array per leaf, then device_put with the new
    sharding) — a job restarted on fewer/more hosts just works.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import threading
import time
from typing import Any, Dict, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize bfloat16 natively; store raw uint16 + dtype tag
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16)}


def _encode(a: np.ndarray):
    name = str(a.dtype)
    if name in _EXOTIC:
        return a.view(_EXOTIC[name][1]), name
    return a, name


def _decode(a: np.ndarray, name: str) -> np.ndarray:
    if name in _EXOTIC:
        return a.view(_EXOTIC[name][0])
    return a


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        flat[key] = leaf
    return flat


def _unflatten_like(template, flat: Dict[str, np.ndarray]):
    leaves = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(template)[0]:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        arr = flat[key]
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(directory, step: int, tree, *, host: str = "host0",
                    extra: Optional[dict] = None) -> pathlib.Path:
    """Synchronous sharded save with two-phase commit."""
    directory = pathlib.Path(directory)
    step_dir = directory / f"step_{step:09d}"
    tmp_dir = directory / f".tmp_step_{step:09d}_{host}"
    tmp_dir.mkdir(parents=True, exist_ok=True)
    step_dir.mkdir(parents=True, exist_ok=True)

    flat = _flatten(tree)
    arrays = {}
    dtypes = {}
    for k, v in flat.items():
        enc, name = _encode(np.asarray(jax.device_get(v)))
        arrays[k] = enc
        dtypes[k] = name
    manifest = {
        "step": step,
        "extra": extra or {},
        "leaves": {k: {"shape": list(a.shape), "dtype": dtypes[k],
                       "file": f"shard_{host}.npz"} for k, a in arrays.items()},
    }
    shard_path = tmp_dir / f"shard_{host}.npz"
    with open(shard_path, "wb") as f:
        np.savez(f, **{k.replace("/", "__"): a for k, a in arrays.items()})
        f.flush()
        os.fsync(f.fileno())
    os.replace(shard_path, step_dir / f"shard_{host}.npz")
    man_path = tmp_dir / "manifest.json"
    man_path.write_text(json.dumps(manifest))
    os.replace(man_path, step_dir / "manifest.json")
    tmp_dir.rmdir()
    done = directory / f"step_{step:09d}.done"
    marker = directory / f".tmp_done_{step:09d}_{host}"
    # persisted wall-clock stamp: the .done marker records WHEN the
    # checkpoint landed for humans/tooling comparing runs across restarts;
    # perf_counter has no epoch and would be meaningless on disk
    marker.write_text(str(time.time()))  # lint: disable=banned-api
    os.replace(marker, done)                       # atomic commit
    return step_dir


def latest_step(directory) -> Optional[int]:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = []
    for p in directory.glob("step_*.done"):
        m = re.match(r"step_(\d+)\.done", p.name)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(directory, template, *, step: Optional[int] = None,
                       shardings=None):
    """Restore into the template's structure; reshard to ``shardings``
    (pytree of NamedSharding) if given — elastic restore onto a new mesh."""
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    step_dir = directory / f"step_{step:09d}"
    manifest = json.loads((step_dir / "manifest.json").read_text())
    leaves_meta = manifest.get("leaves", {})
    flat: Dict[str, np.ndarray] = {}
    for shard_file in sorted(step_dir.glob("shard_*.npz")):
        with np.load(shard_file) as z:
            for k in z.files:
                key = k.replace("__", "/")
                meta = leaves_meta.get(key, {})
                flat[key] = _decode(z[k], meta.get("dtype", str(z[k].dtype)))
    tree = _unflatten_like(template, flat)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, manifest


class CheckpointManager:
    """Async manager: snapshot on-thread, write off-thread, keep last K."""

    def __init__(self, directory, *, keep: int = 3, host: str = "host0"):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self.host = host
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree, extra: Optional[dict] = None) -> None:
        self.wait()                                 # one in flight at a time
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, snapshot,
                                host=self.host, extra=extra)
                self._gc()
            except BaseException as e:      # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self) -> None:
        steps = sorted(
            int(re.match(r"step_(\d+)\.done", p.name).group(1))
            for p in self.directory.glob("step_*.done"))
        for s in steps[: -self.keep]:
            done = self.directory / f"step_{s:09d}.done"
            done.unlink(missing_ok=True)
            sd = self.directory / f"step_{s:09d}"
            if sd.exists():
                for f in sd.iterdir():
                    f.unlink()
                sd.rmdir()

    def restore(self, template, step: Optional[int] = None, shardings=None):
        return restore_checkpoint(self.directory, template, step=step,
                                  shardings=shardings)
