"""Tests for repro.analysis.lint: the framework, each of the five
rules (one positive, one negative, one suppressed fixture case each),
the CLI contract (error -> nonzero exit, --json diagnostics carry
file/line/rule-id), the baseline ratchet, and the self-check that the
shipped tree is clean under the committed LINT_BASELINE.json.

Fixture files are written under tmp_path with the basenames the scoped
rules key on (``cluster_loop.py``, ``telemetry.py``, ``runtime.py``):
the checkers classify by file name + shape, not by import resolution,
so a tiny snippet in a temp dir exercises exactly the production
logic.
"""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis.lint import (all_rules, baseline_payload, check_baseline,
                                 load_baseline, run_lint)
from repro.analysis.lint.cli import main as lint_main

REPO_ROOT = Path(__file__).resolve().parents[1]

pytestmark = pytest.mark.fast


def lint_snippet(tmp_path, name, source, rules=None):
    f = tmp_path / name
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(textwrap.dedent(source))
    return run_lint([str(f)], rules=rules)


def rules_fired(report):
    return {d.rule for d in report.diagnostics}


# -- banned-api -------------------------------------------------------------

def test_banned_api_positive(tmp_path):
    report = lint_snippet(tmp_path, "core/thing.py", """\
        import random, time

        def f():
            t0 = time.time()
            x = random.random()
            try:
                pass
            except:
                pass
            return t0, x
        """)
    msgs = [d for d in report.diagnostics if d.rule == "banned-api"]
    assert len(msgs) == 3
    assert {d.line for d in msgs} == {4, 5, 8}
    assert all(d.severity == "error" for d in msgs)


def test_banned_api_negative(tmp_path):
    report = lint_snippet(tmp_path, "core/thing.py", """\
        import random, time

        def f(seed):
            rng = random.Random(seed)
            t0 = time.perf_counter()
            try:
                pass
            except ValueError:
                pass
            return rng.random(), t0
        """)
    assert "banned-api" not in rules_fired(report)


def test_banned_api_rng_rule_scoped_to_core_train(tmp_path):
    # the unseeded-RNG ban only bites on the replayable core/train paths
    report = lint_snippet(tmp_path, "tools/thing.py", """\
        import random
        x = random.random()
        """)
    assert "banned-api" not in rules_fired(report)


def test_banned_api_suppressed(tmp_path):
    report = lint_snippet(tmp_path, "core/thing.py", """\
        import time
        stamp = time.time()  # wall-clock on purpose; lint: disable=banned-api
        """)
    assert "banned-api" not in rules_fired(report)
    assert [d.rule for d in report.suppressed] == ["banned-api"]
    assert report.suppression_sites == {"banned-api": 1}


# -- lock-order -------------------------------------------------------------

def test_lock_order_positive_direct_and_interprocedural(tmp_path):
    report = lint_snippet(tmp_path, "cluster_loop.py", """\
        import threading

        class ClusterEngine:
            def __init__(self):
                self._lock = threading.Lock()

            def take_cluster(self):
                with self._lock:
                    pass

            def bad_direct(self, d):
                with d.lock:
                    with self._lock:
                        pass

            def bad_via_call(self, d):
                with d.lock:
                    self.take_cluster()
        """)
    msgs = [d for d in report.diagnostics if d.rule == "lock-order"]
    # the nested `with self._lock` (cluster under drive) and the call
    # into take_cluster (may acquire cluster) under the drive lock
    assert {d.line for d in msgs} == {13, 18}


def test_lock_order_negative_cluster_then_drive_and_rlock_reentry(tmp_path):
    report = lint_snippet(tmp_path, "cluster_loop.py", """\
        import threading

        class ClusterEngine:
            def __init__(self):
                self._lock = threading.RLock()

            def fail(self):
                with self._lock:
                    pass

            def step(self, d):
                with self._lock:
                    with d.lock:
                        pass
                    self.fail()
        """)
    assert "lock-order" not in rules_fired(report)


def test_lock_order_plain_lock_reentry_is_flagged(tmp_path):
    # same shape as the RLock case, but re-entering a plain Lock
    # self-deadlocks — the re-entrance exemption must not apply
    report = lint_snippet(tmp_path, "router.py", """\
        import threading

        class Router:
            def __init__(self):
                self._lock = threading.Lock()

            def home(self):
                with self._lock:
                    return 1

            def pick(self):
                with self._lock:
                    return self.home()
        """)
    msgs = [d for d in report.diagnostics if d.rule == "lock-order"]
    assert [d.line for d in msgs] == [13]


def test_lock_order_hub_no_callbacks_out(tmp_path):
    report = lint_snippet(tmp_path, "telemetry.py", """\
        import threading

        class Hub:
            def __init__(self):
                self._lock = threading.Lock()

            def emit(self, on_event):
                with self._lock:
                    on_event()
        """)
    msgs = [d for d in report.diagnostics if d.rule == "lock-order"]
    assert [d.line for d in msgs] == [9]
    assert "hub" in msgs[0].message


def test_lock_order_suppressed(tmp_path):
    report = lint_snippet(tmp_path, "cluster_loop.py", """\
        import threading

        class ClusterEngine:
            def bad(self, d):
                with d.lock:
                    with self._lock:  # lint: disable=lock-order
                        pass
        """)
    assert "lock-order" not in rules_fired(report)
    assert [d.rule for d in report.suppressed] == ["lock-order"]


# -- fault-purity -----------------------------------------------------------

def test_fault_purity_positive(tmp_path):
    report = lint_snippet(tmp_path, "runtime.py", """\
        class DriveWorker:
            def run(self, tick):
                if self.faults.begins(tick, self.drive_id):
                    return True
                self.faults.save("schedule.json")
        """)
    msgs = [d for d in report.diagnostics if d.rule == "fault-purity"]
    assert {d.line for d in msgs} == {3, 5}


def test_fault_purity_negative(tmp_path):
    report = lint_snippet(tmp_path, "runtime.py", """\
        class DriveWorker:
            def run(self, tick):
                if self.faults.crash_active(tick, self.drive_id):
                    return True
                return self.faults.hangs(tick, self.drive_id)
        """)
    assert "fault-purity" not in rules_fired(report)


def test_fault_purity_only_scoped_to_runtime(tmp_path):
    # the coordinator (cluster_loop.py) legitimately consumes begins()
    report = lint_snippet(tmp_path, "cluster_loop.py", """\
        class ClusterEngine:
            def step(self, tick):
                return self.faults.begins(tick, 0)
        """)
    assert "fault-purity" not in rules_fired(report)


def test_fault_purity_suppressed(tmp_path):
    report = lint_snippet(tmp_path, "runtime.py", """\
        class DriveWorker:
            def run(self, tick):
                return self.faults.begins(tick, 0)  # lint: disable=fault-purity
        """)
    assert "fault-purity" not in rules_fired(report)
    assert [d.rule for d in report.suppressed] == ["fault-purity"]


# -- telemetry-guard --------------------------------------------------------

def test_telemetry_guard_positive(tmp_path):
    report = lint_snippet(tmp_path, "runtime.py", """\
        class DriveWorker:
            def run(self):
                self.tele.counter("worker.ticks")
        """)
    msgs = [d for d in report.diagnostics if d.rule == "telemetry-guard"]
    assert [d.line for d in msgs] == [3]
    assert "enabled" in msgs[0].message


def test_telemetry_guard_negative_guard_forms(tmp_path):
    report = lint_snippet(tmp_path, "serve_loop.py", """\
        class ServeEngine:
            def wrapped(self):
                if self.tele.enabled:
                    self.tele.counter("a")

            def early_return(self):
                t = self.tele
                if not t.enabled:
                    return
                t.counter("b")
                t.gauge("c", 1.0)

            def compound_test(self):
                if self.tele.enabled and self.tele_requests:
                    self.tele.open_request("r0")
        """)
    assert "telemetry-guard" not in rules_fired(report)


def test_telemetry_guard_else_branch_not_dominated(tmp_path):
    # the else branch of an enabled check is exactly the disabled path —
    # an emission there must still be flagged
    report = lint_snippet(tmp_path, "runtime.py", """\
        class DriveWorker:
            def run(self):
                if self.tele.enabled:
                    pass
                else:
                    self.tele.counter("oops")
        """)
    msgs = [d for d in report.diagnostics if d.rule == "telemetry-guard"]
    assert [d.line for d in msgs] == [6]


def test_telemetry_guard_suppressed(tmp_path):
    report = lint_snippet(tmp_path, "runtime.py", """\
        class DriveWorker:
            def run(self):
                self.tele.counter("t")  # lint: disable=telemetry-guard
        """)
    assert "telemetry-guard" not in rules_fired(report)
    assert [d.rule for d in report.suppressed] == ["telemetry-guard"]


# -- jit-purity -------------------------------------------------------------

def test_jit_purity_positive(tmp_path):
    report = lint_snippet(tmp_path, "engine.py", """\
        import time
        import jax

        def step(x):
            t0 = time.perf_counter()
            print(x)
            return x * t0

        fn = jax.jit(step)
        body = jax.lax.while_loop(lambda s: s < 3,
                                  lambda s: s + int(time.time()), 0)
        """)
    msgs = [d for d in report.diagnostics if d.rule == "jit-purity"]
    assert {d.line for d in msgs} == {5, 6, 11}


def test_jit_purity_negative(tmp_path):
    report = lint_snippet(tmp_path, "engine.py", """\
        import functools
        import jax
        import jax.numpy as jnp

        def _kernel(q_ref, o_ref, *, scale):
            o_ref[...] = q_ref[...] * scale

        def build(scale):
            kernel = functools.partial(_kernel, scale=scale)
            return pl.pallas_call(kernel, out_shape=None)

        def step(x):
            key = jax.random.PRNGKey(0)     # jax.random is traced, fine
            return x + jax.random.normal(key)

        fn = jax.jit(step)
        """)
    assert "jit-purity" not in rules_fired(report)


def test_jit_purity_partial_unwrapped_and_telemetry(tmp_path):
    # functools.partial around the kernel must not hide its effects,
    # and hub-ish receivers count as host effects
    report = lint_snippet(tmp_path, "engine.py", """\
        import functools

        def _kernel(q_ref, o_ref, *, hub):
            hub.counter("inner")
            o_ref[...] = q_ref[...]

        def build(hub):
            kernel = functools.partial(_kernel, hub=hub)
            return pl.pallas_call(kernel, out_shape=None)
        """)
    msgs = [d for d in report.diagnostics if d.rule == "jit-purity"]
    assert [d.line for d in msgs] == [4]
    assert "telemetry" in msgs[0].message


def test_jit_purity_suppressed(tmp_path):
    report = lint_snippet(tmp_path, "engine.py", """\
        import time
        import jax

        def step(x):
            return x * time.perf_counter()  # trace-time const; lint: disable=jit-purity

        fn = jax.jit(step)
        """)
    assert "jit-purity" not in rules_fired(report)
    assert [d.rule for d in report.suppressed] == ["jit-purity"]


# -- framework --------------------------------------------------------------

def test_suppression_sites_counted_without_a_firing(tmp_path):
    # a disable comment is counted even when no diagnostic fires on the
    # line — the baseline pins comment sites, not fired-and-silenced hits
    report = lint_snippet(tmp_path, "clean.py", """\
        x = 1  # lint: disable=banned-api
        """)
    assert report.diagnostics == []
    assert report.suppression_sites == {"banned-api": 1}


def test_unknown_rule_filter_raises(tmp_path):
    with pytest.raises(ValueError, match="unknown rule ids"):
        lint_snippet(tmp_path, "x.py", "x = 1\n", rules=["no-such-rule"])


def test_parse_error_is_a_diagnostic(tmp_path):
    report = lint_snippet(tmp_path, "bad.py", "def broken(:\n")
    assert [d.rule for d in report.diagnostics] == ["parse-error"]
    assert report.diagnostics[0].severity == "error"


def test_registry_has_the_five_rules():
    assert set(all_rules()) == {"banned-api", "fault-purity", "jit-purity",
                                "lock-order", "telemetry-guard"}


# -- CLI --------------------------------------------------------------------

def test_cli_json_exit_and_diagnostic_shape(tmp_path, capsys):
    bad = tmp_path / "core" / "bad.py"
    bad.parent.mkdir()
    bad.write_text("import time\nt = time.time()\n")
    rc = lint_main([str(bad), "--json"])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert payload["ok"] is False and payload["errors"] == 1
    (diag,) = payload["diagnostics"]
    assert diag["path"].endswith("bad.py")
    assert diag["line"] == 2
    assert diag["rule"] == "banned-api"
    assert diag["severity"] == "error"


def test_cli_clean_tree_exits_zero(tmp_path, capsys):
    good = tmp_path / "good.py"
    good.write_text("import time\nt = time.perf_counter()\n")
    assert lint_main([str(good), "--json"]) == 0
    assert json.loads(capsys.readouterr().out)["ok"] is True


def test_cli_baseline_ratchet(tmp_path, capsys):
    f = tmp_path / "core" / "x.py"
    f.parent.mkdir()
    f.write_text("import time\nt = time.time()  # lint: disable=banned-api\n")
    baseline = tmp_path / "BASE.json"
    # no baseline entry for the suppression -> ratchet fails
    empty = lint_main([str(tmp_path / "nothing"), "--write-baseline",
                       str(baseline)])
    assert empty == 0
    capsys.readouterr()                    # drop the human-format output
    rc = lint_main([str(f), "--json", "--baseline", str(baseline)])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert payload["errors"] == 0          # nothing fired...
    assert payload["baseline_ok"] is False  # ...but the ratchet trips
    # ratcheting the baseline to the current counts makes it pass
    assert lint_main([str(f), "--write-baseline", str(baseline)]) == 0
    assert lint_main([str(f), "--baseline", str(baseline)]) == 0


# -- shipped tree -----------------------------------------------------------

def test_shipped_tree_is_clean_under_committed_baseline():
    paths = [str(REPO_ROOT / p) for p in
             ("src/repro", "benchmarks", "examples")]
    report = run_lint(paths)
    assert report.errors == [], "\n".join(
        d.format() for d in report.errors)
    baseline = load_baseline(str(REPO_ROOT / "LINT_BASELINE.json"))
    assert check_baseline(report, baseline) == []
    # and the committed baseline is exactly what --write-baseline would
    # produce today (no stale counts)
    assert baseline == baseline_payload(report)


def test_committed_baseline_structure_via_bench_gate():
    from benchmarks._gate import check_lint_baseline
    check_lint_baseline(REPO_ROOT / "LINT_BASELINE.json", emit=lambda *a: None)
    with pytest.raises(RuntimeError, match="unknown rule id"):
        import tempfile
        with tempfile.NamedTemporaryFile("w", suffix=".json",
                                         delete=False) as f:
            json.dump({"version": 1,
                       "rules": {"no-such-rule": {"suppressions": 0}}}, f)
        check_lint_baseline(f.name, emit=lambda *a: None)
