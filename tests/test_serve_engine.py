"""Continuous-batching serve engine: variable-length prompts, mid-stream
slot eviction + refill, EOS handling, determinism vs uniform-position
decode, and transfer-ledger accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import reduced_config
from repro.models import model as M
from repro.train.serve_loop import AdmissionController, ServeEngine

MAX_LEN = 64


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(reduced_config("yi-9b"), dtype="float32")


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


def manual_decode(cfg, params, prompt, max_new):
    """Oracle: single-sequence uniform-position decode (the legacy path)."""
    toks = jnp.asarray(np.array([prompt], np.int32))
    caches = M.init_caches(cfg, 1, MAX_LEN)
    for t in range(len(prompt)):
        nxt, caches = M.decode_fn(params, caches, toks[:, t:t + 1],
                                  jnp.int32(t), cfg)
    out = [int(nxt[0])]
    cur = nxt[:, None].astype(jnp.int32)
    pos = len(prompt)
    while len(out) < max_new and pos < MAX_LEN - 1:
        nxt, caches = M.decode_fn(params, caches, cur, jnp.int32(pos), cfg)
        cur = nxt[:, None].astype(jnp.int32)
        out.append(int(nxt[0]))
        pos += 1
    return out


def make_engine(cfg, params, num_slots=4, **kw):
    kw.setdefault("admission",
                  AdmissionController(num_slots, host_rate=3.0, csd_rate=1.0))
    return ServeEngine(cfg, params, max_len=MAX_LEN, num_slots=num_slots, **kw)


def test_variable_length_prompts_match_oracle(cfg, params, rng):
    """Mixed lengths in one call: every request equals its solo decode."""
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist()
               for n in (5, 12, 9, 15)]
    engine = make_engine(cfg, params)
    results = engine.generate(prompts, max_new=4)
    assert [r.rid for r in results] == [0, 1, 2, 3]
    for p, r in zip(prompts, results):
        assert r.tokens == manual_decode(cfg, params, p, 4), r.rid


def test_eviction_refill_mid_decode(cfg, params, rng):
    """More requests than slots + uneven max_new: slots must be evicted and
    refilled mid-decode without leaking the previous occupant's cache."""
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist()
               for n in (6, 11, 7, 13, 9)]
    max_news = [2, 6, 3, 5, 4]
    engine = make_engine(cfg, params, num_slots=2)
    rids = [engine.submit(p, max_new=m) for p, m in zip(prompts, max_news)]
    results = {r.rid: r for r in engine.run_until_complete()}
    assert sorted(results) == rids
    assert engine.num_active == 0 and engine.pending == 0
    for rid, p, m in zip(rids, prompts, max_news):
        assert results[rid].tokens == manual_decode(cfg, params, p, m), rid


def test_eos_evicts_early(cfg, params, rng):
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist() for n in (8, 10)]
    reference = make_engine(cfg, params).generate(prompts, max_new=6)
    eos = reference[0].tokens[2]          # third generated token of req 0
    engine = make_engine(cfg, params, eos_id=eos)
    results = engine.generate(prompts, max_new=6)
    for ref, got in zip(reference, results):
        want = ref.tokens[: ref.tokens.index(eos) + 1] if eos in ref.tokens \
            else ref.tokens
        assert got.tokens == want
    assert len(results[0].tokens) == 3
    assert results[0].tokens[-1] == eos


def test_equal_length_batch_matches_uniform_decode(cfg, params, rng):
    """Greedy decode through the slot pool must equal the legacy
    equal-length batched path (uniform positions, shared kpos)."""
    b, plen, new = 3, 12, 5
    prompts = rng.integers(0, cfg.vocab_size, (b, plen)).tolist()
    results = make_engine(cfg, params).generate(prompts, max_new=new)

    toks = jnp.asarray(np.array(prompts, np.int32))
    caches = M.init_caches(cfg, b, MAX_LEN)
    for t in range(plen):
        nxt, caches = M.decode_fn(params, caches, toks[:, t:t + 1],
                                  jnp.int32(t), cfg)
    manual = [[int(nxt[i])] for i in range(b)]
    cur = nxt[:, None].astype(jnp.int32)
    for j in range(new - 1):
        nxt, caches = M.decode_fn(params, caches, cur, jnp.int32(plen + j), cfg)
        cur = nxt[:, None].astype(jnp.int32)
        for i in range(b):
            manual[i].append(int(nxt[i]))
    for i in range(b):
        assert results[i].tokens == manual[i], i


def test_ledger_link_byte_accounting(cfg, params, rng):
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist() for n in (6, 9, 14)]
    engine = make_engine(cfg, params)
    for p in prompts:
        engine.submit(p, max_new=4)
    engine.step()                         # admission + prefill + first decode
    mid = engine.stats.link_bytes
    assert mid > 0
    engine.run_until_complete()
    st = engine.stats
    assert st.link_bytes >= mid                       # monotone counters
    assert st.link_bytes <= st.host_link_bytes        # chosen plan never worse
    assert st.bytes_never_crossed == pytest.approx(
        st.host_link_bytes - st.link_bytes)
    assert 0.0 <= st.link_reduction <= 1.0
    assert st.tokens == sum(st.tier_tokens.values()) == 12
    assert st.requests == sum(st.tier_requests.values()) == 3


def test_admission_uses_scheduler_tiers(cfg, params, rng):
    """With a 1:1 host:CSD rate the pull order must interleave both tiers."""
    prompts = [rng.integers(0, cfg.vocab_size, 8).tolist() for _ in range(6)]
    engine = make_engine(
        cfg, params, num_slots=2,
        admission=AdmissionController(2, host_rate=1.0, csd_rate=1.0,
                                      batch_size=1))
    results = engine.generate(prompts, max_new=2)
    tiers = {r.tier for r in results}
    assert tiers == {"host", "csd"}


def test_last_tick_observation(cfg, params, rng):
    """Every step() must describe itself for the cluster pull scheduler:
    which requests were admitted, tokens/steps produced, and the
    serving-vs-lazy-compile wall split (first-shape calls are compile)."""
    from repro.train.serve_loop import TickObservation
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist() for n in (6, 9)]
    engine = make_engine(cfg, params, num_slots=2)
    assert isinstance(engine.last_tick, TickObservation)
    rids = [engine.submit(p, max_new=4) for p in prompts]
    engine.step()
    obs = engine.last_tick
    assert obs.admitted_rids == rids
    assert obs.tokens > 0 and obs.steps > 0
    assert obs.per_step_items and sum(obs.per_step_items) > 0
    # a fresh engine's first tick is dominated by lazy XLA compiles, which
    # must land in compile_s (and stats.compile_s), not the serving time
    assert obs.compile_s > 0
    assert engine.stats.compile_s >= obs.compile_s
    assert obs.busy_s < obs.compile_s
    engine.run_until_complete()
    # a warm replay of the same shapes is pure serving: no compile charges
    rids2 = [engine.submit(p, max_new=4) for p in prompts]
    engine.step()
    warm_obs = engine.last_tick
    assert warm_obs.admitted_rids == rids2
    assert warm_obs.compile_s == 0.0
    assert warm_obs.busy_s > 0
    assert warm_obs.tokens > 0
    engine.run_until_complete()
    assert engine.stats.prefill_s + engine.stats.decode_s > 0


def test_generate_keeps_earlier_submissions(cfg, params, rng):
    """generate() drains the queue but must not discard results of requests
    queued earlier via submit()."""
    engine = make_engine(cfg, params, num_slots=2)
    p0 = rng.integers(0, cfg.vocab_size, 7).tolist()
    rid0 = engine.submit(p0, max_new=3)
    p1 = rng.integers(0, cfg.vocab_size, 9).tolist()
    results = engine.generate([p1], max_new=2)
    assert len(results) == 1 and results[0].rid != rid0
    leftover = engine.run_until_complete()
    assert [r.rid for r in leftover] == [rid0]
    assert leftover[0].tokens == manual_decode(cfg, params, p0, 3)


@pytest.mark.fast
def test_admission_rebalance_gated_on_observed_difference():
    """Identical per-tier service times must not disturb the configured
    batch ratio; a real difference must refit it from measured throughput."""
    ctl = AdmissionController(8, host_rate=100.0, csd_rate=1.0,
                              rebalance_every=4)
    ratio0 = ctl.sched.batch_ratio
    for _ in range(8):
        ctl.observe("host", 0.10, 10)
        ctl.observe("csd", 0.01, 1)      # same 10 ms/token on both tiers
    assert ctl.sched.batch_ratio == ratio0

    ctl = AdmissionController(8, host_rate=100.0, csd_rate=1.0,
                              rebalance_every=4)
    for _ in range(8):
        ctl.observe("host", 0.10, 50)    # 2 ms/token
        ctl.observe("csd", 0.10, 1)      # 100 ms/token
    assert ctl.sched.batch_ratio == pytest.approx(50.0)
    assert ctl.shares["host"] > ctl.shares["csd"]


def test_splice_resets_previous_occupant(cfg, params, rng):
    """Refilling a slot must leave no valid kpos entries from the old
    request beyond the new prompt (strip layout; the paged analogue —
    page-table reset + free-list balance — lives in test_paged_decode)."""
    engine = make_engine(cfg, params, num_slots=2, kv_layout="strip")
    long_p = rng.integers(0, cfg.vocab_size, 20).tolist()
    engine.generate([long_p], max_new=4)          # slot 0 reaches pos 24
    short_p = rng.integers(0, cfg.vocab_size, 5).tolist()
    engine.generate([short_p], max_new=1)         # refills slot 0
    kpos = np.asarray(engine.caches["b0"]["kpos"])  # (ng, slots, S)
    assert kpos.shape[1] == 2
    valid = kpos[:, 0] >= 0
    # exactly prompt + 1 decode-written positions may be valid
    assert valid.sum(axis=-1).max() <= len(short_p) + 1
    assert (kpos[:, 0][valid] < len(short_p) + 1).all()
