"""SLO-aware serving: per-request latency records on the serving clock,
EDF admission, deadline shedding, chunk budgets, and the degenerate-stats
conventions (NaN / 0.0, never raise) the benches gate on."""
import dataclasses
import math

import jax
import pytest

from repro.config import reduced_config
from repro.core.cluster import ClusterStats
from repro.core.latency import LatencyRecord, LatencyStats, percentile
from repro.core.scheduler import ClusterAdmission
from repro.models import model as M
from repro.train.cluster_loop import ClusterEngine
from repro.train.serve_loop import AdmissionController, ServeEngine

MAX_LEN = 64
NAN = float("nan")


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(reduced_config("yi-9b"), dtype="float32")


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def ref(cfg, params):
    """Compile donor: every engine in this module shares its jitted
    callables (and warm-key set), so the file costs one XLA compile."""
    return ServeEngine(cfg, params, max_len=MAX_LEN, num_slots=2,
                       chunk_prefill=8)


def make_engine(ref, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("chunk_prefill", 8)
    return ServeEngine(ref.cfg, ref.params, max_len=MAX_LEN, jit_donor=ref,
                       **kw)


def prompts_for(cfg, rng, lengths):
    return [rng.integers(0, cfg.vocab_size, n).tolist() for n in lengths]


def assert_record_ordered(rec):
    assert rec.submit_t <= rec.admit_t <= rec.first_token_t <= rec.finish_t, \
        rec
    assert rec.queue_wait_s >= 0.0 and rec.ttft_s >= 0.0 and rec.e2e_s >= 0.0


# -- pure latency math (no model) -------------------------------------------

@pytest.mark.fast
def test_percentile_conventions():
    assert math.isnan(percentile([], 99))
    assert math.isnan(percentile([NAN, NAN], 50))
    assert percentile([3.0], 99) == 3.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 50) == pytest.approx(2.5)
    assert percentile([1.0, NAN, 3.0], 100) == 3.0


@pytest.mark.fast
def test_latency_record_derived_metrics():
    r = LatencyRecord(rid=0, deadline_s=1.0, submit_t=0.5, admit_t=0.7,
                      first_token_t=0.9, finish_t=1.5, n_tokens=4,
                      status="ok")
    assert r.queue_wait_s == pytest.approx(0.2)
    assert r.ttft_s == pytest.approx(0.4)           # measured from SUBMIT
    assert r.e2e_s == pytest.approx(1.0)
    assert r.tpot_s == pytest.approx(0.6 / 3)
    assert r.met_deadline
    assert not dataclasses.replace(r, first_token_t=1.2).met_deadline
    # 0/1-token requests have no inter-token interval
    assert math.isnan(dataclasses.replace(r, n_tokens=1).tpot_s)
    # restart: service re-stamps, but the user has waited since submit
    r.restart()
    assert r.submit_t == 0.5 and math.isnan(r.admit_t)
    assert math.isnan(r.first_token_t) and r.n_tokens == 0


@pytest.mark.fast
def test_latency_stats_empty_is_nan_not_raise():
    s = LatencyStats()
    assert s.count == 0 and s.shed == 0
    for v in (s.p50_ttft_s, s.p99_ttft_s, s.p99_e2e_s, s.mean_tpot_s,
              s.mean_queue_wait_s, s.slo_attainment):
        assert math.isnan(v)
    assert s.goodput_qps(10.0) == 0.0       # valid wall, nothing met: 0 qps
    # zero / negative / NaN wall clock: rate is NaN, never a ZeroDivision
    s.add(LatencyRecord(rid=0, submit_t=0.0, admit_t=0.0, first_token_t=0.1,
                        finish_t=0.2, n_tokens=2, status="ok"))
    for wall in (0.0, -1.0, NAN):
        assert math.isnan(s.goodput_qps(wall))
    assert s.goodput_qps(2.0) == pytest.approx(0.5)
    assert isinstance(s.summary(), str)


@pytest.mark.fast
def test_latency_stats_per_class_percentiles():
    s = LatencyStats()
    for i in range(4):            # interactive: TTFT 0.1, batch: TTFT 10.0
        prio = i % 2
        s.add(LatencyRecord(rid=i, priority=prio, submit_t=0.0, admit_t=0.0,
                            first_token_t=0.1 if prio == 0 else 10.0,
                            finish_t=11.0, n_tokens=2, status="ok"))
    assert s.ttft_p(99, priority=0) == pytest.approx(0.1)
    assert s.ttft_p(99, priority=1) == pytest.approx(10.0)
    assert s.ttft_p(50) == pytest.approx(5.05)      # aggregate mixes classes
    assert math.isnan(s.ttft_p(99, priority=7))     # empty class: NaN


@pytest.mark.fast
def test_latency_stats_shed_counts_against_attainment():
    s = LatencyStats()
    s.add(LatencyRecord(rid=0, deadline_s=1.0, submit_t=0.0, admit_t=0.1,
                        first_token_t=0.5, finish_t=1.0, n_tokens=2,
                        status="ok"))
    s.add(LatencyRecord(rid=1, deadline_s=0.2, submit_t=0.0, finish_t=0.5,
                        status="shed"))
    assert s.count == 1 and s.shed == 1 and s.slo_met == 1
    assert s.slo_attainment == pytest.approx(0.5)


@pytest.mark.fast
def test_admission_controller_drops_bad_busy_samples():
    """Negative / non-finite busy windows must not poison the refit
    (the negative-dt regression the perf_counter sweep closes)."""
    ac = AdmissionController(4, host_rate=3.0, csd_rate=1.0)
    before = (dict(ac._busy), dict(ac._tok), dict(ac.shares))
    for bad in (-1.0, NAN, math.inf, -math.inf):
        ac.observe("host", bad, 5)
    assert (ac._busy, ac._tok, ac.shares) == before
    ac.observe("host", 0.5, 5)
    assert ac._busy["host"] == pytest.approx(0.5) and ac._tok["host"] == 5


@pytest.mark.fast
def test_cluster_admission_drops_bad_ticks():
    ca = ClusterAdmission(2)
    for bad in (-1.0, 0.0, NAN, math.inf):
        ca.observe(0, bad, [4])
    assert math.isnan(ca.rate(0))
    ca.observe(0, 0.4, [4])
    assert ca.rate(0) == pytest.approx(10.0)


@pytest.mark.fast
def test_cluster_stats_degenerate_zero_conventions():
    s = ClusterStats()
    # no completions / no wall clock: 0.0 by convention, never a raise
    assert s.energy_per_query_mj == 0.0
    assert s.mean_power_w == 0.0
    s.shed_wasted_s = 1.0
    assert s.shed_energy_mj == 0.0          # zero wall => zero mean power
    s.record_tick(n_active=2, tick_s=0.5)
    assert s.mean_power_w > 0.0 and s.shed_energy_mj > 0.0
    with pytest.raises(ValueError):
        s.record_tick(n_active=1, tick_s=-0.1)


# -- single-engine serving clock + SLO path ----------------------------------

def test_single_engine_timestamp_ordering(cfg, params, ref, rng):
    eng = make_engine(ref, admission_order="edf")
    prompts = prompts_for(cfg, rng, (5, 12, 24, 9, 17))
    for i, p in enumerate(prompts):
        eng.submit(p, max_new=4, priority=i % 2, deadline_s=1e9)
    results = eng.run_until_complete()
    assert len(results) == len(prompts)
    assert eng.clock > 0.0
    for r in results:
        assert r.status == "ok"
        assert r.queue_wait_s >= 0.0 and r.ttft_s >= r.queue_wait_s
        assert r.e2e_s >= r.ttft_s and math.isfinite(r.e2e_s)
    for rec in eng.stats.latency.completed:
        assert_record_ordered(rec)
        assert rec.n_tokens == 4
    assert not eng.records                  # every record closed out


def test_edf_matches_fifo_tokens(cfg, params, ref, rng):
    """Admission order changes WHEN a request runs, never WHAT it decodes."""
    prompts = prompts_for(cfg, rng, (7, 14, 10, 21))
    deadlines = [8.0, 0.5, 4.0, 0.1]        # EDF admits in reverse-ish order
    outs = {}
    for order in ("fifo", "edf"):
        eng = make_engine(ref, admission_order=order, shed_expired=False)
        for p, d in zip(prompts, deadlines):
            eng.submit(p, max_new=5, deadline_s=d)
        outs[order] = {r.rid: r.tokens for r in eng.run_until_complete()}
    assert outs["edf"] == outs["fifo"]


def test_edf_prefers_earliest_deadline(cfg, params, ref, rng):
    """With one free slot, the tightest-deadline request is admitted first
    even though it was submitted last; FIFO breaks ties within a class."""
    eng = make_engine(ref, num_slots=1, admission_order="edf",
                      shed_expired=False)
    prompts = prompts_for(cfg, rng, (6, 6, 6))
    rids = [eng.submit(p, max_new=2, deadline_s=d)
            for p, d in zip(prompts, (50.0, 50.0, 1.0))]
    eng.step()
    assert eng.last_tick.admitted_rids == [rids[2]]
    eng.step()
    assert eng.last_tick.admitted_rids == [rids[0]]      # FIFO within ties


def test_chunked_prefill_first_token_after_last_chunk(cfg, params, ref, rng):
    """A chunked prompt's first token may only appear once ALL its chunks
    are spliced — and the TTFT stamp must cover that whole span."""
    eng = make_engine(ref, chunk_prefill=8, chunk_budget=1)
    plen = 24                                # 3 chunks of 8
    rid = eng.submit(prompts_for(cfg, rng, (plen,))[0], max_new=3)
    ticks = 0
    while rid not in eng.last_tick.first_token_rids:
        assert ticks < 50, "first token never arrived"
        eng.step()
        ticks += 1
    assert ticks >= math.ceil(plen / 8)
    results = eng.run_until_complete()
    # max_new <= k_block: the request finished in the first-token tick
    rec = next(r for r in eng.stats.latency.completed if r.rid == rid)
    assert rec.first_token_t >= rec.admit_t
    assert results[0].ttft_s >= results[0].queue_wait_s


def test_chunk_budget_admits_long_prompts_faster(cfg, params, ref, rng):
    """chunk_budget=N runs up to N prefill chunks per tick: the same long
    prompt reaches its first token in fewer ticks than budget 1."""
    prompt = prompts_for(cfg, rng, (24,))[0]
    ticks = {}
    for budget in (1, 4):
        eng = make_engine(ref, chunk_prefill=8, chunk_budget=budget)
        rid = eng.submit(prompt, max_new=2)
        n = 0
        while rid not in eng.last_tick.first_token_rids and n < 50:
            eng.step()
            n += 1
        ticks[budget] = n
        eng.run_until_complete()
    assert ticks[4] < ticks[1], ticks


def test_expired_queued_requests_are_shed(cfg, params, ref, rng):
    eng = make_engine(ref, admission_order="edf", shed_expired=True)
    doomed = [eng.submit(p, max_new=2, deadline_s=-1.0)
              for p in prompts_for(cfg, rng, (5, 6))]
    alive = eng.submit(prompts_for(cfg, rng, (7,))[0], max_new=2,
                       deadline_s=1e9)
    results = eng.run_until_complete()
    by_rid = {r.rid: r for r in results}
    # conservation: completed + shed == submitted, nothing lost
    assert set(by_rid) == set(doomed) | {alive}
    assert eng.stats.shed_requests == 2
    for rid in doomed:
        r = by_rid[rid]
        assert r.status == "shed" and r.tokens == []
        assert math.isfinite(r.e2e_s) and r.e2e_s >= 0.0
    assert by_rid[alive].status == "ok" and len(by_rid[alive].tokens) == 2
    assert eng.stats.latency.shed == 2 and eng.stats.latency.count == 1


def test_mid_prefill_shed_books_wasted_serving_time(cfg, params, ref, rng):
    eng = make_engine(ref, chunk_prefill=8, shed_expired=True)
    # warm the chunk path so the doomed request's chunk time counts as
    # serving (a cold first call is attributed to compile_s, not waste)
    eng.generate(prompts_for(cfg, rng, (20,)), max_new=2)
    # deadline just past the current clock: it survives admission, runs
    # its first chunk (clock advances), and expires mid-prefill
    rid = eng.submit(prompts_for(cfg, rng, (24,))[0], max_new=2,
                     deadline_s=eng.clock + 1e-12)
    results = eng.run_until_complete()
    shed = [r for r in results if r.rid == rid]
    assert len(shed) == 1 and shed[0].status == "shed"
    assert eng.stats.shed_requests == 1
    assert eng.stats.shed_wasted_s > 0.0
    assert shed[0].prefill_s > 0.0
    assert eng.num_active == 0              # the slot was released


def test_oversized_reservation_rejected_at_submit(cfg, params, ref, rng):
    """A request whose worst case can NEVER fit the page pool must be
    rejected at submit — queued forever / mid-flight failure are bugs."""
    eng = make_engine(ref, num_pages=2, page_size=16)
    prompt = prompts_for(cfg, rng, (20,))[0]
    with pytest.raises(ValueError, match="KV"):
        eng.submit(prompt, max_new=44)      # needs 4 pages, pool has 2
    with pytest.raises(ValueError):
        eng.submit([], max_new=4)           # empty prompt
    with pytest.raises(ValueError):
        eng.submit(list(range(MAX_LEN)), max_new=4)     # >= max_len
    assert eng.pending == 0 and not eng.records
    # small-enough requests still pass
    eng.submit(prompt, max_new=4)
    assert eng.pending == 1


# -- cluster serving clock + SLO path ----------------------------------------

def test_cluster_timestamp_ordering_and_conservation(cfg, params, ref, rng):
    clu = ClusterEngine(cfg, params, n_drives=2, jit_donor=ref,
                        admission_order="edf", max_len=MAX_LEN, num_slots=2,
                        chunk_prefill=8)
    prompts = prompts_for(cfg, rng, (5, 12, 24, 9, 17, 7))
    doomed = clu.submit(prompts[0], max_new=2, deadline_s=-1.0)
    alive = [clu.submit(p, max_new=3, priority=i % 2, deadline_s=1e9)
             for i, p in enumerate(prompts[1:])]
    results = clu.run_until_complete()
    assert {r.rid for r in results} == set(alive) | {doomed}
    assert clu.stats.shed_requests == 1
    assert clu.stats.latency.count == len(alive)
    assert clu.clock > 0.0
    for rec in clu.stats.latency.completed:
        assert_record_ordered(rec)
    for r in results:
        if r.status == "ok":
            assert r.ttft_s >= r.queue_wait_s >= 0.0
            assert math.isfinite(r.e2e_s)
    assert not clu.records and not clu._inflight


def test_cluster_oversized_request_rejected_at_enqueue(cfg, params, ref):
    clu = ClusterEngine(cfg, params, n_drives=1, jit_donor=ref,
                        max_len=MAX_LEN, num_slots=2, num_pages=2,
                        page_size=16)
    with pytest.raises(ValueError, match="KV"):
        clu.submit(list(range(20)), max_new=44)
    assert clu.pending == 0 and not clu.records and not clu._inflight


def test_cluster_fail_restart_keeps_original_submit(cfg, params, ref, rng):
    """A fail()-restarted request re-stamps admit/first-token on the
    surviving drive, but queue wait keeps the ORIGINAL submit time."""
    clu = ClusterEngine(cfg, params, n_drives=2, jit_donor=ref,
                        max_len=MAX_LEN, num_slots=2)
    prompts = prompts_for(cfg, rng, (6, 8, 10, 7, 9, 11))
    # max_new > k_block so requests span multiple ticks and are still
    # mid-flight when the drive dies
    rids = [clu.submit(p, max_new=20, deadline_s=1e9) for p in prompts]
    submit_t = {rid: clu.records[rid].submit_t for rid in rids}
    results = []
    for _ in range(2):
        results.extend(clu.step())
    assert clu.drives[0].engine.num_active > 0      # someone is mid-flight
    clu.fail(0)
    results.extend(clu.run_until_complete())
    assert sorted(r.rid for r in results) == rids
    assert all(r.status == "ok" for r in results)
    for rec in clu.stats.latency.records:
        assert rec.submit_t == submit_t[rec.rid]     # original submit kept
        assert_record_ordered(rec)
