"""Data pipeline determinism/partition properties + checkpoint round-trips."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.data import (DataConfig, MemmapTokenSource, ShardedLoader,
                        SyntheticTokenSource, write_token_file)


# --- data pipeline -----------------------------------------------------------


def test_synthetic_deterministic_and_seekable():
    src = SyntheticTokenSource(1000, seed=7)
    a = src.read(12345, 500)
    b = src.read(12345, 500)
    np.testing.assert_array_equal(a, b)
    # random access == streaming access
    c = np.concatenate([src.read(12345, 100), src.read(12445, 400)])
    np.testing.assert_array_equal(a, c)
    assert a.min() >= 0 and a.max() < 1000


@settings(max_examples=25, deadline=None)
@given(step=st.integers(0, 1000), gb=st.integers(2, 16),
       seq=st.integers(4, 64), hosts=st.integers(1, 4))
def test_host_slices_partition_global_batch(step, gb, seq, hosts):
    """Union of per-host batches == global batch; no overlap, no gaps."""
    hosts = min(hosts, gb)
    cfg = DataConfig(seq_len=seq, global_batch=gb, vocab_size=50_000)
    loader = ShardedLoader(SyntheticTokenSource(cfg.vocab_size), cfg,
                           num_hosts=hosts)
    parts = [loader.batch_at(step, h) for h in sorted(loader.shares)]
    glob = loader.global_batch_at(step)
    got = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(got, glob["tokens"])
    assert glob["tokens"].shape == (gb, seq)
    # next-token labels
    np.testing.assert_array_equal(glob["tokens"][:, 1:], glob["labels"][:, :-1])


def test_memmap_source_roundtrip(tmp_path):
    toks = np.arange(1000) % 600
    path = tmp_path / "toks.bin"
    write_token_file(path, toks)
    src = MemmapTokenSource(path)
    np.testing.assert_array_equal(src.read(10, 20), toks[10:30])
    # wraps at epoch boundary
    got = src.read(990, 20)
    np.testing.assert_array_equal(got, np.r_[toks[990:], toks[:10]])


def test_share_rebalance_changes_slices_only_forward():
    cfg = DataConfig(seq_len=8, global_batch=8, vocab_size=100)
    loader = ShardedLoader(SyntheticTokenSource(100), cfg, num_hosts=2)
    before = loader.global_batch_at(5)["tokens"]
    loader.set_shares({"host0": 6, "host1": 2})
    after = loader.global_batch_at(5)["tokens"]
    np.testing.assert_array_equal(before, after)   # global stream unchanged


# --- checkpointing -----------------------------------------------------------


def _tree(rng):
    return {"a": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
            "b": {"c": jnp.asarray(rng.integers(0, 5, (2,)), jnp.int32),
                  "d": jnp.asarray(rng.normal(size=(5,)), jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_path, rng):
    tree = _tree(rng)
    save_checkpoint(tmp_path, 7, tree)
    assert latest_step(tmp_path) == 7
    got, man = restore_checkpoint(tmp_path, tree)
    assert man["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_uncommitted_checkpoint_ignored(tmp_path, rng):
    tree = _tree(rng)
    save_checkpoint(tmp_path, 3, tree)
    # simulate a crash mid-save of step 9: directory exists, no .done marker
    (tmp_path / "step_000000009").mkdir()
    assert latest_step(tmp_path) == 3


def test_manager_async_and_gc(tmp_path, rng):
    tree = _tree(rng)
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, tree)
    mgr.wait()
    assert latest_step(tmp_path) == 4
    kept = sorted(p.name for p in tmp_path.glob("step_*.done"))
    assert len(kept) == 2


def test_restart_exact_resume(tmp_path):
    """Train 6 steps; train 3 + crash + resume 3 — identical final params."""
    import dataclasses
    from repro.config import reduced_config
    from repro.data import DataConfig
    from repro.train.train_loop import TrainConfig, train

    cfg = dataclasses.replace(reduced_config("yi-9b"), dtype="float32")
    dcfg = DataConfig(seq_len=16, global_batch=2, vocab_size=cfg.vocab_size)

    full = train(cfg, dcfg, TrainConfig(steps=6, log_every=100,
                                        ckpt_every=100, ckpt_dir=None))

    d = tmp_path / "ck"
    part = train(cfg, dcfg, TrainConfig(steps=3, log_every=100, ckpt_every=3,
                                        ckpt_dir=str(d)))
    resumed = train(cfg, dcfg, TrainConfig(steps=6, log_every=100,
                                           ckpt_every=100, ckpt_dir=str(d)))
    assert resumed.step == 6
    for a, b in zip(jax.tree.leaves(full.params), jax.tree.leaves(resumed.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-5)
