"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs (assignment requirement)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import reduced_config
from repro.configs import ASSIGNED
from repro.models import model as M


def _batch(cfg, rng, B=2, S=24):
    if cfg.frontend:
        return {"embeddings": jnp.asarray(
                    rng.normal(size=(B, S, cfg.d_model)), jnp.float32),
                "labels": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                  jnp.int32)}


@pytest.fixture(params=ASSIGNED)
def arch(request):
    return request.param


def test_forward_and_train_step(arch, rng):
    cfg = dataclasses.replace(reduced_config(arch), dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    loss, metrics = M.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss)), arch
    grads = jax.grad(lambda p: M.loss_fn(p, batch, cfg)[0])(params)
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf)).all(), arch
    # one optimizer step moves the loss
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    ocfg = AdamWConfig(lr=1e-2)
    opt = adamw_init(params, ocfg)
    params2, _, _ = adamw_update(params, grads, opt, ocfg)
    loss2, _ = M.loss_fn(params2, batch, cfg)
    assert float(loss2) < float(loss), arch


def test_prefill_decode_shapes(arch, rng):
    cfg = dataclasses.replace(reduced_config(arch), dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, rng, B, S)
    batch.pop("labels")
    nxt, caches = M.prefill_fn(params, batch, cfg)
    assert nxt.shape == (B,)
    assert int(nxt.max()) < cfg.vocab_size
    caches = M.init_caches(cfg, B, S + 4)
    tok = nxt[:, None].astype(jnp.int32)
    for t in range(3):
        tok2, caches = M.decode_fn(params, caches, tok, jnp.int32(t), cfg)
        assert tok2.shape == (B,)
        assert np.isfinite(np.asarray(tok2)).all()
        tok = tok2[:, None].astype(jnp.int32)


def test_prefill_matches_decode_chain(arch, rng):
    """Prefill then one decode == feeding tokens stepwise (cache integrity).

    MoE archs allowed small drift (capacity drops differ between batch
    layouts); others must match the next token exactly.
    """
    cfg = dataclasses.replace(reduced_config(arch), dtype="float32")
    if cfg.frontend:
        pytest.skip("frontend archs prefill on embeddings, decode on tokens")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    nxt_pre, _ = M.prefill_fn(params, {"tokens": toks}, cfg)
    caches = M.init_caches(cfg, B, S + 2)
    for t in range(S):
        nxt_seq, caches = M.decode_fn(params, caches, toks[:, t:t + 1],
                                      jnp.int32(t), cfg)
    if cfg.moe is None:
        np.testing.assert_array_equal(np.asarray(nxt_pre), np.asarray(nxt_seq))
