"""Core ISP invariants: embedding/xent equivalence, transfer ledgers,
optimizer, gradient compression (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import ModelConfig
from repro.core import embedding as emb
from repro.core import transfer
from repro.kernels import ref
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, cosine_schedule, int8_compress,
                         int8_decompress)


def _cfg(vocab=64, d=16):
    return ModelConfig(name="t", family="dense", num_layers=2, d_model=d,
                       num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=vocab)


def test_local_xent_matches_logsumexp(rng):
    cfg = _cfg()
    x = jnp.asarray(rng.normal(size=(2, 8, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 64, (2, 8)), jnp.int32)
    got = emb.sharded_xent(x, w, labels, None, cfg)
    logits = x @ w.T
    want = (jax.scipy.special.logsumexp(logits, -1)
            - jnp.take_along_axis(logits, labels[..., None], -1)[..., 0])
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_vocab_padding_never_wins_sampling(rng):
    cfg = _cfg(vocab=60)       # pads to 64
    x = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    w = jnp.zeros((64, 16), jnp.float32)
    w = w.at[60:].set(100.0)   # poison the pad rows
    toks = emb.greedy_sample(x, w, None, cfg)
    assert int(np.max(np.asarray(toks))) < 60


@pytest.mark.fast
def test_embedding_transfer_plan_reduction():
    base, isp = transfer.embedding_plans(num_lookups=65536, vocab=262_144,
                                         d_model=3840, tp=16)
    assert isp.reduction_vs(base) > 0.0
    # table bytes never move under ISP
    assert "all-gather table" not in isp.notes


@pytest.mark.fast
def test_decode_attention_transfer_plan_reduction():
    base, isp = transfer.decode_attention_plans(batch=128, heads=128,
                                                head_dim=128, seq=32_768,
                                                kv_heads=8)
    assert isp.reduction_vs(base) > 0.95   # KV stays resident: >20x saving


@pytest.mark.fast
def test_workload_ledger_matches_paper_fraction():
    led = transfer.workload_split_ledger(3.8e9, csd_fraction=0.68,
                                         output_bytes=1.2e6)
    host_only = transfer.host_only_ledger(3.8e9, 1.2e6)
    assert abs(led.reduction_vs(host_only) - 0.68) < 0.01


# --- optimizer ---------------------------------------------------------------


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, cfg)
    assert float(loss(params)) < 1e-3


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


def test_schedule_warmup_and_decay():
    assert float(cosine_schedule(0, warmup=10, total=100)) == 0.0
    assert float(cosine_schedule(10, warmup=10, total=100)) == pytest.approx(1.0)
    assert float(cosine_schedule(100, warmup=10, total=100)) == pytest.approx(0.1)


# --- gradient compression ----------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(scale=st.floats(1e-3, 1e3), n=st.integers(1, 512), seed=st.integers(0, 2**31))
def test_int8_roundtrip_error_bounded(scale, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
    q, s = int8_compress(x, jax.random.PRNGKey(seed))
    back = int8_decompress(q, s)
    amax = float(jnp.abs(x).max())
    # error per element bounded by one quantization step
    assert float(jnp.abs(back - x).max()) <= amax / 127.0 + 1e-6


def test_int8_stochastic_rounding_unbiased():
    # 0.3/(1/127) = 38.1 — strictly between int8 steps, so deterministic
    # rounding would bias; stochastic rounding must hit 0.3 in expectation.
    x = jnp.concatenate([jnp.ones((1,)), jnp.full((200_000,), 0.3)])
    q, s = int8_compress(x, jax.random.PRNGKey(0))
    est = float(int8_decompress(q, s)[1:].mean())
    assert abs(est - 0.3) < 2e-4
