"""Unit tests for model components: SSM equivalences, attention cache
integrity, MoE dispatch properties, HLO analyzer."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import AttnConfig, ModelConfig, MoEConfig, SSMConfig
from repro.models import ssm
from repro.models import moe as moe_mod
from repro.models.layers import KeyGen, apply_rope, rms_norm


def _ssm_cfg():
    return ModelConfig(name="t", family="ssm", num_layers=2, d_model=32,
                       num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=64,
                       ssm=SSMConfig(state_dim=4, conv_width=3, expand=2,
                                     num_heads=2, chunk_size=8))


@pytest.mark.parametrize("layer", ["mlstm", "mamba", "slstm"])
def test_parallel_equals_stepwise(layer, rng):
    """Chunked-parallel forms exactly match the sequential recurrences."""
    cfg = _ssm_cfg()
    kg = KeyGen(jax.random.PRNGKey(0))
    B, S = 2, 21
    x = jnp.asarray(rng.normal(size=(B, S, 32)) * 0.5, jnp.float32)
    pf, f, cachef = {
        "mlstm": (ssm.mlstm_params, ssm.mlstm_apply,
                  lambda: ssm.init_mlstm_cache(cfg, B)),
        "mamba": (ssm.mamba_params, ssm.mamba_apply,
                  lambda: ssm.init_mamba_cache(cfg, B, jnp.float32)),
        "slstm": (ssm.slstm_params, ssm.slstm_apply,
                  lambda: ssm.init_slstm_cache(cfg, B)),
    }[layer]
    p = pf(cfg, kg, jnp.float32)
    y_par, cache_par = f(p, x, cfg, None, mode="prefill")
    cache = cachef()
    ys = []
    for t in range(S):
        y_t, cache = f(p, x[:, t:t + 1], cfg, None, cache=cache, mode="decode")
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(y_par, y_seq, atol=3e-4, rtol=3e-4)
    for kk in cache_par:
        np.testing.assert_allclose(cache_par[kk], cache[kk], atol=3e-4,
                                   rtol=3e-4, err_msg=f"{layer}/{kk}")


def test_mlstm_chunkwise_matches_step_oracle(rng):
    B, nh, dh, S = 1, 2, 8, 24
    t = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    q, k, v = t(B, S, nh, dh), t(B, S, nh, dh), t(B, S, nh, dh)
    li = t(B, S, nh)
    lf = jax.nn.log_sigmoid(t(B, S, nh))
    state = (jnp.zeros((B, nh, dh, dh)), jnp.zeros((B, nh, dh)),
             jnp.zeros((B, nh)))
    hs = []
    st_seq = state
    for i in range(S):
        h, st_seq = ssm.mlstm_step_ref(q[:, i], k[:, i], v[:, i], li[:, i],
                                       lf[:, i], st_seq)
        hs.append(h)
    st_chunk, h_chunk = ssm._mlstm_chunk(state, (q, k, v, li, lf))
    np.testing.assert_allclose(jnp.stack(hs, 1), h_chunk, atol=2e-4, rtol=2e-4)
    for a, b in zip(st_seq, st_chunk):
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)


def test_rope_relative_property(rng):
    """RoPE: <rot(q,m), rot(k,n)> depends only on m-n."""
    dh = 16
    q = jnp.asarray(rng.normal(size=(1, 1, 1, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, dh)), jnp.float32)

    def dot(m, n):
        qm = apply_rope(q, jnp.array([[m]]), 10_000.0)
        kn = apply_rope(k, jnp.array([[n]]), 10_000.0)
        return float(jnp.sum(qm * kn))

    assert dot(5, 3) == pytest.approx(dot(105, 103), rel=1e-4)
    assert dot(7, 0) == pytest.approx(dot(1007, 1000), rel=1e-4)


def test_rms_norm_scale_invariance(rng):
    x = jnp.asarray(rng.normal(size=(2, 5, 16)), jnp.float32)
    s = jnp.zeros((16,))
    np.testing.assert_allclose(rms_norm(4.0 * x, s), rms_norm(x, s),
                               atol=1e-5, rtol=1e-5)


# --- MoE dispatch properties --------------------------------------------------


def _moe_cfg(E=8, k=2, cap=1.25):
    return ModelConfig(name="t", family="moe", num_layers=2, d_model=16,
                       num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=64,
                       block_pattern=("moe",),
                       moe=MoEConfig(num_experts=E, top_k=k, d_ff_expert=16,
                                     capacity_factor=cap))


@settings(max_examples=30, deadline=None)
@given(t_tokens=st.integers(1, 64), e=st.sampled_from([4, 8]),
       k=st.integers(1, 3), seed=st.integers(0, 1000))
def test_dispatch_slots_unique_and_capacity_bounded(t_tokens, e, k, seed):
    rng = np.random.default_rng(seed)
    experts = jnp.asarray(rng.integers(0, e, (t_tokens, k)), jnp.int32)
    gates = jnp.asarray(rng.random((t_tokens, k)), jnp.float32)
    cap = max(1, int(t_tokens * k * 1.25 / e))
    e_idx, slot, keep, _ = moe_mod._dispatch_indices(experts, gates, e, cap)
    e_idx, slot, keep = map(np.asarray, (e_idx, slot, keep))
    assert (slot[keep] < cap).all()
    pairs = set()
    for ei, sl, kp in zip(e_idx, slot, keep):
        if kp:
            assert (ei, sl) not in pairs      # no slot collisions
            pairs.add((ei, sl))


def test_dense_moe_is_convex_combination(rng):
    """With top_k=E and uniform router the output is bounded by expert outs."""
    cfg = _moe_cfg(E=4, k=1)
    kg = KeyGen(jax.random.PRNGKey(1))
    p = moe_mod.moe_params(cfg, kg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 4, 16)), jnp.float32)
    y, aux = moe_mod.dense_moe(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) >= 1.0 - 1e-3         # switch aux lower bound is 1


# --- HLO analyzer -------------------------------------------------------------


def test_hlo_analyzer_trip_counts():
    from repro.analysis.hlo import analyze

    hlo = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8] dot(%g1, %g1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %t = (s32[], f32[8,8]) tuple(%g0, %d)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %c = pred[] constant(false)
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8] parameter(0)
  %init = (s32[], f32[8,8]) tuple(%x, %x)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  %ar = f32[8,8] all-reduce(%x), replica_groups=[4,8]<=[32], to_apply=%cond
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""
    st_ = analyze(hlo)
    assert st_.flops == 5 * 2 * 8 * 8 * 8           # dot in 5-trip loop
    # all-reduce: 2 * 256B * 7/8
    assert abs(st_.collective_bytes - 2 * 256 * 7 / 8) < 1e-6
