import sys

import numpy as np
import pytest

# NOTE: never set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; multi-device tests run in subprocesses
# (see tests/test_distributed.py).

# The image has no hypothesis and the repo may not add deps: install the
# deterministic stub under the real name, only when the package is missing.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    from repro._testing import hypothesis_stub
    sys.modules["hypothesis"] = hypothesis_stub
    sys.modules["hypothesis.strategies"] = hypothesis_stub.strategies


@pytest.fixture
def rng():
    return np.random.default_rng(0)
