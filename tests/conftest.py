import numpy as np
import pytest

# NOTE: never set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; multi-device tests run in subprocesses
# (see tests/test_distributed.py).


@pytest.fixture
def rng():
    return np.random.default_rng(0)
