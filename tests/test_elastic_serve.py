"""Fault tolerance (supervised restart) and serving engine tests."""
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_supervisor_restarts_after_injected_failure(tmp_path):
    """Kill training at step 12; supervisor relaunches; run completes and
    the checkpoint chain is continuous."""
    from repro.launch.elastic import supervise

    env = {"PYTHONPATH": str(ROOT / "src"), "REPRO_FAIL_AT_STEP": "12",
           "REPRO_FAIL_MARKER": str(tmp_path / "fail.marker")}
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "yi-9b",
           "--smoke", "--steps", "20", "--global-batch", "2",
           "--seq-len", "16", "--ckpt-dir", str(tmp_path),
           "--ckpt-every", "5", "--log-every", "100"]
    res = supervise(cmd, max_restarts=2, env=env, timeout_s=900)
    # attempt 1 dies at step 12 (rc=42, one-shot marker written); the
    # relaunch resumes from the step-10 checkpoint and completes.
    assert res.returncode == 0, res.log
    assert res.restarts >= 1
    from repro.checkpoint import latest_step
    assert latest_step(tmp_path) == 20


def test_serve_engine_generates(rng):
    import dataclasses
    from repro.config import reduced_config
    from repro.models import model as M
    from repro.train.serve_loop import ServeEngine

    cfg = dataclasses.replace(reduced_config("gemma3-12b"), dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_len=64)
    prompts = rng.integers(0, cfg.vocab_size, (3, 12)).tolist()
    results = engine.generate(prompts, max_new=8)
    assert len(results) == 3
    for r in results:
        assert 1 <= len(r.tokens) <= 8
        assert all(0 <= t < cfg.vocab_size for t in r.tokens)


def test_serve_prefill_path_matches_decode_path(rng):
    """Engine prefill+splice must equal pure step-by-step decoding."""
    import dataclasses
    from repro.config import reduced_config
    from repro.models import model as M
    from repro.train.serve_loop import ServeEngine

    cfg = dataclasses.replace(reduced_config("yi-9b"), dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = rng.integers(0, cfg.vocab_size, (2, 12)).tolist()

    eng = ServeEngine(cfg, params, max_len=64)
    via_prefill = eng.generate(prompts, max_new=6)      # plen 12 > 8: prefill

    toks = jnp.asarray(np.array(prompts, np.int32))
    caches = M.init_caches(cfg, 2, 64)
    for t in range(12):
        nxt, caches = M.decode_fn(params, caches, toks[:, t:t + 1],
                                  jnp.int32(t), cfg)
    manual = [[int(nxt[i])] for i in range(2)]
    cur = nxt[:, None].astype(jnp.int32)
    for j in range(5):
        nxt, caches = M.decode_fn(params, caches, cur, jnp.int32(12 + j), cfg)
        cur = nxt[:, None].astype(jnp.int32)
        for i in range(2):
            manual[i].append(int(nxt[i]))
    for i in range(2):
        assert via_prefill[i].tokens == manual[i], i
