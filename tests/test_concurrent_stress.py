"""Seeded stress tier: hammer the concurrent worker runtime with
randomized workloads, tick jitter, and injected crashes / hangs /
stalls, asserting the full invariant set EVERY iteration:

  * every submitted rid comes back exactly once (sorted identity);
  * every "ok" result is token-identical to the fault-free serial
    oracle (greedy decode: recovery is exactly replayable);
  * conservation: ``submitted == ok + shed + failed``;
  * the KV free-list balances on every drive (no leaked pages);
  * worker threads join cleanly after every iteration.

Iteration count defaults to 50 (the acceptance bar); CI's smoke tier
sets ``STRESS_ITERS`` lower.  Every iteration is an independent seeded
cluster, so a failure message's seed reproduces it alone."""
import dataclasses
import os
import threading

import jax
import numpy as np
import pytest

from repro.config import reduced_config
from repro.core.faults import FaultSchedule
from repro.core.runtime import HeartbeatWatchdog
from repro.models import model as M
from repro.train.cluster_loop import ClusterEngine
from repro.train.serve_loop import ServeEngine

MAX_LEN = 64
MAX_NEW = 4
ITERS = int(os.environ.get("STRESS_ITERS", "50"))


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(reduced_config("yi-9b"), dtype="float32")


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def ref_k1(cfg, params):
    return ServeEngine(cfg, params, max_len=MAX_LEN, num_slots=2, k_block=1,
                       prewarm=True)


@pytest.fixture(scope="module")
def pool(cfg, ref_k1):
    """Prompt pool + the serial oracle's tokens for each prompt."""
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).tolist()
               for n in rng.integers(4, 14, 6)]
    want = [r.tokens for r in ref_k1.generate(prompts, max_new=MAX_NEW)]
    return prompts, want


def _iteration_faults(rng) -> FaultSchedule | None:
    """none / crash / short hang (recovers) / long hang (killed) / stall,
    always on drive 1 so drive 0 keeps the cluster alive."""
    roll = int(rng.integers(0, 5))
    if roll == 0:
        return None
    at = int(rng.integers(0, 5))
    if roll == 1:
        spec = {"drive_id": 1, "kind": "crash", "at_tick": at}
    elif roll == 2:
        spec = {"drive_id": 1, "kind": "worker_hang", "at_tick": at,
                "duration": 0.02}
    elif roll == 3:
        spec = {"drive_id": 1, "kind": "worker_hang", "at_tick": at,
                "duration": 5.0}
    else:
        spec = {"drive_id": 1, "kind": "stall", "at_tick": at,
                "duration": int(rng.integers(1, 4))}
    return FaultSchedule.from_spec([spec])


def test_concurrent_stress_seeded_iterations(cfg, params, ref_k1, pool):
    prompts, want = pool
    for it in range(ITERS):
        seed = 1000 + it
        rng = np.random.default_rng(seed)
        picks = sorted(rng.choice(len(prompts),
                                  size=int(rng.integers(3, 6)),
                                  replace=False).tolist())
        faults = _iteration_faults(rng)
        clu = ClusterEngine(
            cfg, params, jit_donor=ref_k1, n_drives=2, concurrent=True,
            routing="round_robin", max_len=MAX_LEN, num_slots=2, k_block=1,
            prewarm=True, faults=faults, max_retries=5,
            dispatch_timeout_s=0.05,
            tick_jitter_s=float(rng.uniform(0.0, 0.01)),
            jitter_seed=seed,
            watchdog=HeartbeatWatchdog(2, suspect_after_s=0.06,
                                       suspect_misses=3, dead_after_s=0.5,
                                       dead_misses=60))
        try:
            rids = [clu.submit(prompts[p], max_new=MAX_NEW) for p in picks]
            res = {r.rid: r for r in clu.run_until_complete()}
            ctx = f"seed={seed} picks={picks} faults={faults}"
            assert sorted(res) == rids, ctx
            for rid, p in zip(rids, picks):
                if res[rid].status == "ok":
                    assert res[rid].tokens == want[p], f"{ctx} rid={rid}"
            ok = sum(1 for r in res.values() if r.status == "ok")
            shed = sum(1 for r in res.values() if r.status == "shed")
            failed = sum(1 for r in res.values() if r.status == "failed")
            assert len(rids) == ok + shed + failed, ctx
            # the retry budget (5) absorbs any single drive-1 fault
            assert failed == 0, ctx
            for d in clu.drives:
                assert d.engine.pager.num_in_use == 0, ctx
                d.engine.pager.check_balanced()
        finally:
            clu.close()
        assert not [t for t in threading.enumerate()
                    if t.name.startswith("drive-worker-")], f"seed={seed}"
