"""Perf smoke (fast tier): the engine benchmark at a tiny config must run,
produce finite non-zero throughput in both KV layouts, keep paged and strip
token-identical, and show the paged peak-KV win — the same gate
``scripts/ci.sh perf-smoke`` applies, wired into ``-m fast``.  The cluster
case is ``scripts/ci.sh cluster-smoke``'s gate: a 2-replica cluster must
serve token-identically to one engine replaying the trace serially."""
import json
import math

import pytest

from benchmarks.fig5_throughput import run_engine_compare
from benchmarks.fig6_cluster import run_cluster

pytestmark = pytest.mark.fast


def test_engine_perf_smoke(tmp_path):
    out = tmp_path / "BENCH_fig5.json"
    payload = run_engine_compare(emit=lambda _: None, n_requests=3,
                                 max_new=3, num_slots=2, page_size=8,
                                 k_block=8, json_path=str(out))
    assert payload["tokens_identical"]
    assert payload["k_block"] == 8
    for layout in ("paged", "strip"):
        t = payload[layout]["tokens_per_s"]
        assert math.isfinite(t) and t > 0
        assert payload[layout]["steps_per_s"] > 0
        assert payload[layout]["decode_steps"] > 0
        assert payload[layout]["compile_s"] > 0          # prewarm ran
        for phase in ("dispatch_s_per_step", "compute_s_per_step"):
            assert math.isfinite(payload[layout]["phases"][phase])
    # PR-2 tentpole: peak KV tracks live tokens, not slots * max_len
    assert payload["paged"]["peak_kv_bytes"] < payload["paged"]["dense_kv_bytes"]
    assert payload["paged"]["kv_reduction"] > 0
    # PR-3 tentpole gate (also enforced inside run_engine_compare): the
    # paged fused loop may not fall behind strip by more than 1.5x plus
    # the 50 ms jitter slack (smoke workloads decode in single-digit ms)
    assert payload["paged"]["decode_s"] <= \
        1.5 * payload["strip"]["decode_s"] + 0.05
    on_disk = json.loads(out.read_text())
    assert on_disk["bench"] == "fig5_engine"
    assert on_disk["paged"]["tokens"] == payload["paged"]["tokens"]


def test_cluster_smoke_token_identical_to_serial_replay(tmp_path):
    """PR-4 tentpole gate: a 2-replica cluster behind one queue serves the
    exact tokens a single engine produces replaying the same trace
    serially, with finite throughput and a live energy-per-query that
    matches the analytic Table I model (checked inside run_cluster)."""
    out = tmp_path / "BENCH_fig6_cluster.json"
    payload = run_cluster(emit=lambda _: None, n_requests=4, max_new=3,
                          num_slots=2, max_drives=2,
                          policies=("least_loaded",), strict=False,
                          json_path=str(out))
    assert payload["tokens_identical"]
    for n in ("1", "2"):
        m = payload["runs"]["least_loaded"][n]
        assert m["completed"] == 4
        assert math.isfinite(m["tokens_per_s"]) and m["tokens_per_s"] > 0
        assert m["energy_per_query_mj"] > 0
        assert 0.0 < m["link_reduction"] <= 1.0
    assert payload["runs"]["least_loaded"]["2"]["mean_active"] > 1.0
    assert json.loads(out.read_text())["bench"] == "fig6_cluster"
