"""Fault tier: injection schedules, the SUSPECT/DEAD detector, retry
budgets, hedged dispatch, pool clamps, and the conservation invariant
``submitted == ok + shed + failed`` under arbitrary fault schedules.

Pure-math tests (FaultEvent / FaultSchedule / FailureDetector /
quarantine) are fast-marked; the engine-backed tests inject faults into a
real replica cluster and assert the ok outputs stay token-identical to a
fault-free serial replay — greedy decode makes recovery exactly
replayable, which is the whole reason the schedule is seeded."""
import dataclasses
import math

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import reduced_config
from repro.core.cluster import ClusterExhaustedError, ClusterStats
from repro.core.faults import (DEAD, HEALTHY, SUSPECT, FailureDetector,
                               FaultEvent, FaultSchedule)
from repro.core.latency import LatencyRecord, LatencyStats
from repro.core.scheduler import ClusterAdmission
from repro.models import model as M
from repro.train.cluster_loop import ClusterEngine
from repro.train.serve_loop import ServeEngine

MAX_LEN = 64


# ---------------------------------------------------------------------------
# pure: fault events + schedules
# ---------------------------------------------------------------------------


@pytest.mark.fast
def test_fault_event_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultEvent(0, "meltdown", at_tick=1)
    with pytest.raises(ValueError, match="exactly one"):
        FaultEvent(0, "stall", at_tick=1, at_s=1.0)
    with pytest.raises(ValueError, match="exactly one"):
        FaultEvent(0, "stall")
    with pytest.raises(ValueError, match="drive_id"):
        FaultEvent(-1, "stall", at_tick=1)
    with pytest.raises(ValueError, match="duration"):
        FaultEvent(0, "stall", at_tick=1, duration=-2.0)
    with pytest.raises(ValueError, match="slowdown factor"):
        FaultEvent(0, "slowdown", at_tick=1, duration=1, factor=0.5)
    with pytest.raises(ValueError, match="page_pool_clamp factor"):
        FaultEvent(0, "page_pool_clamp", at_tick=1, duration=1, factor=1.5)
    # crashes ignore duration entirely (death is permanent)
    e = FaultEvent(0, "crash", at_s=2.0)
    assert e.end == math.inf
    assert not e.active(0, 1.9) and e.active(0, 2.0) and e.active(0, 99.0)


@pytest.mark.fast
def test_fault_event_windows_tick_and_clock_basis():
    t = FaultEvent(1, "stall", at_tick=3, duration=2)
    assert [t.active(k, 0.0) for k in range(7)] == \
        [False, False, False, True, True, False, False]
    assert t.tick_based and t.start == 3 and t.end == 5
    c = FaultEvent(1, "slowdown", at_s=1.0, duration=0.5, factor=2.0)
    assert not c.active(99, 0.99)      # clock basis ignores the tick index
    assert c.active(0, 1.0) and c.active(0, 1.49) and not c.active(0, 1.5)


@pytest.mark.fast
def test_schedule_queries_compose_and_report_once():
    sch = FaultSchedule.from_spec([
        {"drive_id": 0, "kind": "stall", "at_tick": 2, "duration": 3},
        {"drive_id": 0, "kind": "slowdown", "at_tick": 2, "duration": 4,
         "factor": 2.0},
        {"drive_id": 0, "kind": "slowdown", "at_tick": 3, "duration": 2,
         "factor": 3.0},
        {"drive_id": 1, "kind": "crash", "at_tick": 4},
        {"drive_id": 1, "kind": "page_pool_clamp", "at_tick": 0,
         "duration": 10, "factor": 0.5},
    ])
    # begins() reports each event exactly once, at its start
    assert len(sch.begins(0, 0.0)) == 1            # the clamp
    assert len(sch.begins(1, 0.0)) == 0
    assert len(sch.begins(2, 0.0)) == 2            # stall + first slowdown
    assert sch.crashes(3, 0.0) == []
    assert sch.crashes(4, 0.0) == [1]
    assert sch.crashes(5, 0.0) == []               # delivered once
    # a delivered crash still reads as a permanent stall (silence) —
    # ground truth for the engine, invisible to the detector
    assert sch.stalled(1, 99, 0.0)
    assert sch.stalled(0, 2, 0.0) and not sch.stalled(0, 5, 0.0)
    # overlapping slowdowns compound; clamps take the min
    assert sch.slowdown(0, 3, 0.0) == pytest.approx(6.0)
    assert sch.slowdown(0, 6, 0.0) == pytest.approx(1.0)
    assert sch.clamp(1, 1, 0.0) == pytest.approx(0.5)
    assert sch.clamp(0, 1, 0.0) == 1.0
    # boundaries: next start/end strictly after now (crash end = inf never)
    assert sch.next_tick_boundary(0) == 2
    assert sch.next_tick_boundary(4) == 5
    assert sch.next_tick_boundary(10) is None
    assert sch.next_clock_boundary(0.0) is None    # all tick-based


@pytest.mark.fast
def test_schedule_from_rates_is_seeded_and_valid():
    a = FaultSchedule.from_rates(4, mttf_s=2.0, mttr_s=0.5, seed=3)
    b = FaultSchedule.from_rates(4, mttf_s=2.0, mttr_s=0.5, seed=3)
    c = FaultSchedule.from_rates(4, mttf_s=2.0, mttr_s=0.5, seed=4)
    assert [dataclasses.astuple(e) for e in a.events] == \
        [dataclasses.astuple(e) for e in b.events]
    assert [dataclasses.astuple(e) for e in a.events] != \
        [dataclasses.astuple(e) for e in c.events]
    assert a.events                                 # 60s horizon, 2s MTTF
    for e in a.events:
        assert 0 <= e.drive_id < 4
        assert e.at_s is not None and 0.0 < e.at_s < 60.0
        assert e.kind != "crash" or e.end == math.inf
    # a crashed drive draws no further events
    for d in range(4):
        mine = [e for e in a.events if e.drive_id == d]
        crash = [i for i, e in enumerate(mine) if e.kind == "crash"]
        assert not crash or crash == [len(mine) - 1]
    with pytest.raises(ValueError, match="mttf"):
        FaultSchedule.from_rates(2, mttf_s=0.0, mttr_s=1.0)
    with pytest.raises(ValueError, match="crash_prob"):
        FaultSchedule.from_rates(2, mttf_s=1.0, mttr_s=1.0, crash_prob=2.0)


# ---------------------------------------------------------------------------
# pure: failure detector state machine
# ---------------------------------------------------------------------------


@pytest.mark.fast
def test_detector_suspects_then_kills_on_zero_progress_ticks():
    det = FailureDetector(2, suspect_after_s=math.inf, suspect_ticks=2,
                          dead_ticks=4)
    assert det.observe(0, 0.0, progressed=True, has_work=True) == \
        (HEALTHY, HEALTHY)
    # silent with work: 2 ticks -> SUSPECT, 4 -> DEAD (terminal)
    assert det.observe(0, 1.0, False, True) == (HEALTHY, HEALTHY)
    assert det.observe(0, 2.0, False, True) == (HEALTHY, SUSPECT)
    assert det.suspects == [0]
    assert det.observe(0, 3.0, False, True) == (SUSPECT, SUSPECT)
    assert det.observe(0, 4.0, False, True) == (SUSPECT, DEAD)
    assert det.dead == [0]
    assert det.observe(0, 5.0, True, True) == (DEAD, DEAD)   # no resurrection
    # drive 1 never observed: still healthy
    assert det.health[1] == HEALTHY


@pytest.mark.fast
def test_detector_lag_threshold_and_recovery():
    det = FailureDetector(1, suspect_after_s=1.0, suspect_ticks=100,
                          dead_after_s=3.0, dead_ticks=400)
    det.observe(0, 5.0, True, True)            # productive at lead=5
    # lag is measured since the LAST PRODUCTIVE tick, not absolute skew
    assert det.observe(0, 5.9, False, True)[1] == HEALTHY
    assert det.observe(0, 6.1, False, True)[1] == SUSPECT
    # a productive tick clears suspicion AND re-bases the lag
    assert det.observe(0, 6.2, True, True)[1] == HEALTHY
    assert det.observe(0, 7.1, False, True)[1] == HEALTHY    # lag only 0.9
    assert det.observe(0, 9.3, False, True)[1] == DEAD       # lag 3.1 > 3.0


@pytest.mark.fast
def test_detector_never_suspects_idle_drives():
    det = FailureDetector(1, suspect_after_s=10.0, suspect_ticks=1)
    for lead in (1.0, 50.0, 1000.0):
        assert det.observe(0, lead, progressed=False, has_work=False) == \
            (HEALTHY, HEALTHY)
    # idle ticks re-base the lag: work arriving later starts from scratch
    assert det.observe(0, 1000.5, False, True)[1] == SUSPECT  # ticks=1


@pytest.mark.fast
def test_detector_validation_and_mark_dead():
    with pytest.raises(ValueError, match="suspect"):
        FailureDetector(1, suspect_after_s=0.0)
    with pytest.raises(ValueError, match="dead thresholds"):
        FailureDetector(1, suspect_after_s=1.0, dead_after_s=0.5)
    det = FailureDetector(3)
    assert det.dead_after_s == pytest.approx(4 * det.suspect_after_s)
    assert det.dead_ticks == 4 * det.suspect_ticks
    det.mark_dead(1)
    assert det.health == [HEALTHY, DEAD, HEALTHY]
    assert det.observe(1, 0.0, True, True) == (DEAD, DEAD)


@pytest.mark.fast
def test_quarantine_drops_observations_and_refits_quotas():
    pull = ClusterAdmission(3)
    for d in range(3):
        for _ in range(4):
            pull.observe(d, 0.1 * (d + 1), [2])   # drive 0 fastest
    q = pull.quotas(6, [0, 1, 2])
    assert sum(q.values()) == 6 and q[0] > q[2]
    pull.quarantine(1)
    assert pull.quarantined == [1]
    r1 = pull.rate(1)
    pull.observe(1, 99.0, [1])                    # garbage tick: dropped
    assert pull.rate(1) == pytest.approx(r1)
    q = pull.quotas(6, [0, 1, 2])
    assert q.get(1, 0) == 0 and sum(q.values()) == 6
    # EVERY live drive quarantined: fall back to all of them (serve
    # degraded rather than not at all)
    pull.quarantine(0)
    pull.quarantine(2)
    q = pull.quotas(6, [0, 1, 2])
    assert sum(q.values()) == 6 and set(q) == {0, 1, 2}
    # release keeps the pre-quarantine EWMA (transient stall, same drive)
    pull.unquarantine(1)
    assert pull.quarantined == [0, 2]
    assert pull.rate(1) == pytest.approx(r1)
    with pytest.raises(KeyError):
        pull.quarantine(7)


@pytest.mark.fast
def test_latency_failed_accounting_and_restart_budget():
    stats = LatencyStats()
    ok = LatencyRecord(rid=0, submit_t=0.0)
    ok.admit_t = ok.first_token_t = 0.1
    ok.finish_t, ok.status = 0.2, "ok"
    failed = LatencyRecord(rid=1, submit_t=0.0, deadline_s=1.0)
    failed.restart()
    failed.restart()
    failed.finish_t, failed.status = 5.0, "failed"
    stats.add(ok)
    stats.add(failed)
    assert stats.count == 1 and stats.failed == 1 and stats.shed == 0
    # a failed request missed its SLO by definition: the denominator counts it
    assert stats.slo_attainment == pytest.approx(0.5)
    assert "1 failed" in stats.summary()
    # restart() keeps the ORIGINAL submit (the user waited through every
    # retry) and counts the budget spent
    assert failed.retries == 2 and failed.submit_t == 0.0
    assert failed.e2e_s == pytest.approx(5.0)
    assert not math.isfinite(failed.admit_t)       # re-stamped on retry


@pytest.mark.fast
def test_cluster_stats_surface_fault_counters():
    stats = ClusterStats()
    stats.record_tick(2, 0.5)
    stats.completed = 4
    stats.faults_injected = 3
    stats.auto_failed_drives = 1
    stats.health = [HEALTHY, DEAD]
    stats.retries = 2
    stats.failed_requests = 1
    stats.hedges, stats.hedges_won, stats.hedges_lost = 2, 1, 1
    stats.hedge_wasted_s = 0.25
    assert stats.wasted_s == pytest.approx(0.25 + stats.shed_wasted_s)
    assert stats.hedge_energy_mj > 0.0
    s = stats.summary()
    assert "faults" in s and "dead" in s and "retries" in s and "hedge" in s


# ---------------------------------------------------------------------------
# engine-backed: chaos against a real replica cluster
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(reduced_config("yi-9b"), dtype="float32")


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def ref_k1(cfg, params):
    """k_block=1 oracle/donor: one decode step per tick, so injected
    faults land mid-flight deterministically."""
    return ServeEngine(cfg, params, max_len=MAX_LEN, num_slots=2, k_block=1)


@pytest.fixture(scope="module")
def trace(cfg, ref_k1):
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist()
               for n in (5, 9, 7, 11)]
    want = [r.tokens for r in ref_k1.generate(prompts, max_new=6)]
    return prompts, want


def make_cluster(cfg, params, ref_k1, **kw):
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("num_slots", 2)
    kw.setdefault("k_block", 1)
    kw.setdefault("routing", "round_robin")
    return ClusterEngine(cfg, params, jit_donor=ref_k1, **kw)


def assert_conserved_and_balanced(clu, res, n_submitted):
    ok = sum(1 for r in res if r.status == "ok")
    shed = sum(1 for r in res if r.status == "shed")
    failed = sum(1 for r in res if r.status == "failed")
    assert n_submitted == ok + shed + failed
    for d in clu.drives:
        if d.failed or not d.has_work:
            assert d.engine.pager.num_in_use == 0
            d.engine.pager.check_balanced()


def test_crash_is_detected_and_recovered_token_identically(
        cfg, params, ref_k1, trace):
    """The tentpole path: a hidden crash mid-decode -> zero-progress ticks
    -> SUSPECT -> DEAD -> auto-fail() -> retries replay on the survivor
    and reproduce the oracle's tokens exactly."""
    prompts, want = trace
    faults = FaultSchedule.from_spec(
        [{"drive_id": 1, "kind": "crash", "at_tick": 3}])
    det = FailureDetector(2, suspect_ticks=2, dead_ticks=4,
                          suspect_after_s=math.inf)
    clu = make_cluster(cfg, params, ref_k1, n_drives=2, faults=faults,
                       detector=det)
    rids = [clu.submit(p, max_new=6) for p in prompts]
    res = {r.rid: r for r in clu.run_until_complete()}
    assert sorted(res) == rids
    assert [res[r].tokens for r in rids] == want
    assert clu.stats.health == [HEALTHY, DEAD]
    assert clu.stats.faults_injected == 1
    assert clu.stats.auto_failed_drives == 1
    assert clu.stats.retries > 0                   # in-flight work restarted
    assert clu.stats.failed_requests == 0          # budget sufficed
    assert_conserved_and_balanced(clu, list(res.values()), len(rids))
    # the detector's verdict is in the latency records too
    assert clu.stats.latency.count == len(rids)


def test_fail_requeue_after_dispatch_reaches_idle_survivor(cfg, params,
                                                           ref_k1):
    """Regression: detection runs AFTER dispatch inside a tick, so a
    fail()'s requeued request can land in the queue when every surviving
    drive is already idle.  The idle-advance path must grant dispatch one
    more tick instead of raising ClusterExhaustedError.  Fused decode
    blocks (k_block>1) make the window easy to hit: whole requests finish
    per tick, so the survivor drains while the crashed drive sits."""
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size,
                            rng.integers(4, 17)).tolist() for _ in range(6)]
    want = [r.tokens for r in ref_k1.generate(prompts, max_new=6)]
    faults = FaultSchedule.from_spec(
        [{"drive_id": 1, "kind": "crash", "at_tick": 1}])
    det = FailureDetector(2, suspect_ticks=2, dead_ticks=4,
                          suspect_after_s=math.inf)
    # no jit_donor: ref_k1 is k_block=1 wiring, this cluster needs the
    # fused block (the donor check rightly refuses the mismatch)
    clu = ClusterEngine(cfg, params, n_drives=2, routing="round_robin",
                        max_len=MAX_LEN, num_slots=2, k_block=8,
                        faults=faults, detector=det)
    rids = [clu.submit(p, max_new=6) for p in prompts]
    res = {r.rid: r for r in clu.run_until_complete()}
    assert sorted(res) == rids
    assert [res[r].tokens for r in rids] == want
    assert not clu._stuck
    assert clu.stats.health == [HEALTHY, DEAD]
    assert clu.stats.auto_failed_drives == 1
    assert_conserved_and_balanced(clu, list(res.values()), len(rids))


def test_stall_suspects_quarantines_then_recovers(cfg, params, ref_k1,
                                                  trace):
    """A transient stall must NOT kill the drive: SUSPECT while silent
    (quarantined from quotas), HEALTHY again on the first productive tick,
    and every token identical to the fault-free oracle."""
    prompts, want = trace
    faults = FaultSchedule.from_spec(
        [{"drive_id": 1, "kind": "stall", "at_tick": 2, "duration": 4}])
    det = FailureDetector(2, suspect_ticks=2, dead_ticks=1000,
                          suspect_after_s=math.inf)
    clu = make_cluster(cfg, params, ref_k1, n_drives=2, faults=faults,
                       detector=det)
    rids = [clu.submit(p, max_new=6) for p in prompts]
    saw_suspect = saw_quarantine = False
    while clu.queue or any(d.has_work for d in clu.drives):
        clu.step()
        saw_suspect |= clu.stats.health[1] == SUSPECT
        saw_quarantine |= clu.pull.quarantined == [1]
    got = {r.rid: r for r in clu._finished}
    assert sorted(got) == rids
    assert [got[r].tokens for r in rids] == want
    assert saw_suspect and saw_quarantine
    assert clu.stats.health == [HEALTHY, HEALTHY]  # recovered
    assert clu.pull.quarantined == []              # released on recovery
    assert clu.stats.auto_failed_drives == 0
    assert clu.stats.retries == 0                  # nothing restarted
    assert_conserved_and_balanced(clu, list(got.values()), len(rids))


def test_retry_budget_exhaustion_fails_requests_terminally(cfg, params,
                                                           ref_k1):
    """max_retries=0: a fail() mid-flight may not requeue — the in-flight
    requests finish status="failed" with their ORIGINAL submit time, and
    conservation still holds."""
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist()
               for n in (5, 9, 7, 11)]
    clu = make_cluster(cfg, params, ref_k1, n_drives=2, max_retries=0)
    rids = [clu.submit(p, max_new=6) for p in prompts]
    clu.step()
    clu.step()                                     # drive 1 mid-decode
    assert clu.stats.drives[1].requests > 0
    clu.fail(1)
    res = {r.rid: r for r in clu.run_until_complete()}
    res.update({r.rid: r for r in clu._finished})
    assert sorted(res) == rids
    failed = [r for r in res.values() if r.status == "failed"]
    assert failed and clu.stats.failed_requests == len(failed)
    assert clu.stats.retries == 0                  # budget was zero
    assert all(r.tokens == [] for r in failed)
    recs = [r for r in clu.stats.latency.records if r.status == "failed"]
    assert len(recs) == len(failed)
    assert all(r.submit_t == 0.0 and r.retries == 0 for r in recs)
    assert_conserved_and_balanced(clu, list(res.values()), len(rids))


def test_hedged_dispatch_rescues_suspect_stranded_request(cfg, params,
                                                          ref_k1, trace):
    """hedge=True: the oldest slot-stranded request of a SUSPECT drive is
    duplicated onto a healthy drive; the first finisher wins, the loser's
    burned serving time is booked as hedge waste."""
    prompts, want = trace
    # two requests only: round_robin puts one on each drive, leaving the
    # healthy drive a free slot to hedge into; the stall outlives the run
    faults = FaultSchedule.from_spec(
        [{"drive_id": 1, "kind": "stall", "at_tick": 2, "duration": 10000}])
    det = FailureDetector(2, suspect_ticks=2, dead_ticks=10 ** 6,
                          suspect_after_s=math.inf)
    clu = make_cluster(cfg, params, ref_k1, n_drives=2, faults=faults,
                       detector=det, hedge=True)
    rids = [clu.submit(p, max_new=6) for p in prompts[:2]]
    for _ in range(400):
        clu.step()
        if not (clu.queue or any(not d.failed and d.engine.num_active
                                 for d in clu.drives if d.drive_id == 0)):
            if all(r in {x.rid for x in clu._finished} for r in rids):
                break
    got = {r.rid: r for r in clu._finished}
    assert sorted(got) == rids
    assert [got[r].tokens for r in rids] == want[:2]   # hedge replays exactly
    assert clu.stats.hedges >= 1
    assert clu.stats.hedges_won >= 1               # the stalled copy lost
    assert got[rids[1]].drive == 0                 # served by the hedger
    assert clu._hedges == {}                       # settled
    # the canceled copy's slot went back to the pool
    d1 = clu.drives[1].engine
    assert d1.num_active == 0 and d1.pager.num_in_use == 0
    d1.pager.check_balanced()


def test_pool_clamp_backpressures_then_lifts(cfg, params, ref_k1):
    """page_pool_clamp frac=0: NO new admissions while active (in-flight
    reservations untouched); when the window ends the queue drains and
    tokens match the oracle — degradation, not deadlock."""
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist() for n in (5, 8)]
    want = [r.tokens for r in ref_k1.generate(prompts, max_new=4)]
    faults = FaultSchedule.from_spec(
        [{"drive_id": 0, "kind": "page_pool_clamp", "at_tick": 0,
          "duration": 6, "factor": 0.0}])
    clu = make_cluster(cfg, params, ref_k1, n_drives=1, faults=faults)
    rids = [clu.submit(p, max_new=4) for p in prompts]
    for _ in range(4):
        clu.step()
    eng = clu.drives[0].engine
    assert eng.num_active == 0                     # clamp blocked admission
    assert eng.pending + len(clu.queue) == 2
    res = {r.rid: r for r in clu.run_until_complete()}
    res.update({r.rid: r for r in clu._finished})
    assert sorted(res) == rids
    assert [res[r].tokens for r in rids] == want
    assert all(r.status == "ok" for r in res.values())
    assert eng.pool_clamp_frac == 1.0              # lifted
    assert_conserved_and_balanced(clu, list(res.values()), len(rids))


def test_serve_engine_cancel_frees_slot_and_pages(cfg, params, ref_k1):
    eng = ServeEngine(cfg, params, max_len=MAX_LEN, num_slots=2, k_block=1,
                      jit_donor=ref_k1)
    rid_q = eng.submit([1, 2, 3], max_new=4)
    # queued cancel: nothing ran, nothing wasted
    assert eng.cancel(rid_q) == 0.0
    assert eng.pending == 0
    rid_a = eng.submit([4, 5, 6, 7], max_new=4)
    eng.step()
    eng.step()
    assert eng.num_active == 1
    wasted = eng.cancel(rid_a)
    assert wasted is not None and wasted > 0.0     # burned prefill+decode
    assert eng.num_active == 0 and eng.pager.num_in_use == 0
    eng.pager.check_balanced()
    assert eng.cancel(rid_a) is None               # unknown rid
    # the engine must not deliver a result for a canceled request
    assert eng.run_until_complete() == []


def test_fail_mid_chunked_prefill_leaks_no_pages(cfg, params, ref_k1):
    """Regression: fail() while a chunked prefill is half-spliced must
    free the partially filled pages (the free-list is the gate)."""
    rng = np.random.default_rng(17)
    long_p = rng.integers(0, cfg.vocab_size, 24).tolist()
    short_p = rng.integers(0, cfg.vocab_size, 5).tolist()
    want = [r.tokens
            for r in ref_k1.generate([short_p, long_p], max_new=4)]
    clu = make_cluster(cfg, params, ref_k1, n_drives=2, chunk_prefill=4)
    rids = [clu.submit(short_p, max_new=4), clu.submit(long_p, max_new=4)]
    clu.step()                                     # first chunk spliced
    d1 = clu.drives[1]
    assert any(s.active and s.prefilling for s in d1.engine.slots)
    assert d1.engine.pager.num_in_use > 0
    clu.fail(1)
    assert d1.engine.pager.num_in_use == 0         # partial splice freed
    d1.engine.pager.check_balanced()
    res = {r.rid: r for r in clu.run_until_complete()}
    res.update({r.rid: r for r in clu._finished})
    assert sorted(res) == rids
    assert [res[r].tokens for r in rids] == want   # retried on drive 0
    assert_conserved_and_balanced(clu, list(res.values()), len(rids))


def test_last_drive_crash_fails_queue_and_raises_when_drained(cfg, params,
                                                              ref_k1):
    """Total loss: the detector kills the only drive -> queued requests
    finish status="failed" (conservation), and a later submit against the
    dead cluster raises ClusterExhaustedError."""
    faults = FaultSchedule.from_spec(
        [{"drive_id": 0, "kind": "crash", "at_tick": 1}])
    det = FailureDetector(1, suspect_ticks=2, dead_ticks=4,
                          suspect_after_s=math.inf)
    clu = make_cluster(cfg, params, ref_k1, n_drives=1, faults=faults,
                       detector=det, max_retries=1)
    rids = [clu.submit([1, 2, 3], max_new=4), clu.submit([4, 5], max_new=4)]
    res = clu.run_until_complete()
    assert sorted(r.rid for r in res) == rids
    assert all(r.status == "failed" for r in res)
    assert clu.stats.health == [DEAD]
    assert_conserved_and_balanced(clu, res, len(rids))
    clu.submit([7, 8], max_new=2)
    with pytest.raises(ClusterExhaustedError, match="draining/failed"):
        clu.run_until_complete()


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 60))
def test_any_fault_schedule_conserves_and_replays_tokens(cfg, params,
                                                         ref_k1, seed):
    """Property: under a randomized seeded fault schedule on drives 1..2
    (drive 0 stays clean so the cluster survives), every request that
    finishes "ok" is token-identical to the fault-free serial replay and
    ``submitted == ok + shed + failed`` — recovery never invents, loses,
    or corrupts work."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).tolist()
               for n in rng.integers(4, 10, 5)]
    want = {i: r.tokens
            for i, r in enumerate(ref_k1.generate(prompts, max_new=4))}
    kinds = ("stall", "slowdown", "crash", "page_pool_clamp")
    events = []
    for _ in range(int(rng.integers(1, 4))):
        kind = kinds[int(rng.integers(len(kinds)))]
        e = {"drive_id": int(rng.integers(1, 3)), "kind": kind,
             "at_tick": int(rng.integers(0, 8))}
        if kind != "crash":
            e["duration"] = int(rng.integers(1, 5))
        if kind == "slowdown":
            e["factor"] = 2.0
        if kind == "page_pool_clamp":
            e["factor"] = float(rng.uniform(0.0, 1.0))
        events.append(e)
    det = FailureDetector(3, suspect_ticks=2, dead_ticks=4,
                          suspect_after_s=math.inf)
    clu = make_cluster(cfg, params, ref_k1, n_drives=3,
                       faults=FaultSchedule.from_spec(events), detector=det,
                       max_retries=5, hedge=bool(seed % 2))
    rids = [clu.submit(p, max_new=4) for p in prompts]
    res = {r.rid: r for r in clu.run_until_complete()}
    res.update({r.rid: r for r in clu._finished})
    assert sorted(res) == rids
    for i, rid in enumerate(rids):
        if res[rid].status == "ok":
            assert res[rid].tokens == want[i]
    assert_conserved_and_balanced(clu, list(res.values()), len(rids))
    # the spill ledger's invariant survives chaos too: never negative
    assert clu.stats.spill_bytes >= 0.0
