"""Device-resident fused K-block decode loop + chunked prefill: early-exit
semantics, same-tick page release, chunked-vs-one-shot prefill equivalence
(caches and sampled tokens), jit pre-warm accounting, and the bench guard's
payload invariants."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import reduced_config
from repro.core import kv_pages
from repro.models import model as M
from repro.train.serve_loop import AdmissionController, ServeEngine

MAX_LEN = 64


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(reduced_config("yi-9b"), dtype="float32")


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


def make_engine(cfg, params, num_slots=2, **kw):
    kw.setdefault("admission",
                  AdmissionController(num_slots, host_rate=3.0, csd_rate=1.0))
    return ServeEngine(cfg, params, max_len=MAX_LEN, num_slots=num_slots, **kw)


# ---------------------------------------------------------------------------
# K-block early exit
# ---------------------------------------------------------------------------


def test_kblock_early_exit_no_extra_tokens_pages_freed(cfg, params, rng):
    """All slots finishing mid-block must end the block early (no wasted
    device steps), emit exactly max_new tokens, and return every page to
    the pool in the same engine tick."""
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist() for n in (6, 9)]
    engine = make_engine(cfg, params, kv_layout="paged", page_size=8,
                         k_block=8)
    for p in prompts:
        engine.submit(p, max_new=3)
    done = engine.step()                 # admit + prefill + ONE fused block
    # max_new=3 = prefill token + 2 decode steps — both slots die at inner
    # step 2 of an 8-step block, so the while_loop must exit early
    assert [len(r.tokens) for r in done] == [3, 3]
    assert engine.stats.decode_steps == 2
    assert engine.num_active == 0 and engine.pending == 0
    engine.pager.check_balanced()        # pages freed in the SAME tick


def test_kblock_matches_host_loop_with_eos(cfg, params, rng):
    """EOS firing inside a block must stop that slot exactly where the K=1
    host loop stops it, while other slots keep decoding to their budget."""
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist() for n in (8, 10)]
    reference = make_engine(cfg, params, k_block=1).generate(prompts,
                                                            max_new=6)
    eos = reference[0].tokens[2]
    want = [r.tokens[: r.tokens.index(eos) + 1] if eos in r.tokens
            else r.tokens for r in reference]
    got = make_engine(cfg, params, eos_id=eos, k_block=8).generate(
        prompts, max_new=6)
    assert [r.tokens for r in got] == want
    assert len(got[0].tokens) == 3 and got[0].tokens[-1] == eos


def test_kblock_device_state_survives_refill(cfg, params, rng):
    """More requests than slots with k_block > 1: mid-workload refills must
    resync the persistent device token/position/alive arrays correctly."""
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist()
               for n in (6, 11, 7, 13, 9)]
    max_news = [2, 6, 3, 5, 4]
    ref = make_engine(cfg, params, k_block=1, kv_layout="strip")
    fused = make_engine(cfg, params, k_block=3)   # K not dividing budgets
    for p, m in zip(prompts, max_news):
        ref.submit(p, max_new=m)
        fused.submit(p, max_new=m)
    want = {r.rid: r.tokens for r in ref.run_until_complete()}
    got = {r.rid: r.tokens for r in fused.run_until_complete()}
    assert got == want
    fused.pager.check_balanced()


# ---------------------------------------------------------------------------
# Chunked prefill
# ---------------------------------------------------------------------------


def _prompt_rows(engine, group, n_tokens):
    """Gather a slot-0 KV strip view (k, v) for the first n_tokens rows."""
    cache = engine.caches[group]
    pages = np.asarray(engine.page_table[0])[None]
    k = kv_pages.gather_pages(cache["kp"][0], pages)[0, :n_tokens]
    v = kv_pages.gather_pages(cache["vp"][0], pages)[0, :n_tokens]
    return np.asarray(k), np.asarray(v)


def test_chunked_prefill_equivalent_to_one_shot(cfg, params, rng):
    """Chunked prefill must leave the paged pool holding the same KV rows
    as the one-shot prefill (same physical pages, allclose values) and
    sample the same next token."""
    prompt = rng.integers(0, cfg.vocab_size, 21).tolist()

    oneshot = make_engine(cfg, params, page_size=8, k_block=1)
    chunked = make_engine(cfg, params, page_size=8, k_block=1,
                          chunk_prefill=8)
    r1 = oneshot.submit(prompt, max_new=1)
    r2 = chunked.submit(prompt, max_new=1)
    while oneshot.num_active or oneshot.pending:
        oneshot.step()
    ticks = 0
    while chunked.num_active or chunked.pending:
        chunked.step()
        ticks += 1
    assert ticks >= 3                          # 21 tokens / 8 = 3 chunks
    want = {r.rid: r.tokens for r in oneshot._finished}
    got = {r.rid: r.tokens for r in chunked._finished}
    assert got[r2] == want[r1]                 # same sampled token

    # engines are drained, so re-prefill once more and inspect the pool
    # before decode: submit + single admission/prefill tick each
    oneshot.submit(prompt, max_new=4)
    chunked.submit(prompt, max_new=4)
    oneshot._admit()
    chunked._admit()
    for _ in range(3):
        chunked._chunk_prefill_tick()
    assert np.array_equal(oneshot.page_table, chunked.page_table)
    for g in oneshot.caches:
        k1, v1 = _prompt_rows(oneshot, g, len(prompt))
        k2, v2 = _prompt_rows(chunked, g, len(prompt))
        np.testing.assert_allclose(k1, k2, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(v1, v2, atol=1e-5, rtol=1e-5)


def test_chunked_prefill_interleaves_decode(cfg, params, rng):
    """A long admission must not stall in-flight decodes: the short request
    keeps emitting (and can finish) while the long prompt is still
    splicing chunk by chunk."""
    short = rng.integers(0, cfg.vocab_size, 5).tolist()
    long_p = rng.integers(0, cfg.vocab_size, 48).tolist()
    engine = make_engine(cfg, params, num_slots=2, page_size=8,
                         k_block=1, chunk_prefill=4)      # 12 chunk ticks
    engine.submit(short, max_new=3)
    engine.submit(long_p, max_new=2)
    finished = []
    while (engine.num_active or engine.pending) and not finished:
        finished = engine.step()
    # the short request finished while the long one was still prefilling
    assert finished and finished[0].tokens and len(finished[0].tokens) == 3
    assert any(s.active and s.prefilling for s in engine.slots)
    engine.run_until_complete()
    engine.pager.check_balanced()


def test_chunk_prefill_gated_to_paged_full_attention(cfg, params):
    """Strip layouts (and stacks with window/recurrent layers) must fall
    back to one-shot prefill instead of mis-splicing chunks."""
    strip = make_engine(cfg, params, kv_layout="strip", chunk_prefill=8)
    assert strip.chunk_prefill is None
    g3 = dataclasses.replace(reduced_config("gemma3-12b"), dtype="float32")
    g3_engine = ServeEngine(g3, M.init_params(g3, jax.random.PRNGKey(0)),
                            max_len=MAX_LEN, num_slots=2, chunk_prefill=8)
    assert g3_engine.chunk_prefill is None     # window layers in the stack


# ---------------------------------------------------------------------------
# Pre-warm
# ---------------------------------------------------------------------------


def test_prewarm_reports_compile_time_and_stays_identical(cfg, params, rng):
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist() for n in (5, 12)]
    cold = make_engine(cfg, params, k_block=8)
    warm = make_engine(cfg, params, k_block=8, chunk_prefill=8, prewarm=True)
    assert warm.stats.compile_s > 0
    want = [r.tokens for r in cold.generate(prompts, max_new=4)]
    got = [r.tokens for r in warm.generate(prompts, max_new=4)]
    assert got == want
    # the cold engine's lazy first-shape calls are booked as compile, not
    # serving: neither engine's decode_s contains the XLA compile anymore,
    # and the compile the cold engine paid is visible in compile_s
    assert cold.stats.compile_s > 0
    assert cold.stats.decode_s < cold.stats.compile_s
    assert warm.stats.decode_s < warm.stats.compile_s
