"""Open-loop workload generator: determinism, arrival-process shape,
trace persistence and time-axis scaling."""
import math

import pytest

from repro.data.workload import (ARRIVAL_MODES, DEFAULT_CLASSES,
                                 PriorityClass, WorkloadConfig,
                                 generate_trace, load_trace, save_trace,
                                 scale_trace)


def _cfg(**kw):
    base = dict(n_requests=64, vocab_size=1000, seed=0)
    base.update(kw)
    return WorkloadConfig(**base)


@pytest.mark.fast
@pytest.mark.parametrize("mode", ARRIVAL_MODES)
def test_trace_deterministic_per_seed(mode):
    a = generate_trace(_cfg(arrival=mode, seed=3))
    b = generate_trace(_cfg(arrival=mode, seed=3))
    assert a == b
    c = generate_trace(_cfg(arrival=mode, seed=4))
    assert [r.arrival_s for r in a] != [r.arrival_s for r in c]


@pytest.mark.fast
@pytest.mark.parametrize("mode", ARRIVAL_MODES)
def test_arrivals_monotone_positive(mode):
    trace = generate_trace(_cfg(arrival=mode))
    times = [r.arrival_s for r in trace]
    assert len(times) == 64
    assert all(t > 0.0 and math.isfinite(t) for t in times)
    assert times == sorted(times)


@pytest.mark.fast
def test_requests_respect_class_ranges():
    trace = generate_trace(_cfg(n_requests=128))
    by_name = {c.name: c for c in DEFAULT_CLASSES}
    seen = set()
    for r in trace:
        c = by_name[r.cls]
        seen.add(r.cls)
        assert r.priority == c.priority
        assert c.prompt_range[0] <= len(r.prompt) <= c.prompt_range[1]
        assert c.max_new_range[0] <= r.max_new <= c.max_new_range[1]
        assert all(0 <= t < 1000 for t in r.prompt)
        # deadline is ABSOLUTE: arrival + the class's TTFT budget
        assert r.deadline_s == pytest.approx(r.arrival_s + c.slo_s)
    assert seen == set(by_name)       # 128 draws hit both classes


@pytest.mark.fast
def test_bursty_is_actually_bursty():
    """The on-phase of each cycle must hold a disproportionate share of
    arrivals (duty 0.25 at burst_factor 4 => ~80% of the mean rate mass)."""
    cfg = _cfg(n_requests=256, arrival="bursty", rate=8.0)
    trace = generate_trace(cfg)
    on = sum(1 for r in trace
             if (r.arrival_s % cfg.period_s) < cfg.duty * cfg.period_s)
    assert on / len(trace) > 2 * cfg.duty


@pytest.mark.fast
def test_save_load_roundtrip(tmp_path):
    trace = generate_trace(_cfg(n_requests=16, arrival="bursty"))
    path = tmp_path / "trace.jsonl"
    save_trace(str(path), trace)
    assert load_trace(str(path)) == trace


@pytest.mark.fast
def test_scale_trace_scales_arrivals_and_deadlines():
    trace = generate_trace(_cfg(n_requests=16))
    scaled = scale_trace(trace, 0.5)
    for r, s in zip(trace, scaled):
        assert s.arrival_s == pytest.approx(r.arrival_s * 0.5)
        assert s.deadline_s == pytest.approx(r.deadline_s * 0.5)
        assert s.prompt == r.prompt and s.max_new == r.max_new
        assert s.priority == r.priority and s.cls == r.cls
    # best-effort requests stay best-effort
    trace[0].deadline_s = None
    assert scale_trace(trace, 2.0)[0].deadline_s is None
    for bad in (0.0, -1.0, math.inf, math.nan):
        with pytest.raises(ValueError):
            scale_trace(trace, bad)


@pytest.mark.fast
def test_workload_config_validation():
    with pytest.raises(ValueError):
        _cfg(arrival="lumpy")
    with pytest.raises(ValueError):
        _cfg(n_requests=0)
    for bad_rate in (0.0, -2.0, math.inf, math.nan):
        with pytest.raises(ValueError):
            _cfg(rate=bad_rate)
    for bad_duty in (0.0, 1.5):
        with pytest.raises(ValueError):
            _cfg(arrival="bursty", duty=bad_duty)
    with pytest.raises(ValueError):
        _cfg(classes=())


@pytest.mark.fast
def test_custom_single_class():
    cls = (PriorityClass("only", priority=2, weight=1.0, slo_s=None,
                         prompt_range=(3, 3), max_new_range=(2, 2)),)
    trace = generate_trace(_cfg(n_requests=8, classes=cls))
    for r in trace:
        assert len(r.prompt) == 3 and r.max_new == 2
        assert r.priority == 2 and r.deadline_s is None
