"""Frontend stubs + ISP plan-choice tests."""
import numpy as np

from repro.config import get_config
from repro.core.isp import choose_decode_plan, choose_embedding_plan
from repro.models.frontend import AudioFrontendStub, VQFrontendStub


def test_audio_frontend_shapes(rng):
    cfg = get_config("musicgen-large")
    fe = AudioFrontendStub(cfg)
    wav = rng.standard_normal((2, 16_000)).astype(np.float32)
    emb, toks = fe.encode(wav)
    assert emb.shape == (2, 50, cfg.d_model)
    assert toks.shape == (2, 50)
    assert toks.min() >= 0 and toks.max() < cfg.vocab_size
    # deterministic
    emb2, _ = fe.encode(wav)
    np.testing.assert_array_equal(emb, emb2)


def test_vq_frontend_shapes(rng):
    cfg = get_config("chameleon-34b")
    fe = VQFrontendStub(cfg, patch=16)
    img = rng.standard_normal((2, 64, 64, 3)).astype(np.float32)
    emb, codes = fe.encode(img)
    assert emb.shape == (2, 16, cfg.d_model)
    assert codes.shape == (2, 16)
    assert codes.max() < cfg.vocab_size


def test_plan_choice_prefers_isp_for_big_tables():
    c = choose_embedding_plan(num_lookups=65_536, vocab=262_144, d_model=3840)
    assert c.plan == "isp" and c.saving > 0.3


def test_plan_choice_prefers_isp_for_decode_kv():
    c = choose_decode_plan(batch=128, heads=128, head_dim=128, seq=32_768,
                           kv_heads=8)
    assert c.plan == "isp" and c.saving > 0.9


def test_plan_choice_host_wins_for_tiny_resident_object():
    # table smaller than the rows it would serve: ship it once
    c = choose_embedding_plan(num_lookups=1_000_000, vocab=64, d_model=8)
    assert c.plan == "host"
