"""Multi-drive cluster tier: routing policies, ledger merging, Table I
energy through the cluster path, drain/fail requeue, and spill accounting.

Pure-math tests (router / merge / ClusterStats) are fast-marked; the
engine-backed tests drive real replica ``ServeEngine``s and assert the
cluster serves token-identically to a single engine."""
import dataclasses
import math

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import reduced_config
from repro.core.cluster import (ClusterStats, DriveLoad, Router,
                                merge_ledgers, shard_spill_bytes)
from repro.core.energy import energy_per_query_mj, server_power
from repro.core.transfer import TransferLedger
from repro.models import model as M
from repro.train.cluster_loop import ClusterEngine
from repro.train.serve_loop import ServeEngine, ServeStats

MAX_LEN = 64


# ---------------------------------------------------------------------------
# pure: ledger merging
# ---------------------------------------------------------------------------


@pytest.mark.fast
def test_merge_ledgers_sums_tiers_and_notes():
    a, b = TransferLedger(), TransferLedger()
    a.add("link", 10.0, "prefill")
    a.add("kv", 5.0, "decode KV rows")
    a.add("local", 2.0)
    b.add("link", 7.0, "prefill")
    b.add("output", 1.0, "results")
    b.add("kv", 3.0, "decode KV rows")
    m = merge_ledgers([a, b])
    assert m.link_bytes == 17.0
    assert m.kv_bytes == 8.0
    assert m.local_bytes == 2.0
    assert m.output_bytes == 1.0
    assert m.notes == {"prefill": 17.0, "decode KV rows": 8.0, "results": 1.0}
    # inputs untouched
    assert a.link_bytes == 10.0 and b.link_bytes == 7.0
    assert merge_ledgers([]).link_bytes == 0.0


@pytest.mark.fast
def test_merged_ledger_reduction_matches_per_drive_sum():
    stats = []
    for chosen, base in ((10.0, 100.0), (30.0, 100.0)):
        s = ServeStats()
        s.ledger.add("link", chosen)
        s.baseline.add("link", base)
        stats.append(s)
    cs = ClusterStats(drives=stats)
    assert cs.link_bytes == 40.0
    assert cs.host_link_bytes == 200.0
    assert cs.link_reduction == pytest.approx(0.8)
    cs.spill_ledger.add("link", 60.0, "remote shard spill")
    assert cs.link_bytes == 100.0
    assert cs.link_reduction == pytest.approx(0.5)
    assert cs.spill_bytes == 60.0


# ---------------------------------------------------------------------------
# pure: routing policies
# ---------------------------------------------------------------------------


def loads(*caps, slots=2):
    """DriveLoads with the given free capacities (active fills the rest)."""
    return [DriveLoad(drive_id=i, num_slots=slots, active=slots - c)
            for i, c in enumerate(caps)]


@pytest.mark.fast
def test_router_validates_policy_and_placement():
    with pytest.raises(ValueError):
        Router("fastest", 2)
    r = Router("data_local", 2, placement={7: 5})
    with pytest.raises(ValueError):
        r.home(7)
    assert Router("data_local", 3).home(7) == 1        # shard % n_drives


@pytest.mark.fast
def test_round_robin_cycles_and_skips_full_drives():
    r = Router("round_robin", 3)
    got = [r.pick(None, loads(1, 1, 1)).drive_id for _ in range(4)]
    assert got == [0, 1, 2, 0]
    r = Router("round_robin", 3)
    got = [r.pick(None, loads(1, 0, 1)).drive_id for _ in range(3)]
    assert got == [0, 2, 0]                            # drive 1 full: skipped
    assert r.pick(None, loads(0, 0, 0)) is None        # everyone full: wait


@pytest.mark.fast
def test_least_loaded_uses_occupancy_and_page_fill_tiebreak():
    r = Router("least_loaded", 3)
    assert r.pick(None, loads(1, 2, 1)).drive_id == 1
    tied = loads(1, 1, 1)
    tied[0].page_fill = 0.9                            # fuller KV pool loses
    assert r.pick(None, tied).drive_id == 1


@pytest.mark.fast
def test_data_local_pins_home_then_spills_when_full():
    r = Router("data_local", 2)
    route = r.pick(1, loads(1, 1))
    assert (route.drive_id, route.remote) == (1, False)
    route = r.pick(1, loads(1, 0))                     # home full -> spill
    assert (route.drive_id, route.remote) == (0, True)
    r = Router("data_local", 2, spill=False)
    assert r.pick(1, loads(1, 0)) is None              # no spill: wait
    # a dead home drive forces the spill even with spill=False
    dead = loads(1, 1)
    dead[1].accepting = False
    route = r.pick(1, dead)
    assert (route.drive_id, route.remote) == (0, True)
    # unsharded requests fall back to least_loaded, never "remote"
    assert r.pick(None, loads(0, 1)).remote is False


@pytest.mark.fast
def test_shard_spill_bytes_scales_with_request_footprint():
    assert shard_spill_bytes(10, 6, 64, 4) == 16 * 64 * 4
    assert shard_spill_bytes(1, 0, 8, 2) == 16


@pytest.mark.fast
def test_round_robin_uniform_over_survivors_after_drain():
    """A drive draining mid-rotation must not skew which survivor absorbs
    its turns: the rotation stays uniform over the eligible set."""
    from collections import Counter
    r = Router("round_robin", 4)
    # advance the rotation so the pointer sits mid-cycle when drive 2 dies
    for _ in range(6):
        r.pick(None, loads(1, 1, 1, 1))
    drained = loads(1, 1, 1, 1)
    drained[2].accepting = False
    picks = Counter(r.pick(None, drained).drive_id for _ in range(300))
    assert set(picks) == {0, 1, 3}
    assert all(n == 100 for n in picks.values())       # exactly uniform
    # same when the ineligibility comes from a FULL drive instead
    r = Router("round_robin", 3)
    picks = Counter(r.pick(None, loads(1, 0, 1)).drive_id
                    for _ in range(200))
    assert picks[0] == picks[2] == 100


@pytest.mark.fast
def test_driveload_quota_caps_capacity():
    l = DriveLoad(drive_id=0, num_slots=4, active=1, pending=1)
    assert l.capacity == 2
    l.quota = 3                                        # cap below slots
    assert l.capacity == 1
    l.quota = 9                                        # slack cap: slots win
    assert l.capacity == 2


@pytest.mark.fast
def test_rate_aware_explores_cold_drives_first():
    """Drives without a rate estimate are routed to first (they must serve
    something before the scheduler can rate them), in least_loaded order."""
    r = Router("rate_aware", 2)
    cold = loads(1, 1)
    assert r.pick(None, cold).drive_id == 0
    cold[0].service_s = 0.5                            # drive 1 still cold
    cold[0].clock = 0.0
    assert r.pick(None, cold).drive_id == 1


@pytest.mark.fast
def test_rate_aware_routes_by_expected_completion_and_defers():
    """Rated drives: the request goes to the earliest expected completion
    (clock + backlog x service time); when that drive is full the head
    WAITS instead of burdening the slower drive."""
    r = Router("rate_aware", 2)

    def rated(fast_busy, slow_busy, slots=2):
        ls = loads(slots - fast_busy, slots - slow_busy, slots=slots)
        ls[0].service_s, ls[0].clock = 0.1, 0.0        # fast drive
        ls[1].service_s, ls[1].clock = 0.2, 0.0        # 2x slower
        return ls

    # both idle: fast drive finishes sooner
    assert r.pick(None, rated(0, 0)).drive_id == 0
    # fast has 1 in flight: ETA 0.2 vs slow idle 0.2 — tie broken on load,
    # the slow drive gets its exploratory share
    assert r.pick(None, rated(1, 0)).drive_id == 1
    # fast FULL, slow idle: waiting for the fast drive (2+1)*0.1 = 0.3 is
    # still later than slow (0+1)*0.2 = 0.2 -> slow serves it
    assert r.pick(None, rated(2, 0)).drive_id == 1
    # fast full and far ahead of a busy slow drive: defer for the fast one
    ls = rated(2, 1)
    ls[1].clock = 1.0                                  # slow clock is ahead
    assert r.pick(None, ls) is None
    # a draining fast drive can't be waited for: the slow one serves
    ls[0].accepting = False
    got = r.pick(None, ls)
    assert got is not None and got.drive_id == 1


@pytest.mark.fast
def test_router_replace_shard_overrides_home():
    r = Router("data_local", 3)
    assert r.home(4) == 1                              # static: shard % 3
    r.replace_shard(4, 2)
    assert r.home(4) == 2                              # override wins
    route = r.pick(4, loads(1, 1, 1))
    assert (route.drive_id, route.remote) == (2, False)
    with pytest.raises(ValueError):
        r.replace_shard(4, 3)                          # outside the cluster
    # other shards keep the static placement
    assert r.home(1) == 1


# ---------------------------------------------------------------------------
# pure: ClusterStats energy — all six published Table I numbers through the
# cluster path (live integral == core.energy analytics on the same load)
# ---------------------------------------------------------------------------

TABLE1 = [
    # (throughput qps, active ISP engines, paper mJ/query)
    (96.0, 0, 5021.0),
    (296.0, 36, 1662.0),
    (579.0, 0, 832.0),
    (1506.0, 36, 327.0),
    (9496.0, 0, 50.8),
    (20994.0, 36, 23.4),
]


@pytest.mark.fast
@pytest.mark.parametrize("qps,n_active,paper_mj", TABLE1)
def test_cluster_stats_reproduces_table1(qps, n_active, paper_mj):
    stats = ClusterStats()
    ticks, tick_s = 8, 0.25
    for _ in range(ticks):
        stats.record_tick(n_active, tick_s)
    stats.completed = int(round(qps * ticks * tick_s))
    assert stats.throughput_qps == pytest.approx(qps, rel=1e-3)
    assert stats.mean_active == pytest.approx(n_active)
    # the live integral must equal the analytic Table I model exactly...
    assert stats.energy_per_query_mj == pytest.approx(
        energy_per_query_mj(stats.throughput_qps, n_active), rel=1e-9)
    # ...and therefore land on the published numbers
    tol = 2.0 if paper_mj > 100 else 1.0
    assert abs(stats.energy_per_query_mj - paper_mj) < tol


@pytest.mark.fast
def test_cluster_stats_energy_integral_with_varying_activity():
    """server_power is affine in n_active, so the integral over a varying
    activity trace equals server_power(time-weighted mean) * time."""
    stats = ClusterStats()
    trace = [(4, 0.5), (1, 0.25), (0, 1.0), (3, 0.25)]
    for n, dt in trace:
        stats.record_tick(n, dt)
    total_t = sum(dt for _, dt in trace)
    stats.completed = 10
    assert stats.cluster_s == pytest.approx(total_t)
    assert stats.energy_j == pytest.approx(
        sum(server_power(n) * dt for n, dt in trace))
    assert stats.energy_per_query_mj == pytest.approx(
        energy_per_query_mj(stats.throughput_qps, stats.mean_active),
        rel=1e-9)
    with pytest.raises(ValueError):
        stats.record_tick(1, -0.1)


@pytest.mark.fast
def test_cluster_stats_energy_reduction_vs_host():
    """2 drives halving the wall time at marginal ISP watts must save
    energy per query; degenerate stats must not blow up."""
    stats = ClusterStats()
    for _ in range(4):
        stats.record_tick(2, 0.5, tick_serial_s=1.0)   # parallel halves wall
    stats.completed = 8
    assert stats.serial_s == pytest.approx(2 * stats.cluster_s)
    e_host = energy_per_query_mj(stats.completed / stats.serial_s, 0)
    expect = 1.0 - stats.energy_per_query_mj / e_host
    assert stats.energy_reduction_vs_host == pytest.approx(expect)
    assert expect > 0.4
    assert ClusterStats().energy_reduction_vs_host == 0.0
    assert ClusterStats().energy_per_query_mj == 0.0


# ---------------------------------------------------------------------------
# engine-backed: replica serving, locality, drain/fail
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(reduced_config("yi-9b"), dtype="float32")


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def ref(cfg, params):
    """Single engine: the serial-replay oracle AND the shared jit donor."""
    return ServeEngine(cfg, params, max_len=MAX_LEN, num_slots=2)


@pytest.fixture(scope="module")
def ref_k1(cfg, params):
    """k_block=1 oracle/donor: one decode step per tick, so drain/fail
    events land mid-flight deterministically."""
    return ServeEngine(cfg, params, max_len=MAX_LEN, num_slots=2, k_block=1)


@pytest.fixture(scope="module")
def trace(cfg):
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist()
               for n in (5, 11, 7, 14, 9, 6)]
    shards = [1, 0, 1, 1, 0, 1]
    return prompts, shards


def make_cluster(cfg, params, ref, **kw):
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("num_slots", 2)
    return ClusterEngine(cfg, params, jit_donor=ref, **kw)


def test_cluster_token_identical_to_serial_replay(cfg, params, ref, trace):
    prompts, shards = trace
    want = [r.tokens for r in ref.generate(prompts, max_new=4)]
    clu = make_cluster(cfg, params, ref, n_drives=2, routing="least_loaded")
    res = clu.generate(prompts, max_new=4, shard_ids=shards)
    assert [r.tokens for r in res] == want
    assert sorted({r.drive for r in res}) == [0, 1]    # both drives served
    st = clu.stats
    assert st.completed == len(prompts)
    assert st.tokens == sum(len(t) for t in want)
    assert st.ticks > 0 and st.cluster_s > 0
    assert st.serial_s >= st.cluster_s                 # parallel model
    assert 1.0 <= st.mean_active <= 2.0
    assert st.energy_per_query_mj == pytest.approx(
        energy_per_query_mj(st.throughput_qps, st.mean_active), rel=1e-6)
    assert 0.0 < st.link_reduction <= 1.0
    assert st.kv_reduction > 0.0                       # paged replicas


def test_data_local_pins_and_charges_spills(cfg, params, ref, trace):
    prompts, shards = trace
    want = [r.tokens for r in ref.generate(prompts, max_new=4)]
    # spill disabled: every request must be served on its shard's home
    clu = make_cluster(cfg, params, ref, n_drives=2, routing="data_local",
                       spill=False)
    res = clu.generate(prompts, max_new=4, shard_ids=shards)
    assert [r.tokens for r in res] == want
    assert all(r.drive == s % 2 for r, s in zip(res, shards))
    assert clu.stats.spill_bytes == 0.0
    assert clu.stats.remote_requests == 0
    # round_robin on the same sharded trace cannot stay home
    rr = make_cluster(cfg, params, ref, n_drives=2, routing="round_robin")
    res = rr.generate(prompts, max_new=4, shard_ids=shards)
    assert [r.tokens for r in res] == want
    assert rr.stats.remote_requests > 0
    assert rr.stats.spill_bytes > 0
    assert rr.stats.link_bytes > clu.stats.link_bytes  # locality saved bytes
    assert rr.stats.spill_ledger.notes.get("remote shard spill", 0.0) == \
        pytest.approx(rr.stats.spill_bytes)


def test_drain_requeues_unprefilled_and_stops_routing(cfg, params, ref,
                                                      trace):
    prompts, shards = trace
    want = [r.tokens for r in ref.generate(prompts, max_new=4)]
    clu = make_cluster(cfg, params, ref, n_drives=2, routing="round_robin")
    rids = [clu.submit(p, max_new=4, shard_id=s)
            for p, s in zip(prompts, shards)]
    # requeue BEFORE any tick: drive 1 must never see work
    n = clu.drain(1)
    assert n == 0                       # nothing dispatched yet
    res = {r.rid: r for r in clu.run_until_complete()}
    assert sorted(res) == rids
    assert all(res[r].drive == 0 for r in rids)
    assert clu.stats.drives[1].requests == 0
    assert [res[r].tokens for r in rids] == want


def test_drain_mid_flight_requeues_backpressured_drive_queue(cfg, params,
                                                            ref):
    """A tiny KV page pool leaves a dispatched request un-admitted in the
    drive's own queue (page backpressure); draining the drive must pull
    that un-prefilled request back and finish it on the other drive.
    (Re-placement is off: this test pins the per-request spill economics
    of a static placement; the replacement path has its own tests.)"""
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, 100, 6).tolist() for _ in range(3)]
    # 6 + 40 tokens → 3 pages/request; a 4-page pool admits one at a time
    clu = make_cluster(cfg, params, ref, n_drives=2, routing="data_local",
                       spill=False, num_pages=4, shard_replacement=False)
    rids = [clu.submit(p, max_new=40, shard_id=1) for p in prompts]
    clu.step()
    # dispatch filled both drive-1 slots, but the pool admitted only one:
    # the second sits un-prefilled in the drive's own queue
    assert clu.stats.drives[1].requests == 1
    assert clu.drives[1].engine.pending == 1
    requeued = clu.drain(1)
    assert requeued == 1
    res = {r.rid: r for r in clu.run_until_complete()}
    assert sorted(res) == rids
    assert res[rids[0]].drive == 1                 # in-flight finished home
    assert res[rids[1]].drive == 0 and res[rids[2]].drive == 0
    assert clu.stats.remote_requests >= 2          # forced off the home
    assert clu.stats.spill_bytes > 0


def test_drain_refunds_spill_of_never_admitted_requests(cfg, params, ref):
    """A remote-charged request that never left the drive's own queue moved
    no bytes: draining the drive must refund its spill charge (in-flight
    remote requests keep theirs — their shard bytes really crossed)."""
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, 100, 6).tolist() for _ in range(4)]
    clu = make_cluster(cfg, params, ref, n_drives=2, routing="round_robin",
                       num_pages=4)
    # every request homes on drive 0; round_robin sends half remote, and
    # the 4-page pool admits only one per drive — the rest queue un-admitted
    rids = [clu.submit(p, max_new=40, shard_id=0) for p in prompts]
    clu.step()
    one_spill = shard_spill_bytes(6, 40, cfg.d_model, 4)
    assert clu.stats.remote_requests == 2
    assert clu.stats.spill_bytes == pytest.approx(2 * one_spill)
    assert clu.drives[1].engine.pending == 1       # un-admitted remote
    assert clu.drain(1) == 1
    assert clu.stats.remote_requests == 1          # refunded
    assert clu.stats.spill_bytes == pytest.approx(one_spill)
    res = {r.rid: r for r in clu.run_until_complete()}
    assert sorted(res) == rids
    # requeued request went home to drive 0: no new charge
    assert clu.stats.remote_requests == 1
    assert clu.stats.spill_bytes == pytest.approx(one_spill)
    # the cluster owns result delivery: drive engines must not leak results
    assert all(d.engine._finished == [] for d in clu.drives)


def test_cluster_submit_validates_like_single_engine(cfg, params, ref):
    clu = make_cluster(cfg, params, ref, n_drives=2)
    with pytest.raises(ValueError, match="empty"):
        clu.submit([])
    with pytest.raises(ValueError, match="max_len"):
        clu.submit(list(range(MAX_LEN)))
    assert clu.pending == 0                        # nothing half-enqueued


def test_fail_restarts_inflight_requests(cfg, params):
    """k_block=1 engines decode one token per tick, so a fail() lands
    mid-flight; the restarted requests must reproduce identical tokens."""
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, 100, n).tolist() for n in (5, 9, 7, 11)]
    ref1 = ServeEngine(cfg, params, max_len=MAX_LEN, num_slots=2, k_block=1)
    want = [r.tokens for r in ref1.generate(prompts, max_new=6)]
    clu = ClusterEngine(cfg, params, n_drives=2, routing="round_robin",
                        jit_donor=ref1, max_len=MAX_LEN, num_slots=2,
                        k_block=1)
    rids = [clu.submit(p, max_new=6) for p in prompts]
    clu.step()
    clu.step()                                   # drive 1 is now mid-decode
    assert clu.stats.drives[1].requests > 0
    n = clu.fail(1)
    assert n > 0                                 # in-flight work requeued
    res = {r.rid: r for r in clu.run_until_complete()}
    assert sorted(res) == rids
    assert all(r.drive == 0 for r in res.values() if r.rid in rids[2:])
    assert [res[r].tokens for r in rids] == want
    # the dead drive's stats stay merged (its ledger bytes happened)
    assert clu.stats.drives[1].ledger.link_bytes > 0
    assert len(clu.stats.drives) == 2


def test_all_drives_down_raises(cfg, params, ref):
    # drain() refuses new work but fails no one: requests stranded behind
    # drained-only drives have no terminal status, so the cluster raises
    clu = make_cluster(cfg, params, ref, n_drives=2)
    clu.submit([1, 2, 3], max_new=2)
    clu.drain(0)
    clu.drain(1)
    with pytest.raises(RuntimeError, match="draining/failed"):
        clu.run_until_complete()


def test_last_drive_fail_finishes_queue_as_failed(cfg, params, ref):
    """fail() of the LAST healthy drive is a terminal event, not a hang:
    queued requests finish with status="failed" and conservation holds."""
    clu = make_cluster(cfg, params, ref, n_drives=2)
    rids = [clu.submit([1, 2, 3], max_new=2), clu.submit([4, 5], max_new=2)]
    clu.fail(0)
    clu.fail(1)
    res = clu.run_until_complete()
    assert sorted(r.rid for r in res) == rids
    assert all(r.status == "failed" and r.tokens == [] for r in res)
    assert clu.stats.failed_requests == len(rids)
    assert clu.stats.completed == 0
    # latency records carry the terminal status too
    assert clu.stats.latency.failed == len(rids)
    assert clu.stats.latency.count == 0
    assert clu.fail(0) == 0                        # idempotent


def test_jit_donor_rejects_mismatched_wiring(cfg, params, ref):
    with pytest.raises(ValueError, match="jit_donor"):
        ServeEngine(cfg, params, max_len=MAX_LEN, num_slots=2, k_block=2,
                    jit_donor=ref)
    with pytest.raises(ValueError, match="jit_donor"):
        ServeEngine(cfg, params, max_len=32, num_slots=2, jit_donor=ref)


def test_generate_validates_shard_ids(cfg, params, ref):
    clu = make_cluster(cfg, params, ref, n_drives=2)
    with pytest.raises(ValueError, match="shard_ids"):
        clu.generate([[1, 2]], max_new=1, shard_ids=[0, 1])
    assert not math.isnan(clu.stats.energy_per_query_mj)


def test_cluster_generate_keeps_earlier_submissions(cfg, params, ref, rng):
    """Same contract as ServeEngine.generate: draining the queue must not
    discard results of requests queued earlier via submit()."""
    clu = make_cluster(cfg, params, ref, n_drives=2)
    p0 = rng.integers(0, cfg.vocab_size, 7).tolist()
    rid0 = clu.submit(p0, max_new=3)
    results = clu.generate([rng.integers(0, cfg.vocab_size, 9).tolist()],
                           max_new=2)
    assert len(results) == 1 and results[0].rid != rid0
    leftover = clu.run_until_complete()
    assert [r.rid for r in leftover] == [rid0]
    assert len(leftover[0].tokens) == 3


# ---------------------------------------------------------------------------
# cluster pull scheduler: heterogeneous rates, speed_factor, shard
# re-placement, spill conservation, compile-free tick accounting
# ---------------------------------------------------------------------------


def test_speed_factor_validated_and_learned(cfg, params, ref, trace):
    """speed_factor must be shape/value-checked, flow into the learned
    per-drive rates (the modeled 2x-slower drive rates lower), and leave
    serving token-identical."""
    with pytest.raises(ValueError, match="speed_factor"):
        ClusterEngine(cfg, params, n_drives=2, jit_donor=ref,
                      max_len=MAX_LEN, num_slots=2, speed_factor=[1.0])
    with pytest.raises(ValueError, match="speed_factor"):
        ClusterEngine(cfg, params, n_drives=2, jit_donor=ref,
                      max_len=MAX_LEN, num_slots=2, speed_factor=[1.0, 0.0])
    prompts, shards = trace
    want = [r.tokens for r in ref.generate(prompts, max_new=8)]
    clu = make_cluster(cfg, params, ref, n_drives=2, routing="round_robin",
                       speed_factor=[1.0, 0.5])
    res = clu.generate(prompts, max_new=8, shard_ids=shards)
    assert [r.tokens for r in res] == want
    r0, r1 = clu.drive_rates()
    assert math.isfinite(r0) and math.isfinite(r1)
    assert r0 > r1                     # the slowed drive rates lower
    assert clu.summary()               # rates render without blowing up


def test_drain_replaces_shards_once_and_saves_link_bytes(cfg, params, ref):
    """After drain(), re-submitting a trace pinned to the drained drive's
    shard must pay ONE migration charge instead of a per-request spill —
    strictly fewer link bytes than the no-replacement path."""
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, 100, 6).tolist() for _ in range(4)]
    one_req = shard_spill_bytes(6, 6, cfg.d_model, 4)
    shard_cost = 2.5 * one_req         # pays off after 3 re-routed requests

    def serve_after_drain(replacement):
        clu = make_cluster(cfg, params, ref, n_drives=2,
                           routing="data_local", spill=False,
                           shard_replacement=replacement,
                           shard_bytes=shard_cost)
        first = clu.generate(prompts, max_new=6, shard_ids=[1] * 4)
        clu.drain(1)
        before = clu.stats.link_bytes
        second = clu.generate(prompts, max_new=6, shard_ids=[1] * 4)
        assert [r.tokens for r in first] == [r.tokens for r in second]
        assert all(r.drive == 0 for r in second)
        return clu, clu.stats.link_bytes - before

    with_rp, paid_with = serve_after_drain(True)
    without_rp, paid_without = serve_after_drain(False)
    # one migration, charged exactly once, replacing ALL per-request spills
    assert with_rp.stats.migrated_shards == 1
    assert with_rp.stats.shard_migration_bytes == pytest.approx(shard_cost)
    assert with_rp.stats.remote_requests == 0
    assert without_rp.stats.migrated_shards == 0
    assert without_rp.stats.remote_requests == 4
    assert without_rp.stats.spill_bytes == pytest.approx(4 * one_req)
    assert paid_with < paid_without
    # with no accepting survivor left (drive 1 already drained), a further
    # drain has nowhere to move the shard — no phantom charge
    with_rp.drain(0)
    assert with_rp.router.home(1) == 0
    assert with_rp.stats.migrated_shards == 1


def test_cold_cluster_energy_matches_warm(cfg, params):
    """The bugfix gate: first-use XLA compiles (decode block, prefill
    buckets, eager splice shapes) must NOT inflate the cluster wall clock
    or the server_power*dt energy integral — a cold cluster's mJ/query has
    to land near a warm one's despite seconds of lazy compile."""
    rng = np.random.default_rng(17)
    # enough requests that steady-state (non-compiling) ticks dominate the
    # integral once the first waves have eaten the lazy compiles
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist()
               for n in (5, 9, 12, 7, 6, 11, 8, 10)]
    # fresh jit closures (no donor): this cluster really compiles lazily
    cold = ClusterEngine(cfg, params, n_drives=2, routing="least_loaded",
                         max_len=MAX_LEN, num_slots=2)
    cold_res = cold.generate(prompts, max_new=8)
    compile_s = sum(d.engine.stats.compile_s for d in cold.drives)
    assert compile_s > 0.5             # the compiles really happened...
    assert cold.stats.cluster_s < compile_s  # ...but never hit the clock
    warm = ClusterEngine(cfg, params, n_drives=2, routing="least_loaded",
                         jit_donor=cold.drives[0].engine, max_len=MAX_LEN,
                         num_slots=2)
    warm_res = warm.generate(prompts, max_new=8)
    assert [r.tokens for r in cold_res] == [r.tokens for r in warm_res]
    cold_mj = cold.stats.energy_per_query_mj
    warm_mj = warm.stats.energy_per_query_mj
    assert warm_mj > 0 and cold_mj > 0
    # without the compile exclusion the cold integral lands ~100x high
    # (seconds of XLA per tick vs milliseconds of serving); a generous
    # band absorbs shared-box wall-clock noise while catching the bug
    assert cold_mj < 5.0 * warm_mj
    assert cold_mj > warm_mj / 10.0


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 40))
def test_spill_ledger_conserved_under_drain_fail(cfg, params, ref_k1, seed):
    """Property: net 'remote shard spill' ledger bytes equal the spill
    bytes of remote dispatches that were ACTUALLY admitted to a drive
    (bytes that really crossed the link), under randomized routing,
    sharding, page backpressure, and drain/fail sequences — every refund
    path must give back exactly what was never moved."""
    rng = np.random.default_rng(seed)
    policy = ("round_robin", "least_loaded",
              "data_local", "rate_aware")[seed % 4]
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).tolist()
               for n in rng.integers(4, 9, 8)]
    shards = [int(s) if s >= 0 else None
              for s in rng.integers(-1, 3, 8)]
    clu = ClusterEngine(cfg, params, n_drives=3, routing=policy,
                        jit_donor=ref_k1, max_len=MAX_LEN, num_slots=2,
                        k_block=1, page_size=4, num_pages=6)
    moved = {"bytes": 0.0, "remote": 0}
    for d in clu.drives:
        def stepped(d=d, orig=d.engine.step):
            res = orig()
            # ground truth, observed independently of the ledger: a
            # request's shard bytes cross the link when a remote-charged
            # dispatch is ADMITTED into a slot (prefill starts)
            for local in d.engine.last_tick.admitted_rids:
                req = clu._inflight[d.rid_map[local]]
                moved["bytes"] += req.spilled_bytes
                moved["remote"] += req.spilled_bytes > 0
            return res
        d.engine.step = stepped
    rids = [clu.submit(p, max_new=3, shard_id=s)
            for p, s in zip(prompts, shards)]
    # random drain/fail schedule on drives 1 and 2 (0 stays up)
    events = []
    for drive in (1, 2):
        if rng.random() < 0.7:
            events.append((int(rng.integers(0, 6)),
                           "fail" if rng.random() < 0.5 else "drain", drive))
    tick = 0
    while clu.queue or any(d.has_work for d in clu.drives):
        for when, kind, drive in events:
            if when == tick:
                getattr(clu, kind)(drive)
        clu.step()
        tick += 1
        assert tick < 500
    res = {r.rid: r for r in clu.run_until_complete()}
    assert sorted(res) == rids
    want = [r.tokens for r in ref_k1.generate(prompts, max_new=3)]
    assert [res[r].tokens for r in rids] == want
    st_ = clu.stats
    assert st_.spill_ledger.notes.get("remote shard spill", 0.0) == \
        pytest.approx(moved["bytes"])
    assert st_.remote_requests == moved["remote"]
    assert st_.shard_migration_bytes == \
        pytest.approx(st_.migrated_shards * clu.shard_bytes)
    assert st_.spill_bytes == pytest.approx(
        moved["bytes"] + st_.shard_migration_bytes)
