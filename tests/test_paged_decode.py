"""Paged KV decode: the fused Pallas ragged kernel (interpret mode) vs the
jnp reference, and the paged serve engine vs the dense-strip engine —
token-identical across random prompt lengths, evictions and refills, with
a balanced free-list and a live-token (not num_slots*max_len) footprint."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import reduced_config
from repro.core import kv_pages
from repro.kernels import ops as kops
from repro.kernels import paged_decode, ref
from repro.models import model as M
from repro.train.serve_loop import AdmissionController, ServeEngine

MAX_LEN = 64


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(reduced_config("yi-9b"), dtype="float32")


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


def make_engine(cfg, params, num_slots=2, **kw):
    kw.setdefault("admission",
                  AdmissionController(num_slots, host_rate=3.0, csd_rate=1.0))
    return ServeEngine(cfg, params, max_len=MAX_LEN, num_slots=num_slots, **kw)


# ---------------------------------------------------------------------------
# Kernel vs reference
# ---------------------------------------------------------------------------


def _random_pool(rng, B, Hkv, dh, P, ps, maxp, dtype=jnp.float32):
    t = lambda *s: jnp.asarray(rng.normal(size=s), dtype)
    kpool, vpool = t(P + 1, ps, Hkv, dh), t(P + 1, ps, Hkv, dh)
    # random non-overlapping page tables with ragged fill levels
    perm = rng.permutation(P)
    tables, cur, used = [], [], 0
    for b in range(B):
        n_alloc = int(rng.integers(0, min(maxp, P - used) + 1))
        row = np.full(maxp, -1, np.int32)
        row[:n_alloc] = perm[used: used + n_alloc]
        used += n_alloc
        tables.append(row)
        hi = n_alloc * ps - 1
        cur.append(int(rng.integers(0, hi + 1)) if hi >= 0 else 0)
    return kpool, vpool, jnp.asarray(np.stack(tables)), \
        jnp.asarray(cur, jnp.int32)


@pytest.mark.fast
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 11])
def test_pallas_paged_decode_matches_ref(rng, dtype, window):
    B, H, Hkv, dh, ps, P, maxp = 3, 8, 4, 16, 8, 12, 5
    q = jnp.asarray(rng.normal(size=(B, H, dh)), dtype)
    kpool, vpool, pages, cur = _random_pool(rng, B, Hkv, dh, P, ps, maxp,
                                            dtype)
    want = paged_decode.paged_decode_partial_ref(q, kpool, vpool, pages, cur,
                                                 window=window)
    got = paged_decode.paged_decode_partial(q, kpool, vpool, pages, cur,
                                            window=window, interpret=True)
    tol = dict(atol=5e-6, rtol=5e-6) if dtype == jnp.float32 \
        else dict(atol=2e-2, rtol=2e-2)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **tol)


@pytest.mark.fast
def test_paged_ref_equals_strip_path(rng):
    """The jnp paged reference must equal the strip-path reference on the
    gathered view — bit-exact (same oracle, same masking)."""
    B, H, Hkv, dh, ps, P, maxp = 2, 4, 2, 16, 4, 8, 4
    q = jnp.asarray(rng.normal(size=(B, H, dh)), jnp.float32)
    kpool, vpool, pages, cur = _random_pool(rng, B, Hkv, dh, P, ps, maxp)
    acc, l, m = paged_decode.paged_decode_partial_ref(q, kpool, vpool, pages,
                                                      cur)
    k, v, kpos = kv_pages.pages_to_strips((kpool, vpool), pages, ps)
    acc2, l2, m2 = ref.decode_partial_masked(q, k, v, kpos, cur)
    for a, b in zip((acc, l, m), (acc2, l2, m2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.fast
def test_ops_dispatch_paged(rng):
    B, H, Hkv, dh, ps, P, maxp = 2, 4, 2, 16, 4, 8, 4
    q = jnp.asarray(rng.normal(size=(B, H, dh)), jnp.float32)
    kpool, vpool, pages, cur = _random_pool(rng, B, Hkv, dh, P, ps, maxp)
    jn = kops.paged_decode_partial(q, kpool, vpool, pages, cur, impl="jnp")
    pk = kops.paged_decode_partial(q, kpool, vpool, pages, cur, impl="pallas")
    for a, b in zip(jn, pk):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-6, rtol=5e-6)


# ---------------------------------------------------------------------------
# Engine: paged == strip, end to end
# ---------------------------------------------------------------------------


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_paged_engine_token_identical_to_strip(cfg, params, seed):
    """Random mixed-length workloads with eviction + refill: the paged
    engine — running the fused K-block loop AND chunked prefill — must emit
    exactly the K=1 strip host-reference loop's tokens, finish with a
    balanced free-list, and peak below the dense worst case."""
    rng = np.random.default_rng(seed)
    n_req = int(rng.integers(4, 7))
    prompts = [rng.integers(0, cfg.vocab_size, int(rng.integers(3, 25))).tolist()
               for _ in range(n_req)]
    max_news = [int(rng.integers(1, 7)) for _ in range(n_req)]

    strip = make_engine(cfg, params, kv_layout="strip", k_block=1)
    paged = make_engine(cfg, params, kv_layout="paged", page_size=8,
                        k_block=8, chunk_prefill=8)
    for p, m in zip(prompts, max_news):
        strip.submit(p, max_new=m)
        paged.submit(p, max_new=m)
    want = {r.rid: r.tokens for r in strip.run_until_complete()}
    got = {r.rid: r.tokens for r in paged.run_until_complete()}
    assert got == want

    paged.pager.check_balanced()                      # eager frees leaked 0
    assert paged.pager.peak_pages <= paged.pager.num_pages
    st_ = paged.stats
    assert st_.kv_bytes_touched < st_.baseline.kv_bytes
    assert 0.0 < st_.kv_reduction <= 1.0
    assert paged.kv_stats()["peak_kv_bytes"] < paged.kv_stats()["dense_kv_bytes"]


def test_paged_engine_eos_eviction_frees_same_step(cfg, params, rng):
    """EOS must return the slot's pages to the pool in the same engine step
    (not at refill): run until the EOS request finishes, then check the
    free-list regained its pages while other slots still decode."""
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist() for n in (8, 10)]
    reference = make_engine(cfg, params).generate(prompts, max_new=6)
    eos = reference[0].tokens[2]
    # k_block=1: per-step ticks, so the EOS tick is observable while the
    # other slot is still mid-decode (the fused-block analogue — pages
    # freed in the same tick the block reports EOS — is in
    # test_decode_block.py)
    engine = make_engine(cfg, params, eos_id=eos, page_size=8, k_block=1)
    for p in prompts:
        engine.submit(p, max_new=6)
    done = []
    while (engine.queue or engine.num_active) and not done:
        done = engine.step()
    assert done and done[0].tokens[-1] == eos
    assert engine.num_active == 1                      # other slot still live
    # only the surviving request's pages remain in use: req 1 holds at most
    # pages_for(10 prompt + 6 new) = 2 pages; lazy eviction would retain
    # req 0's 2 pages as well
    assert engine.pager.num_in_use <= kv_pages.pages_for(
        len(prompts[1]) + 6, engine.page_size)
    assert (engine.page_table >= 0).sum() == engine.pager.num_in_use
    engine.run_until_complete()
    engine.pager.check_balanced()


def test_paged_engine_backpressure_tiny_pool(cfg, params, rng):
    """A pool sized for a single request must serialize admission through
    reservation backpressure — every request still completes, exactly."""
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist()
               for n in (6, 11, 7, 13)]
    max_news = [2, 5, 3, 4]
    want = {}
    strip = make_engine(cfg, params, kv_layout="strip")
    for p, m in zip(prompts, max_news):
        strip.submit(p, max_new=m)
    want = {r.rid: r.tokens for r in strip.run_until_complete()}

    ps = 8
    biggest = max(kv_pages.pages_for(len(p) + m, ps)
                  for p, m in zip(prompts, max_news))
    engine = make_engine(cfg, params, kv_layout="paged", page_size=ps,
                         num_pages=biggest)
    for p, m in zip(prompts, max_news):
        engine.submit(p, max_new=m)
    got = {r.rid: r.tokens for r in engine.run_until_complete()}
    assert got == want
    engine.pager.check_balanced()
    assert engine.pager.peak_pages <= biggest


def test_paged_refill_resets_page_table(cfg, params, rng):
    """Refilling a slot must leave no pages from the old occupant mapped
    (the paged analogue of the strip kpos-reset test)."""
    engine = make_engine(cfg, params, page_size=8)
    long_p = rng.integers(0, cfg.vocab_size, 20).tolist()
    engine.generate([long_p], max_new=4)          # 24 tokens -> 3 pages peak
    assert (engine.page_table == -1).all()        # eager free on completion
    engine.pager.check_balanced()
    assert engine.pager.peak_pages == 3
    short_p = rng.integers(0, cfg.vocab_size, 5).tolist()
    engine.generate([short_p], max_new=1)         # refill needs only 1 page
    assert engine.pager.peak_pages == 3           # no stale pages retained
    engine.pager.check_balanced()


def test_submit_rejects_request_larger_than_pool(cfg, params, rng):
    engine = make_engine(cfg, params, page_size=8, num_pages=1)
    with pytest.raises(ValueError):
        engine.submit(rng.integers(0, cfg.vocab_size, 20).tolist(),
                      max_new=4)


def test_paged_engine_pallas_interpret_token_identical(cfg, params, rng,
                                                       monkeypatch):
    """Force the fused Pallas kernel (interpret mode on CPU) through the
    engine's decode path — INSIDE the fused K-block loop and with chunked
    prefill — and require exactly the K=1 strip host loop's tokens."""
    import functools
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist() for n in (5, 9, 13)]
    want = [r.tokens for r in
            make_engine(cfg, params, kv_layout="strip", k_block=1).generate(
                prompts, max_new=3)]
    monkeypatch.setattr(kops, "paged_decode_partial", functools.partial(
        kops.paged_decode_partial, impl="pallas"))
    got = [r.tokens for r in
           make_engine(cfg, params, kv_layout="paged", page_size=8,
                       k_block=8, chunk_prefill=4)
           .generate(prompts, max_new=3)]
    assert got == want
