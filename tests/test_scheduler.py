"""Scheduler: paper-number reproduction + hypothesis property tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

pytestmark = pytest.mark.fast

from repro.core.scheduler import (Node, PullScheduler, make_cluster,
                                  optimal_batch_ratio, rebalance_shares)
from repro.core.energy import energy_per_query_mj, energy_saving


# --- paper reproduction -----------------------------------------------------

PAPER = {
    # app: (host_rate, csd_rate, batch, items, host_only, with_36, csd_frac)
    "speech": (102.0, 5.3, 6, 225_715, 96.0, 296.0, 0.68),
    "recommender": (600.0, 25.8, 50, 58_000 * 5, 579.0, 1506.0, 0.64),
    "sentiment": (9_800.0, 380.0, 40_000, 8_000_000, 9_496.0, 20_994.0, 0.56),
}


@pytest.mark.parametrize("app", sorted(PAPER))
def test_reproduces_paper_throughput(app):
    host, csd, batch, items, host_only, with36, csd_frac = PAPER[app]
    ratio = optimal_batch_ratio(host, csd)
    nodes = make_cluster(host, csd, 0, host_overhead=0.05, csd_overhead=0.02)
    r0 = PullScheduler(nodes, batch, ratio, poll_interval=0.05).run(items)
    nodes = make_cluster(host, csd, 36, host_overhead=0.05, csd_overhead=0.02)
    r36 = PullScheduler(nodes, batch, ratio, poll_interval=0.05).run(items)
    assert abs(r0.throughput - host_only) / host_only < 0.15, (app, r0.throughput)
    assert abs(r36.throughput - with36) / with36 < 0.15, (app, r36.throughput)
    speedup = r36.throughput / r0.throughput
    paper_speedup = with36 / host_only
    assert abs(speedup - paper_speedup) / paper_speedup < 0.15
    assert abs(r36.csd_fraction - csd_frac) < 0.08, (app, r36.csd_fraction)


def test_reproduces_table1_energy():
    # Table I: energy/query = wall power / throughput (validated exactly)
    assert abs(energy_per_query_mj(96, 0) - 5021) < 2
    assert abs(energy_per_query_mj(296, 36) - 1662) < 2
    assert abs(energy_per_query_mj(579, 0) - 832) < 2
    assert abs(energy_per_query_mj(1506, 36) - 327) < 2
    assert abs(energy_per_query_mj(9496, 0) - 50.8) < 1
    assert abs(energy_per_query_mj(20994, 36) - 23.4) < 1
    assert abs(energy_saving(96, 296) - 0.67) < 0.01
    assert abs(energy_saving(579, 1506) - 0.61) < 0.01
    assert abs(energy_saving(9496, 20994) - 0.54) < 0.01


def test_batch_ratio_matters():
    """Any ratio far from optimal under-utilizes the system (paper claim)."""
    host, csd = 102.0, 5.3
    nodes = make_cluster(host, csd, 36)
    opt = PullScheduler(nodes, 6, optimal_batch_ratio(host, csd),
                        poll_interval=0.05).run(50_000).throughput
    bad = PullScheduler(nodes, 6, 1.0, poll_interval=0.05).run(50_000).throughput
    assert opt > bad * 1.2


# --- property tests ----------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(
    host_rate=st.floats(10, 1000),
    csd_rate=st.floats(1, 100),
    n_csd=st.integers(0, 16),
    batch=st.integers(1, 500),
    items=st.integers(1, 20_000),
)
def test_work_conservation(host_rate, csd_rate, n_csd, batch, items):
    """Every item is processed exactly once; makespan is consistent."""
    nodes = make_cluster(host_rate, csd_rate, n_csd)
    r = PullScheduler(nodes, batch, optimal_batch_ratio(host_rate, csd_rate)
                      ).run(items)
    assert sum(s.items for s in r.per_node.values()) == items
    assert r.makespan >= max(s.busy_s for s in r.per_node.values()) - 1e-6
    slowest = min(n.effective_rate(batch) for n in nodes)
    assert r.throughput <= sum(n.rate for n in nodes) + 1e-6 or True
    assert r.makespan > 0


@settings(max_examples=30, deadline=None)
@given(
    n_csd=st.integers(1, 8),
    items=st.integers(1000, 30_000),
)
def test_adding_csds_never_hurts(n_csd, items):
    nodes0 = make_cluster(100.0, 5.0, 0)
    nodesN = make_cluster(100.0, 5.0, n_csd)
    t0 = PullScheduler(nodes0, 10, 20).run(items).makespan
    tN = PullScheduler(nodesN, 10, 20).run(items).makespan
    assert tN <= t0 * 1.01


@settings(max_examples=50, deadline=None)
@given(
    times=st.dictionaries(st.sampled_from(["a", "b", "c", "d"]),
                          st.floats(0.01, 10.0), min_size=2, max_size=4),
    total=st.integers(8, 4096),
)
def test_rebalance_preserves_total(times, total):
    shares = {w: max(1, total // len(times)) for w in times}
    drift = total - sum(shares.values())
    shares[sorted(shares)[0]] += drift
    new = rebalance_shares(times, shares, total)
    assert sum(new.values()) == total
    assert all(v >= 1 for v in new.values())


def test_rebalance_shifts_toward_fast_worker():
    shares = {"fast": 50, "slow": 50}
    times = {"fast": 1.0, "slow": 4.0}     # fast is 4x quicker
    new = rebalance_shares(times, shares, 100, smoothing=1.0)
    assert new["fast"] > new["slow"]
    assert new["fast"] >= 75


@settings(max_examples=50, deadline=None)
@given(
    times=st.dictionaries(st.sampled_from(["a", "b", "c", "d"]),
                          st.floats(1e-6, 1e6), min_size=2, max_size=4),
    total=st.integers(2, 4096),
    min_share=st.integers(1, 8),
)
def test_rebalance_exact_sum_or_raises(times, total, min_share):
    """Shares sum to exactly ``total`` and never dip below ``min_share``;
    infeasible totals raise instead of silently drifting."""
    shares = {w: max(min_share, total // len(times)) for w in times}
    if total < min_share * len(times):
        with pytest.raises(ValueError):
            rebalance_shares(times, shares, total, min_share=min_share)
        return
    new = rebalance_shares(times, shares, total, min_share=min_share)
    assert sum(new.values()) == total
    assert all(v >= min_share for v in new.values())
    assert set(new) == set(times)


def test_rebalance_cold_start_guard_keeps_current_shares():
    """A worker with no observations yet (zero/NaN service time — e.g. a
    cluster replica that has served nothing) must not poison the refit:
    the current share proportions come back unchanged (settled to the
    exact total) until every worker has data."""
    shares = {"host": 6, "csd": 2}
    for bad in (0.0, -1.0, float("nan"), float("inf")):
        got = rebalance_shares({"host": 0.05, "csd": bad}, shares, 8)
        assert got == shares
        assert got is not shares               # a copy, not an alias
    # all-cold is equally inert
    assert rebalance_shares({"host": 0.0, "csd": 0.0}, shares, 8) == shares
    # the exact-sum contract holds on the guard path too (pool grew)
    got = rebalance_shares({"host": 0.05, "csd": 0.0}, shares, 16)
    assert got == {"host": 12, "csd": 4}
    # infeasible totals still raise, even when cold
    with pytest.raises(ValueError):
        rebalance_shares({"host": 0.0, "csd": 0.0}, shares, 1)
    # with real measurements on both workers the refit engages again
    got = rebalance_shares({"host": 0.01, "csd": 1.0}, shares, 8,
                           smoothing=1.0)
    assert got["host"] > shares["host"]


# --- incremental tick() API ---------------------------------------------------


def test_tick_agrees_with_run_on_makespan():
    nodes = make_cluster(102.0, 5.3, 7, host_overhead=0.05, csd_overhead=0.02)
    sched = PullScheduler(nodes, 6, optimal_batch_ratio(102.0, 5.3),
                          poll_interval=0.05)
    want = sched.run(40_000)
    state = sched.start(40_000)
    n_assignments = 0
    while (a := sched.tick(state)) is not None:
        n_assignments += 1
        assert a.finish >= a.start >= 0.0
        assert a.n_items >= 1
    got = state.result()
    assert got.makespan == want.makespan
    assert got.throughput == want.throughput
    assert {n: s.items for n, s in got.per_node.items()} == \
        {n: s.items for n, s in want.per_node.items()}
    assert n_assignments == sum(s.batches for s in want.per_node.values())


@settings(max_examples=40, deadline=None)
@given(
    n_csd=st.integers(0, 8),
    batch=st.integers(1, 200),
    items=st.integers(1, 10_000),
)
def test_tick_conserves_items(n_csd, batch, items):
    """Every item is assigned exactly once across the tick stream."""
    sched = PullScheduler(make_cluster(100.0, 5.0, n_csd), batch, 20.0)
    state = sched.start(items)
    assigned = 0
    while (a := sched.tick(state)) is not None:
        assigned += a.n_items
    assert assigned == items
    assert state.done
    assert sched.tick(state) is None          # exhausted stream stays None


@settings(max_examples=40, deadline=None)
@given(t=st.floats(0.0, 1e4), poll=st.floats(0.001, 2.0))
def test_quantization_monotone(t, poll):
    """Ack pickup waits for the next wakeup: q(t) ∈ [t, t + poll], and a
    finer poll never delays pickup past a coarser one."""
    sched = PullScheduler(make_cluster(10.0, 1.0, 1), 4, 10.0,
                          poll_interval=poll)
    q = sched._quantize(t)
    assert t - 1e-9 <= q <= t + poll + 1e-9
    finer = PullScheduler(make_cluster(10.0, 1.0, 1), 4, 10.0,
                          poll_interval=poll / 2)
    assert finer._quantize(t) <= q + 1e-9


@settings(max_examples=20, deadline=None)
@given(poll=st.floats(0.01, 1.0), items=st.integers(100, 5000))
def test_coarser_poll_never_speeds_up(poll, items):
    nodes = make_cluster(50.0, 4.0, 3)
    fast = PullScheduler(nodes, 8, 12.0, poll_interval=0.0).run(items)
    slow = PullScheduler(nodes, 8, 12.0, poll_interval=poll).run(items)
    assert slow.makespan >= fast.makespan - 1e-9


@settings(max_examples=50, deadline=None)
@given(host=st.floats(1.0, 10_000.0), csd=st.floats(0.1, 100.0))
def test_optimal_batch_ratio_bounds(host, csd):
    r = optimal_batch_ratio(host, csd)
    assert r == pytest.approx(host / csd)
    assert r > 0
    if host > csd:
        assert r > 1.0


# ---------------------------------------------------------------------------
# K-block service attribution (fused decode loop -> per-step observe samples)
# ---------------------------------------------------------------------------


def test_split_block_service_proportional_and_exact():
    from repro.core.scheduler import split_block_service
    parts = split_block_service(1.0, [4, 4, 2])
    assert parts == pytest.approx([0.4, 0.4, 0.2])
    assert sum(parts) == pytest.approx(1.0)


@settings(max_examples=30, deadline=None)
@given(block_s=st.floats(0.0, 10.0),
       items=st.lists(st.integers(0, 8), min_size=1, max_size=16))
def test_split_block_service_conserves_time(block_s, items):
    from repro.core.scheduler import split_block_service
    parts = split_block_service(block_s, items)
    assert len(parts) == len(items)
    assert all(p >= 0 for p in parts)
    assert sum(parts) == pytest.approx(block_s)
    if sum(items) > 0:
        # a step serving more slots is charged at least as much time
        order = sorted(range(len(items)), key=lambda i: items[i])
        for a, b in zip(order, order[1:]):
            assert parts[a] <= parts[b] + 1e-12


def test_split_block_service_edge_cases():
    """Empty step lists, all-zero steps, and zero-duration blocks must not
    divide by zero or invent time."""
    from repro.core.scheduler import split_block_service
    assert split_block_service(1.0, []) == []                # no steps at all
    assert split_block_service(0.0, []) == []
    # all-idle block: the wall time is spread evenly (nothing ran, but the
    # time still passed and must be conserved)
    assert split_block_service(0.9, [0, 0, 0]) == \
        pytest.approx([0.3, 0.3, 0.3])
    # zero-item steps inside a live block get zero charge
    assert split_block_service(1.0, [2, 0, 2]) == \
        pytest.approx([0.5, 0.0, 0.5])
    # zero-duration block: zero everywhere, lengths preserved
    assert split_block_service(0.0, [3, 1]) == [0.0, 0.0]


# ---------------------------------------------------------------------------
# ClusterAdmission — the cluster-wide pull scheduler (learned per-drive
# rates -> pull quotas, the §IV-A batch-ratio rule drive-vs-drive)
# ---------------------------------------------------------------------------


def test_cluster_admission_validates():
    from repro.core.scheduler import ClusterAdmission
    with pytest.raises(ValueError):
        ClusterAdmission(0)
    with pytest.raises(ValueError):
        ClusterAdmission(2, alpha=0.0)
    ca = ClusterAdmission(2)
    with pytest.raises(KeyError):
        ca.observe(5, 1.0, [1])
    with pytest.raises(ValueError):
        ca.quotas(1, [0, 1])                  # cannot cover both drives
    assert ca.quotas(4, []) == {}


def test_cluster_admission_converges_on_skewed_trace():
    """A drive fed 2x the per-item service time must converge to half the
    rate, and the pull quotas must skew toward the fast drive while
    summing exactly to the budget."""
    import math

    from repro.core.scheduler import ClusterAdmission
    ca = ClusterAdmission(2, alpha=0.2)
    assert all(math.isnan(r) for r in ca.rates())      # cold: no estimates
    # cold-start guard: quotas stay even until every drive is observed
    assert ca.quotas(8, [0, 1]) == {0: 4, 1: 4}
    ca.observe(0, 0.10, [2, 2])                        # 25 ms/item
    assert ca.quotas(8, [0, 1]) == {0: 4, 1: 4}        # drive 1 still cold
    for _ in range(64):                                # 2x-skewed tick trace
        ca.observe(0, 0.10, [2, 2])                    # 25 ms/item
        ca.observe(1, 0.20, [2, 2])                    # 50 ms/item
    r0, r1 = ca.rates()
    assert r0 == pytest.approx(40.0, rel=0.05)
    assert r1 == pytest.approx(20.0, rel=0.05)
    quotas = None
    for _ in range(16):                                # smoothing settles
        quotas = ca.quotas(9, [0, 1])
    assert sum(quotas.values()) == 9
    assert quotas[0] == pytest.approx(6, abs=1)        # ~2:1 split
    assert quotas[0] > quotas[1] >= 1
    # idle/garbage observations never poison the estimate
    ca.observe(0, 0.0, [4])
    ca.observe(0, float("nan"), [4])
    ca.observe(1, 0.5, [0, 0])
    assert ca.rates()[0] == pytest.approx(r0)
    assert ca.rates()[1] == pytest.approx(r1)


def test_cluster_admission_quotas_follow_live_set():
    """Quotas refit over the LIVE drives only (a failed drive drops out),
    and the block wall time is attributed per step via
    split_block_service — a step serving more items contributes a smaller
    per-item time."""
    from repro.core.scheduler import ClusterAdmission
    ca = ClusterAdmission(3, alpha=0.5)
    for _ in range(8):
        ca.observe(0, 0.1, [2, 2])
        ca.observe(1, 0.1, [2, 2])
        ca.observe(2, 0.4, [2, 2])
    q = ca.quotas(6, [0, 1, 2])
    assert sum(q.values()) == 6 and set(q) == {0, 1, 2}
    assert q[2] <= q[0] and q[2] <= q[1]
    q = ca.quotas(6, [0, 1])                           # drive 2 failed
    assert set(q) == {0, 1} and sum(q.values()) == 6
    # per-step attribution: [4, 0] concentrates the same wall time on
    # fewer items than [2, 2] -> same per-item estimate either way
    ca2 = ClusterAdmission(2, alpha=1.0)
    ca2.observe(0, 0.1, [4, 0])
    ca2.observe(1, 0.1, [2, 2])
    assert ca2.rate(0) == pytest.approx(ca2.rate(1))
