"""Multi-device integration tests.

These need >1 XLA device, so they run in a subprocess with
``--xla_force_host_platform_device_count`` (never set in the parent — the
rest of the suite must see one device)."""
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _run(body: str, devices: int = 8, timeout: int = 900):
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import jax, jax.numpy as jnp, numpy as np
        from dataclasses import replace
        from repro.config import reduced_config, ShapeConfig
        from repro.models import model as M
        from repro.sharding import make_plan, make_recipe
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


@pytest.mark.slow
def test_sharded_loss_matches_local():
    out = _run("""
        rng = np.random.default_rng(0)
        for name in ("gemma3-12b", "xlstm-125m", "hymba-1.5b"):
            cfg = replace(reduced_config(name), dtype="float32")
            params = M.init_params(cfg, jax.random.PRNGKey(0))
            B, S = 8, 32
            batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B,S)), jnp.int32),
                     "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B,S)), jnp.int32)}
            loss_ref, _ = M.loss_fn(params, batch, cfg)
            plan = make_plan(mesh, cfg, fsdp=True)
            recipe = make_recipe(plan, cfg, ShapeConfig("t", S, B, "train"))
            with mesh:
                loss_sh, _ = jax.jit(lambda p, b: M.loss_fn(p, b, cfg, recipe))(params, batch)
            assert abs(float(loss_ref) - float(loss_sh)) < 2e-3, (name, float(loss_ref), float(loss_sh))
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_ep_moe_exact_at_full_capacity():
    out = _run("""
        rng = np.random.default_rng(0)
        for name in ("deepseek-v2-236b", "llama4-scout-17b-a16e"):
            cfg = replace(reduced_config(name), dtype="float32")
            cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
            params = M.init_params(cfg, jax.random.PRNGKey(0))
            B, S = 8, 32
            batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B,S)), jnp.int32),
                     "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B,S)), jnp.int32)}
            _, m_ref = M.loss_fn(params, batch, cfg)
            plan = make_plan(mesh, cfg, fsdp=True)
            recipe = make_recipe(plan, cfg, ShapeConfig("t", S, B, "train"))
            with mesh:
                _, m_sh = jax.jit(lambda p, b: M.loss_fn(p, b, cfg, recipe))(params, batch)
            d = abs(float(m_ref["xent"]) - float(m_sh["xent"]))
            assert d < 2e-4, (name, d)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_isp_decode_matches_local_decode():
    out = _run("""
        rng = np.random.default_rng(0)
        for name in ("gemma3-12b", "yi-9b"):
            cfg = replace(reduced_config(name), dtype="float32")
            params = M.init_params(cfg, jax.random.PRNGKey(0))
            B, S = 8, 32
            toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
            caches_l = M.init_caches(cfg, B, S)
            caches_s = M.init_caches(cfg, B, S)
            plan = make_plan(mesh, cfg, fsdp=False)
            recipe = make_recipe(plan, cfg, ShapeConfig("d", S, B, "decode"))
            dec_sh = jax.jit(lambda p, c, t, pos: M.decode_fn(p, c, t, pos, cfg, recipe))
            with mesh:
                for t in range(6):
                    nl, caches_l = M.decode_fn(params, caches_l, toks[:, t:t+1], jnp.int32(t), cfg)
                    ns, caches_s = dec_sh(params, caches_s, toks[:, t:t+1], jnp.int32(t))
                    assert (np.asarray(nl) == np.asarray(ns)).all(), (name, t)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_compiles_small_mesh_all_archs():
    """Every (arch × mode) lowers AND compiles on a 3-axis mesh."""
    out = _run("""
        from repro.launch import steps as S
        from repro.configs import ASSIGNED
        for name in ASSIGNED:
            cfg = reduced_config(name)
            plan = make_plan(mesh, cfg, fsdp=True)
            for shape in (ShapeConfig("t", 32, 8, "train"),
                          ShapeConfig("p", 32, 8, "prefill"),
                          ShapeConfig("d", 32, 8, "decode")):
                recipe = make_recipe(plan, cfg, shape)
                fn, args = S.jitted_step_for(cfg, shape, recipe)
                with mesh:
                    fn.lower(*args).compile()
        print("OK")
    """, timeout=2400)
    assert "OK" in out


@pytest.mark.slow
def test_compressed_psum_matches_psum():
    out = _run("""
        from repro.compat import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.optim import compressed_psum
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
        def f(x):
            key = jax.random.fold_in(jax.random.PRNGKey(0), jax.lax.axis_index("pod"))
            return compressed_psum(x, "pod", key)
        g = shard_map(f, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
                      check_vma=False)
        got = g(x)
        # exact psum of the two pod shards
        want = x[:4] + x[4:]
        want = jnp.concatenate([want, want], axis=0)
        err = float(jnp.abs(got - want).max())
        amax = float(jnp.abs(x).max())
        assert err <= 2 * 2 * amax / 127.0 + 1e-6, err
        print("OK")
    """)
    assert "OK" in out
