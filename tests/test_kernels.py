"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as pallas_flash
from repro.kernels.isp_decode import decode_partial as pallas_decode
from repro.kernels.isp_gather import isp_gather as pallas_gather
from repro.kernels.isp_gather import isp_gather_pool as pallas_pool
from repro.kernels.topk_similarity import topk_similarity as pallas_topk


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
        dict(atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    # (B, Sq, Skv, H, Hkv, dh, window)
    (2, 64, 64, 4, 2, 16, None),
    (1, 100, 100, 4, 4, 8, None),
    (2, 96, 96, 4, 1, 16, 32),
    (1, 48, 48, 2, 2, 32, 16),
])
def test_pallas_flash_vs_oracle(rng, dtype, shape):
    B, Sq, Skv, H, Hkv, dh, win = shape
    t = lambda *s: jnp.asarray(rng.normal(size=s), dtype)
    q, k, v = t(B, Sq, H, dh), t(B, Skv, Hkv, dh), t(B, Skv, Hkv, dh)
    want = ref.naive_attention(q, k, v, window=win)
    got = pallas_flash(q, k, v, window=win, q_block=32, kv_block=32,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("qoff", [0, 64])
def test_chunked_attention_grads_match_naive(rng, qoff):
    B, S, H, Hkv, dh = 2, 64, 4, 2, 16
    t = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    q, k, v = t(B, S, H, dh), t(B, S + qoff, Hkv, dh), t(B, S + qoff, Hkv, dh)
    f_ref = lambda q, k, v: (ref.naive_attention(q, k, v, q_offset=qoff) ** 2).sum()
    f_chk = lambda q, k, v: (ref.chunked_attention(
        q, k, v, q_offset=qoff, q_chunk=16, kv_chunk=16) ** 2).sum()
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g_chk = jax.grad(f_chk, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_chk):
        np.testing.assert_allclose(b, a, atol=5e-4, rtol=5e-4)


def test_chunked_attention_mla_vdim(rng):
    """v head dim != qk head dim (MLA non-absorbed prefill)."""
    B, S, H, dh, dhv = 1, 32, 2, 16, 8
    t = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    q, k, v = t(B, S, H, dh), t(B, S, H, dh), t(B, S, H, dhv)
    want = ref.naive_attention(q, k, v)
    got = ref.chunked_attention(q, k, v, q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 16])
def test_pallas_decode_partial(rng, dtype, window):
    B, S, H, Hkv, dh = 2, 70, 8, 4, 16
    t = lambda *s: jnp.asarray(rng.normal(size=s), dtype)
    q, k, v = t(B, H, dh), t(B, S, Hkv, dh), t(B, S, Hkv, dh)
    kpos = jnp.asarray(np.r_[np.arange(50), -np.ones(20)], jnp.int32)
    want = ref.decode_partial_masked(q, k, v, kpos, jnp.int32(49), window=window)
    got = pallas_decode(q, k, v, kpos, jnp.int32(49), window=window,
                        kv_block=32, interpret=True)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, **_tol(dtype))


def test_decode_partials_combine_to_full(rng):
    """Split KV into spans; combined partials == monolithic attention."""
    B, S, H, Hkv, dh = 2, 64, 8, 4, 16
    t = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    q, k, v = t(B, H, dh), t(B, S, Hkv, dh), t(B, S, Hkv, dh)
    full = ref.decode_attention(q, k, v, kv_valid=50)
    accs, ls, ms = [], [], []
    for i in range(4):
        a, l, m = ref.decode_partial(q, k[:, i * 16:(i + 1) * 16],
                                     v[:, i * 16:(i + 1) * 16], 50,
                                     kv_offset=i * 16)
        accs.append(a), ls.append(l), ms.append(m)
    got = ref.combine_partials(jnp.stack(accs), jnp.stack(ls), jnp.stack(ms))
    np.testing.assert_allclose(got, full, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,voc,d,off", [(33, 64, 40, 16), (7, 16, 8, 0),
                                         (128, 256, 64, 128)])
def test_pallas_gather(rng, dtype, n, voc, d, off):
    table = jnp.asarray(rng.normal(size=(voc, d)), dtype)
    idx = jnp.asarray(rng.integers(-5, voc + off + 5, (n,)), jnp.int32)
    want = ref.isp_gather(table, idx, shard_offset=off)
    got = pallas_gather(table, idx, shard_offset=off, idx_block=8, d_block=16,
                        interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_pallas_gather_pool(rng):
    table = jnp.asarray(rng.normal(size=(64, 40)), jnp.float32)
    idx = jnp.asarray(rng.integers(-10, 120, (33,)), jnp.int32)
    seg = jnp.asarray(rng.integers(0, 7, (33,)), jnp.int32)
    w = jnp.asarray(rng.normal(size=(33,)), jnp.float32)
    want = ref.isp_gather_pool(table, idx, seg, 7, shard_offset=16, weights=w)
    got = pallas_pool(table, idx, seg, 7, shard_offset=16, weights=w,
                      idx_block=8, d_block=16, interpret=True)
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


def test_gather_shards_psum_to_full(rng):
    """ISP invariant: per-shard masked gathers sum to the dense lookup."""
    V, D, shards = 64, 16, 4
    table = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, V, (20,)), jnp.int32)
    want = jnp.take(table, idx, axis=0)
    vloc = V // shards
    got = sum(ref.isp_gather(table[i * vloc:(i + 1) * vloc], idx,
                             shard_offset=i * vloc) for i in range(shards))
    np.testing.assert_allclose(got, want, atol=1e-6)


@pytest.mark.parametrize("Q,N,D,k", [(9, 130, 24, 5), (4, 32, 8, 3)])
def test_pallas_topk(rng, Q, N, D, k):
    qs = jnp.asarray(rng.normal(size=(Q, D)), jnp.float32)
    corpus = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    ws, wi = ref.topk_similarity(qs, corpus, k)
    gs, gi = pallas_topk(qs, corpus, k, q_block=4, corpus_tile=32,
                         interpret=True)
    np.testing.assert_allclose(gs, ws, atol=3e-5, rtol=3e-5)
    assert (np.asarray(gi) == np.asarray(wi)).mean() > 0.9  # ties may swap
