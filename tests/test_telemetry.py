"""Telemetry tier: the opt-in hub, its instrumentation sites, and the
exporters.

Pure hub tests (fast-marked) cover the keyed-span lifecycle
(double-open/double-close counted, never raised), the bounded event
ring, histograms, detection-latency bookkeeping, and the Chrome-trace
structure through ``scripts/trace_report.py`` — the same checks a
Perfetto import would trip over.

Engine-backed tests assert the honesty contracts: tracing changes no
token (greedy decode with the hub attached is identical to the
untraced oracle), every request span closes exactly once under
retry/hedge/shed/cancel, each track's events stay monotone on its own
clock, and a scheduled crash yields a finite detection latency for
BOTH health authorities (virtual-clock detector and heartbeat
watchdog)."""
import dataclasses
import importlib.util
import json
import math
import threading
import time
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.config import reduced_config
from repro.core.faults import DEAD, HEALTHY, FailureDetector, FaultSchedule
from repro.core.runtime import HeartbeatWatchdog
from repro.core.telemetry import NULL_HUB, NullHub, TelemetryHub
from repro.models import model as M
from repro.train.cluster_loop import ClusterEngine
from repro.train.serve_loop import ServeEngine

MAX_LEN = 64
REPO = Path(__file__).resolve().parents[1]


def _trace_report():
    """scripts/ is not a package; load the report tool by path."""
    spec = importlib.util.spec_from_file_location(
        "trace_report", REPO / "scripts" / "trace_report.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# pure: the hub itself
# ---------------------------------------------------------------------------


@pytest.mark.fast
def test_null_hub_is_disabled_and_cheap():
    assert NULL_HUB.enabled is False
    assert isinstance(NULL_HUB, NullHub)
    t0 = time.perf_counter()
    for i in range(100_000):
        if NULL_HUB.enabled:        # the call-site guard pattern
            NULL_HUB.counter("x")
            NULL_HUB.point("t", "n", 0.0, a=i)
    guarded = time.perf_counter() - t0
    # the guarded disabled path is one attribute check per site; even a
    # loaded CI box does 100k of those in well under a second
    assert guarded < 1.0


@pytest.mark.fast
def test_span_lifecycle_double_open_and_double_close_are_counted():
    hub = TelemetryHub()
    hub.open_request(7, 1.0, priority=0)
    assert hub.open_span_count() == 1
    hub.open_request(7, 1.5)            # double open: original kept
    hub.request_point(7, "admit", 2.0, tier="interactive")
    hub.close_request(7, 3.0, "ok", tokens=4)
    hub.close_request(7, 3.5, "ok")     # double close: counted, dropped
    assert hub.open_span_count() == 0
    m = hub.metrics()
    assert m["counters"]["spans.ok"] == 1
    assert m["counters"]["telemetry.span_double_open"] == 1
    assert m["counters"]["telemetry.span_double_close"] == 1
    phases = [e for e in hub.events() if e["ev"] == "phase"]
    assert len(phases) == 1
    (ph,) = phases
    assert ph["name"] == "req7" and ph["t"] == 1.0 and ph["dur"] == 2.0
    # close merges the open attrs with the close attrs plus status
    assert ph["attrs"]["priority"] == 0
    assert ph["attrs"]["tokens"] == 4
    assert ph["attrs"]["status"] == "ok"


@pytest.mark.fast
def test_event_ring_is_bounded_and_drops_are_counted():
    hub = TelemetryHub(capacity=8)
    for i in range(20):
        hub.point("t", "p", float(i))
    assert len(hub.events()) == 8
    assert hub.events_dropped == 12
    assert [e["t"] for e in hub.events()] == [float(i) for i in range(12, 20)]
    with pytest.raises(ValueError, match="capacity"):
        TelemetryHub(capacity=0)


@pytest.mark.fast
def test_histograms_bucket_and_aggregate():
    hub = TelemetryHub()
    for v in (0.0005, 0.002, 0.002, 0.5, 100.0):
        hub.observe("tick_busy_s", v)
    h = hub.metrics()["histograms"]["tick_busy_s"]
    assert h["count"] == 5
    assert h["sum"] == pytest.approx(100.5045)
    assert sum(h["counts"]) == 5
    assert h["counts"][0] == 1          # <= 1ms
    assert h["counts"][1] == 2          # <= 3ms
    assert h["counts"][-1] == 1         # > 30s overflow bin


@pytest.mark.fast
def test_detection_latency_first_transition_per_authority_wins():
    hub = TelemetryHub()
    hub.fault_injected(1, "crash", 2.0, tick=4)
    hub.fault_injected(1, "stall", 9.0, tick=8)    # first injection wins
    hub.health_transition("detector", 1, "healthy", "suspect", 2.5)
    hub.health_transition("detector", 1, "suspect", "dead", 3.25)
    hub.health_transition("detector", 1, "suspect", "dead", 9.0)  # ignored
    hub.health_transition("watchdog", 1, "healthy", "dead", 4.0)
    hub.health_transition("watchdog", 0, "healthy", "suspect", 5.0)  # no inj
    det = hub.metrics()["detection_latency"]
    assert det["detector.drive1"]["kind"] == "crash"
    assert det["detector.drive1"]["suspect_s"] == pytest.approx(0.5)
    assert det["detector.drive1"]["dead_s"] == pytest.approx(1.25)
    assert det["watchdog.drive1"]["dead_s"] == pytest.approx(2.0)
    assert "watchdog.drive0" not in det    # no injection, no latency


@pytest.mark.fast
def test_chrome_trace_structure_loads_through_trace_report(tmp_path):
    hub = TelemetryHub()
    hub.open_request(0, 0.1, priority=1)
    hub.close_request(0, 0.6, "ok", tokens=3)
    hub.phase("drive0", "decode", 0.2, 0.3, steps=2)
    hub.point("coordinator", "fault_injected", 0.4, drive=1)
    hub.counter_sample("coordinator", "queue_depth", 0.5, 2)
    doc = hub.to_chrome_trace()
    assert doc["displayTimeUnit"] == "ms"
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    named = {e["args"]["name"] for e in meta}
    assert named == {"coordinator", "drive0", "requests"}
    # coordinator is always pid 1 so traces line up across runs
    coord = [e for e in meta if e["args"]["name"] == "coordinator"]
    assert all(e["pid"] == 1 for e in coord)
    # timestamps are microseconds
    x = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in x} == {"req0", "decode"}
    req = next(e for e in x if e["name"] == "req0")
    assert req["ts"] == pytest.approx(0.1e6)
    assert req["dur"] == pytest.approx(0.5e6)

    path = tmp_path / "trace.json"
    hub.write_chrome_trace(str(path))
    tr = _trace_report()
    events = tr.load_trace(str(path))
    names = tr.track_names(events)
    assert set(names.values()) == {"coordinator", "drive0", "requests"}
    agg = tr.phase_breakdown(events)
    assert sum(n for n, _ in agg.values()) == 2
    slow = tr.slowest_requests(events, names, top=5)
    assert [e["name"] for e in slow] == ["req0"]
    assert tr.main([str(path), "--top", "3"]) == 0


@pytest.mark.fast
def test_trace_report_rejects_malformed_traces(tmp_path):
    tr = _trace_report()
    bad_phase = tmp_path / "bad_phase.json"
    bad_phase.write_text(json.dumps(
        {"traceEvents": [{"ph": "Q", "pid": 1, "tid": 0, "ts": 0,
                          "name": "x"}]}))
    with pytest.raises(ValueError, match="unknown phase"):
        tr.load_trace(str(bad_phase))
    bad_dur = tmp_path / "bad_dur.json"
    bad_dur.write_text(json.dumps(
        {"traceEvents": [{"ph": "X", "pid": 1, "tid": 0, "ts": 0,
                          "dur": -1.0, "name": "x"}]}))
    with pytest.raises(ValueError, match="bad dur"):
        tr.load_trace(str(bad_dur))
    nan_ts = tmp_path / "nan_ts.json"
    nan_ts.write_text('{"traceEvents": [{"ph": "i", "pid": 1, "tid": 0, '
                      '"ts": NaN, "name": "x"}]}')
    with pytest.raises(ValueError, match="bad ts"):
        tr.load_trace(str(nan_ts))
    assert tr.main([str(bad_phase)]) == 1
    assert tr.main([str(tmp_path / "missing.json")]) == 1


@pytest.mark.fast
def test_hub_is_thread_safe_under_concurrent_writers():
    hub = TelemetryHub(capacity=100_000)
    n, per = 8, 500

    def writer(w):
        for i in range(per):
            hub.counter("hits")
            hub.open_span(("w", w, i), float(i), f"t{w}", f"s{i}")
            hub.close_span(("w", w, i), float(i) + 0.5, "ok")

    threads = [threading.Thread(target=writer, args=(w,)) for w in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    m = hub.metrics()
    assert m["counters"]["hits"] == n * per
    assert m["counters"]["spans.ok"] == n * per
    assert m["open_spans"] == 0
    assert m["counters"].get("telemetry.span_double_close", 0) == 0


# ---------------------------------------------------------------------------
# engine-backed: instrumentation honesty
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(reduced_config("yi-9b"), dtype="float32")


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def ref_k1(cfg, params):
    """k_block=1 oracle/donor: one decode step per tick, so injected
    faults land mid-flight deterministically."""
    return ServeEngine(cfg, params, max_len=MAX_LEN, num_slots=2, k_block=1,
                       prewarm=True)


@pytest.fixture(scope="module")
def trace(cfg, ref_k1):
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist()
               for n in (5, 9, 7, 11)]
    want = [r.tokens for r in ref_k1.generate(prompts, max_new=6)]
    return prompts, want


def _engine(cfg, params, ref, **kw):
    return ServeEngine(cfg, params, jit_donor=ref, max_len=ref.max_len,
                       num_slots=ref.num_slots, k_block=1, **kw)


def _cluster(cfg, params, ref, **kw):
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("num_slots", 2)
    kw.setdefault("k_block", 1)
    kw.setdefault("routing", "round_robin")
    return ClusterEngine(cfg, params, jit_donor=ref, **kw)


def _assert_track_monotone(events):
    """Per track, event times are non-decreasing on that track's own
    clock.  Request spans are exempt: their phase events are emitted at
    CLOSE time stamped with the OPEN time, so overlapping requests close
    out of t0 order by design."""
    last: dict = {}
    for e in events:
        track = e["track"]
        if track in ("requests", "orphans"):
            continue
        assert e["t"] >= last.get(track, -math.inf) - 1e-9, \
            f"track {track} went backwards: {e}"
        last[track] = e["t"]


def test_engine_tracing_is_token_identical_and_closes_every_span(
        cfg, params, ref_k1, trace):
    prompts, want = trace
    hub = TelemetryHub()
    eng = _engine(cfg, params, ref_k1, telemetry=hub)
    got = [r.tokens for r in eng.generate(prompts, max_new=6)]
    assert got == want                  # `want` came from an untraced engine
    m = hub.metrics()
    assert m["counters"]["spans.ok"] == len(prompts)
    assert m["counters"].get("telemetry.span_double_close", 0) == 0
    assert m["open_spans"] == 0
    names = {e["name"] for e in hub.events()}
    assert {"prefill", "decode"} & names
    # first_token precedes every request close
    assert any(e["ev"] == "point" and e["name"] == "first_token"
               for e in hub.events())
    _assert_track_monotone(hub.events())
    # engine tick metrics landed in the registry
    assert m["counters"]["engine.ticks"] > 0
    assert m["counters"]["engine.tokens"] == eng.stats.tokens
    assert m["histograms"]["tick_busy_s"]["count"] > 0


def test_engine_shed_and_cancel_close_spans_exactly_once(cfg, params,
                                                         ref_k1, trace):
    prompts, _ = trace
    hub = TelemetryHub()
    eng = _engine(cfg, params, ref_k1, telemetry=hub)
    # fill both slots so the doomed requests wait in the queue
    rids_ok = [eng.submit(prompts[0], max_new=4),
               eng.submit(prompts[1], max_new=4)]
    rid_shed = eng.submit(prompts[2], max_new=4, deadline_s=1e-9)
    rid_cancel = eng.submit(prompts[3], max_new=4)
    assert eng.cancel(rid_cancel) == 0.0    # still queued: nothing burned
    while eng.queue or eng.num_active:
        eng.step()
    m = hub.metrics()
    assert m["counters"]["spans.ok"] == len(rids_ok)
    assert m["counters"]["spans.shed"] == 1
    assert m["counters"]["spans.canceled"] == 1
    assert m["counters"].get("telemetry.span_double_close", 0) == 0
    assert m["open_spans"] == 0
    shed_phase = next(e for e in hub.events() if e["ev"] == "phase"
                      and e["attrs"].get("status") == "shed")
    assert shed_phase["attrs"]["rid"] == rid_shed
    assert eng.stats.shed_requests == 1


def test_serial_cluster_crash_records_detector_latency_and_retry(
        cfg, params, ref_k1, trace):
    prompts, want = trace
    hub = TelemetryHub()
    faults = FaultSchedule.from_spec(
        [{"drive_id": 1, "kind": "crash", "at_tick": 3}])
    det = FailureDetector(2, suspect_ticks=2, dead_ticks=4,
                          suspect_after_s=math.inf)
    clu = _cluster(cfg, params, ref_k1, n_drives=2, faults=faults,
                   detector=det, telemetry=hub)
    rids = [clu.submit(p, max_new=6) for p in prompts]
    res = {r.rid: r for r in clu.run_until_complete()}
    assert sorted(res) == rids
    assert [res[r].tokens for r in rids] == want
    assert clu.stats.health == [HEALTHY, DEAD]

    m = hub.metrics()
    lat = m["detection_latency"]["detector.drive1"]
    assert lat["kind"] == "crash"
    # the crash is hidden; detection needs silent ticks, so the latency is
    # strictly positive and SUSPECT precedes DEAD on the cluster wall
    assert 0.0 < lat["suspect_s"] <= lat["dead_s"]
    assert math.isfinite(lat["dead_s"])
    # every request span closed ok despite the mid-flight retries
    assert m["counters"]["spans.ok"] == len(rids)
    assert m["counters"].get("telemetry.span_double_close", 0) == 0
    assert m["open_spans"] == 0
    assert m["counters"]["cluster.retries"] == clu.stats.retries > 0
    assert m["counters"]["cluster.drive_failures"] == 1
    retry_pts = [e for e in hub.events()
                 if e["ev"] == "point" and e["name"] == "retry"]
    assert retry_pts and all("from_drive" in e["attrs"] for e in retry_pts)
    _assert_track_monotone(hub.events())
    # per-drive utilization gauges exist and are sane
    for d in (0, 1):
        u = m["gauges"][f"drive.{d}.utilization"]
        assert 0.0 <= u and math.isfinite(u)


def test_concurrent_cluster_crash_records_watchdog_latency_and_valid_trace(
        cfg, params, ref_k1, trace, tmp_path):
    prompts, want = trace
    hub = TelemetryHub()
    faults = FaultSchedule.from_spec(
        [{"drive_id": 1, "kind": "crash", "at_tick": 2}])
    clu = _cluster(cfg, params, ref_k1, n_drives=2, concurrent=True,
                   prewarm=True, faults=faults, max_retries=5,
                   dispatch_timeout_s=0.05, telemetry=hub,
                   watchdog=HeartbeatWatchdog(2, suspect_after_s=0.06,
                                              suspect_misses=3,
                                              dead_after_s=0.5,
                                              dead_misses=60))
    try:
        rids = [clu.submit(p, max_new=6) for p in prompts]
        res = {r.rid: r for r in clu.run_until_complete()}
        assert sorted(res) == rids
        for rid, w in zip(rids, want):
            if res[rid].status == "ok":
                assert res[rid].tokens == w
        assert clu.stats.health[1] == DEAD
    finally:
        clu.close()
    assert not [t for t in threading.enumerate()
                if t.name.startswith("drive-worker-")]

    m = hub.metrics()
    lat = m["detection_latency"]["watchdog.drive1"]
    assert lat["kind"] == "crash"
    assert math.isfinite(lat["dead_s"]) and lat["dead_s"] > 0.0
    if "suspect_s" in lat:              # watchdog may jump straight to DEAD
        assert 0.0 <= lat["suspect_s"] <= lat["dead_s"]
    assert m["open_spans"] == 0
    assert m["counters"].get("telemetry.span_double_close", 0) == 0
    _assert_track_monotone(hub.events())
    # worker heartbeats made it onto the worker tracks, and the crashed
    # worker annotated its own exit
    tracks = {e["track"] for e in hub.events()}
    assert {"worker0", "worker1", "coordinator"} <= tracks
    assert any(e["name"] == "worker_exit" and e["track"] == "worker1"
               for e in hub.events())

    path = tmp_path / "trace.json"
    hub.write_chrome_trace(str(path))
    tr = _trace_report()
    events = tr.load_trace(str(path))
    names = tr.track_names(events)
    assert "requests" in names.values() and "coordinator" in names.values()
    assert tr.main([str(path)]) == 0


def test_hedge_span_settles_exactly_once_with_waste_attr(cfg, params,
                                                         ref_k1, trace):
    prompts, want = trace
    hub = TelemetryHub()
    # the stall outlives the run: the hedged copy must win, the stalled
    # loser is canceled and its burned time booked as hedge waste
    faults = FaultSchedule.from_spec(
        [{"drive_id": 1, "kind": "stall", "at_tick": 2, "duration": 10000}])
    det = FailureDetector(2, suspect_ticks=2, dead_ticks=10 ** 6,
                          suspect_after_s=math.inf)
    clu = _cluster(cfg, params, ref_k1, n_drives=2, faults=faults,
                   detector=det, hedge=True, telemetry=hub)
    rids = [clu.submit(p, max_new=6) for p in prompts[:2]]
    for _ in range(400):
        clu.step()
        if all(r in {x.rid for x in clu._finished} for r in rids):
            break
    got = {r.rid: r for r in clu._finished}
    assert sorted(got) == rids
    assert [got[r].tokens for r in rids] == want[:2]
    assert clu.stats.hedges >= 1 and clu.stats.hedges_won >= 1
    assert clu._hedges == {}

    m = hub.metrics()
    assert m["counters"]["cluster.hedges"] == clu.stats.hedges
    assert m["open_spans"] == 0         # hedge spans settled, none leaked
    hedge_phases = [e for e in hub.events() if e["ev"] == "phase"
                    and e["name"].startswith("hedge")]
    assert len(hedge_phases) == clu.stats.hedges
    # the winner's span closed "ok"; the loser's copy was canceled and the
    # span carries the booked waste either way
    assert all("hedge_wasted_s" in e["attrs"] for e in hedge_phases)
    assert any(e["attrs"]["status"] == "ok" for e in hedge_phases)


def test_tracing_on_equals_tracing_off(cfg, params, ref_k1, trace):
    """The whole-point gate: attaching the hub changes no token."""
    prompts, want = trace
    eng_off = _engine(cfg, params, ref_k1)
    assert eng_off.tele is NULL_HUB and not eng_off.tele.enabled
    off = [r.tokens for r in eng_off.generate(prompts, max_new=6)]
    eng_on = _engine(cfg, params, ref_k1, telemetry=TelemetryHub())
    on = [r.tokens for r in eng_on.generate(prompts, max_new=6)]
    assert on == off == want
