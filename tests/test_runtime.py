"""Concurrent-runtime tier: the drive-worker threads, the heartbeat
watchdog, fault-schedule persistence, race-safe lifecycle, and the
atomic hedge settlement.

Pure tests (watchdog state machine with an injectable fake clock,
jsonl round-trips) are fast-marked; the engine-backed tests run a REAL
two-worker cluster — crashes manifest as thread death (silence on the
monitor channel), hangs really block the worker — and assert the
watchdog's verdicts plus token identity against the fault-free serial
oracle.  Greedy decode makes recovery exactly replayable, so "no work
lost, invented, or corrupted under concurrency" is a literal token
comparison, not a statistic."""
import dataclasses
import math
import threading
import time

import jax
import numpy as np
import pytest

from repro.config import reduced_config
from repro.core.faults import (DEAD, HEALTHY, SUSPECT, FaultEvent,
                               FaultSchedule)
from repro.core.runtime import HeartbeatWatchdog
from repro.models import model as M
from repro.train.cluster_loop import ClusterEngine
from repro.train.serve_loop import ServeEngine

MAX_LEN = 64


# ---------------------------------------------------------------------------
# pure: heartbeat watchdog state machine
# ---------------------------------------------------------------------------


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@pytest.mark.fast
def test_watchdog_miss_counters_suspect_then_dead():
    clk = FakeClock()
    wd = HeartbeatWatchdog(2, suspect_after_s=math.inf, suspect_misses=2,
                           dead_after_s=math.inf, dead_misses=4, clock=clk)
    assert wd.observe(0, replied=True, progressed=True, has_work=True) == \
        (HEALTHY, HEALTHY)
    # silent with work: 2 misses -> SUSPECT, 4 -> DEAD (terminal)
    assert wd.observe(0, False, False, True) == (HEALTHY, HEALTHY)
    assert wd.observe(0, False, False, True) == (HEALTHY, SUSPECT)
    assert wd.suspects == [0]
    # an "alive"-only beat (replied, no progress) is still a miss: a
    # stalled drive answers pings without doing work
    assert wd.observe(0, True, False, True) == (SUSPECT, SUSPECT)
    assert wd.observe(0, False, False, True) == (SUSPECT, DEAD)
    assert wd.dead == [0]
    assert wd.observe(0, True, True, True) == (DEAD, DEAD)  # no resurrection
    assert wd.health[1] == HEALTHY                 # never observed


@pytest.mark.fast
def test_watchdog_wall_silence_thresholds_and_recovery():
    clk = FakeClock()
    wd = HeartbeatWatchdog(1, suspect_after_s=1.0, suspect_misses=10 ** 6,
                           dead_after_s=3.0, dead_misses=10 ** 6, clock=clk)
    wd.observe(0, True, True, True)                # productive at t=0
    clk.t = 0.9
    assert wd.observe(0, False, False, True)[1] == HEALTHY
    clk.t = 1.1
    assert wd.observe(0, False, False, True)[1] == SUSPECT
    # a productive beat clears suspicion AND re-bases the silence timer
    clk.t = 1.2
    assert wd.observe(0, True, True, True)[1] == HEALTHY
    clk.t = 2.1
    assert wd.observe(0, False, False, True)[1] == HEALTHY  # silent 0.9
    clk.t = 4.3
    assert wd.observe(0, False, False, True)[1] == DEAD     # silent 3.1


@pytest.mark.fast
def test_watchdog_lazy_baseline_judges_doa_drive_by_own_timeline():
    # a drive crashed before its FIRST beat must not be killed off the
    # process-start clock: silence is measured from first observation
    clk = FakeClock()
    clk.t = 1000.0                                 # long-running process
    wd = HeartbeatWatchdog(1, suspect_after_s=1.0, suspect_misses=10 ** 6,
                           dead_after_s=3.0, dead_misses=10 ** 6, clock=clk)
    assert wd.observe(0, False, False, True)[1] == HEALTHY  # baseline set
    clk.t = 1002.0
    assert wd.observe(0, False, False, True)[1] == SUSPECT
    clk.t = 1004.0
    assert wd.observe(0, False, False, True)[1] == DEAD


@pytest.mark.fast
def test_watchdog_idle_drives_never_suspected():
    clk = FakeClock()
    wd = HeartbeatWatchdog(1, suspect_after_s=0.5, suspect_misses=1,
                           dead_after_s=2.0, dead_misses=4, clock=clk)
    for clk.t in (1.0, 50.0, 1000.0):
        assert wd.observe(0, replied=False, progressed=False,
                          has_work=False) == (HEALTHY, HEALTHY)
    # idle re-bases the timer: work arriving later starts from scratch
    clk.t = 1000.4
    assert wd.observe(0, False, False, True)[1] == SUSPECT  # misses=1


@pytest.mark.fast
def test_watchdog_validation_and_mark_dead():
    with pytest.raises(ValueError, match="suspect"):
        HeartbeatWatchdog(1, suspect_after_s=0.0)
    with pytest.raises(ValueError, match="dead thresholds"):
        HeartbeatWatchdog(1, suspect_after_s=1.0, dead_after_s=0.5)
    with pytest.raises(ValueError, match="at least one"):
        HeartbeatWatchdog(0)
    wd = HeartbeatWatchdog(3)
    assert wd.dead_after_s == pytest.approx(4 * wd.suspect_after_s)
    assert wd.dead_misses == 4 * wd.suspect_misses
    wd.mark_dead(1)
    assert wd.health == [HEALTHY, DEAD, HEALTHY]
    assert wd.observe(1, True, True, True) == (DEAD, DEAD)


# ---------------------------------------------------------------------------
# pure: fault-schedule persistence + worker-facing queries
# ---------------------------------------------------------------------------


@pytest.mark.fast
def test_fault_schedule_jsonl_round_trip(tmp_path):
    sch = FaultSchedule.from_rates(3, mttf_s=1.0, mttr_s=0.3, seed=5)
    assert sch.events
    path = tmp_path / "faults.jsonl"
    sch.save(path)
    back = FaultSchedule.load(path)
    assert [dataclasses.astuple(e) for e in back.events] == \
        [dataclasses.astuple(e) for e in sch.events]
    # loaded schedules are fresh: delivery state does not round-trip
    tick = next((e.at_tick for e in sch.events if e.tick_based), None)
    if tick is not None:
        first = sch.begins(tick, 0.0)
        assert back.begins(tick, 0.0) == first


@pytest.mark.fast
def test_fault_schedule_load_accepts_legacy_json_list(tmp_path):
    spec = [{"drive_id": 0, "kind": "stall", "at_tick": 2, "duration": 3},
            {"drive_id": 1, "kind": "crash", "at_s": 1.5}]
    legacy = tmp_path / "faults.json"
    legacy.write_text('[{"drive_id": 0, "kind": "stall", "at_tick": 2, '
                      '"duration": 3}, '
                      '{"drive_id": 1, "kind": "crash", "at_s": 1.5}]')
    a = FaultSchedule.load(legacy)
    assert [dataclasses.astuple(e) for e in a.events] == \
        [dataclasses.astuple(e) for e in FaultSchedule.from_spec(spec).events]
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert FaultSchedule.load(empty).events == []


@pytest.mark.fast
def test_worker_hang_event_and_pure_queries_hide_ground_truth():
    with pytest.raises(ValueError, match="duration"):
        FaultEvent(0, "worker_hang", at_tick=1)
    sch = FaultSchedule.from_spec([
        {"drive_id": 0, "kind": "worker_hang", "at_tick": 2,
         "duration": 0.05},
        {"drive_id": 1, "kind": "crash", "at_tick": 3},
    ])
    # pure predicates: repeated calls keep answering (no delivered-set
    # mutation a worker could leak to the watchdog)
    for _ in range(3):
        assert sch.hangs(0, 2, 0.0) == [(0, pytest.approx(0.05))]
        assert sch.hangs(0, 1, 0.0) == []
        assert sch.crash_active(1, 3, 0.0)
        assert not sch.crash_active(1, 2, 0.0)
    # a hung worker reads as stalled (silence) to the serial loop too
    assert sch.stalled(0, 2, 0.0) and not sch.stalled(0, 99, 0.0)
    # ...and the one-shot begins() is untouched by the pure reads
    assert len(sch.begins(2, 0.0)) == 1


# ---------------------------------------------------------------------------
# engine-backed: a real two-worker cluster
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(reduced_config("yi-9b"), dtype="float32")


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def ref_k1(cfg, params):
    """Prewarmed k_block=1 oracle/donor.  Prewarm matters here: a lazy
    XLA compile inside a worker's first tick is seconds of real silence
    on the monitor channel, and the watchdog — correctly — cannot tell a
    compiling drive from a dead one."""
    return ServeEngine(cfg, params, max_len=MAX_LEN, num_slots=2, k_block=1,
                       prewarm=True)


@pytest.fixture(scope="module")
def trace(cfg, ref_k1):
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist()
               for n in (5, 9, 7, 11)]
    want = [r.tokens for r in ref_k1.generate(prompts, max_new=5)]
    return prompts, want


def make_concurrent(cfg, params, ref_k1, n_drives=2, **kw):
    """Concurrent cluster with watchdog thresholds fast enough for tests
    but lenient enough (dead_misses, 0.5s wall) that slow CI machines
    don't false-kill a healthy-but-scheduling-starved worker.  Drives
    prewarm at construction (cheap: the donor's jit cache is hot) — a
    cold drive's first tick is ~0.4s of real silence, which an honest
    watchdog cannot tell from death."""
    kw.setdefault("watchdog", HeartbeatWatchdog(
        n_drives, suspect_after_s=0.06, suspect_misses=3,
        dead_after_s=0.5, dead_misses=60))
    kw.setdefault("dispatch_timeout_s", 0.05)
    kw.setdefault("max_retries", 5)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("num_slots", 2)
    kw.setdefault("k_block", 1)
    kw.setdefault("routing", "round_robin")
    kw.setdefault("prewarm", True)
    return ClusterEngine(cfg, params, jit_donor=ref_k1, n_drives=n_drives,
                         concurrent=True, **kw)


def assert_conserved_and_balanced(clu, res, n_submitted):
    ok = sum(1 for r in res if r.status == "ok")
    shed = sum(1 for r in res if r.status == "shed")
    failed = sum(1 for r in res if r.status == "failed")
    assert n_submitted == ok + shed + failed
    for d in clu.drives:
        if d.failed or not d.has_work:
            assert d.engine.pager.num_in_use == 0
            d.engine.pager.check_balanced()


def test_concurrent_runtime_matches_serial_oracle(cfg, params, ref_k1,
                                                  trace):
    """The tentpole path: real worker threads, measured wall-clock ticks —
    and the exact tokens of the fault-free serial oracle."""
    prompts, want = trace
    with make_concurrent(cfg, params, ref_k1, min_tick_s=0.02) as clu:
        rids = [clu.submit(p, max_new=5) for p in prompts]
        res = {r.rid: r for r in clu.run_until_complete()}
        assert sorted(res) == rids
        assert [res[r].tokens for r in rids] == want
        assert all(r.status == "ok" for r in res.values())
        # ticks are measured wall clock: the two workers genuinely
        # overlapped, so parallel time beat the summed busy time
        assert clu.stats.ticks > 0 and clu.stats.cluster_s > 0.0
        assert clu.stats.cluster_s < clu.stats.serial_s * 0.95
        # the virtual clocks still run (rate-aware routing + prediction)
        assert clu.predicted_parallel_s > 0.0
        assert clu.stats.health == [HEALTHY, HEALTHY]
        assert_conserved_and_balanced(clu, list(res.values()), len(rids))
    # context-manager exit joined the workers
    assert not [t for t in threading.enumerate()
                if t.name.startswith("drive-worker-")]


def test_concurrent_silent_crash_detected_by_watchdog(cfg, params, ref_k1,
                                                      trace):
    """A crashed worker THREAD DIES — no flag is set anywhere the
    coordinator can see.  Only its silence on the monitor channel (missed
    beats + real dispatch timeouts) can convict it."""
    prompts, want = trace
    faults = FaultSchedule.from_spec(
        [{"drive_id": 1, "kind": "crash", "at_tick": 1}])
    with make_concurrent(cfg, params, ref_k1, faults=faults) as clu:
        rids = [clu.submit(p, max_new=5) for p in prompts]
        res = {r.rid: r for r in clu.run_until_complete()}
        assert sorted(res) == rids
        assert [res[r].tokens for r in rids] == want
        assert clu.stats.health == [HEALTHY, DEAD]
        assert clu.stats.auto_failed_drives == 1
        assert clu.stats.retries > 0               # in-flight work restarted
        assert clu.stats.failed_requests == 0
        assert_conserved_and_balanced(clu, list(res.values()), len(rids))
        # the dead worker's thread really exited (not just ignored)
        dead = [w for w in clu._workers if w.drive_id == 1]
        assert dead and not dead[0].is_alive()


def test_concurrent_long_hang_killed_and_close_is_fast(cfg, params, ref_k1,
                                                       trace):
    """A worker_hang really blocks the thread mid-protocol: the command it
    held is lost, the watchdog convicts the silence, survivors replay the
    work — and close() interrupts the 30s sleep instead of waiting it
    out."""
    prompts, want = trace
    faults = FaultSchedule.from_spec(
        [{"drive_id": 1, "kind": "worker_hang", "at_tick": 1,
          "duration": 30.0}])
    clu = make_concurrent(cfg, params, ref_k1, faults=faults)
    try:
        rids = [clu.submit(p, max_new=5) for p in prompts]
        t0 = time.perf_counter()
        res = {r.rid: r for r in clu.run_until_complete()}
        wall = time.perf_counter() - t0
        assert wall < 15.0                         # did NOT serve the hang
        assert sorted(res) == rids
        assert [res[r].tokens for r in rids] == want
        assert clu.stats.health == [HEALTHY, DEAD]
        assert clu.stats.auto_failed_drives == 1
        assert_conserved_and_balanced(clu, list(res.values()), len(rids))
    finally:
        t0 = time.perf_counter()
        clu.close()                                # worker 1 is mid-wait
        assert time.perf_counter() - t0 < 5.0
    assert not [t for t in threading.enumerate()
                if t.name.startswith("drive-worker-")]


def test_concurrent_short_hang_recovers_without_kill(cfg, params, ref_k1,
                                                     trace):
    """A transient hang shorter than the dead threshold: the woken worker
    announces it lost the command, the coordinator re-dispatches, and the
    drive finishes its own work — no fail(), no retries."""
    prompts, want = trace
    faults = FaultSchedule.from_spec(
        [{"drive_id": 1, "kind": "worker_hang", "at_tick": 1,
          "duration": 0.02}])
    with make_concurrent(cfg, params, ref_k1, faults=faults) as clu:
        rids = [clu.submit(p, max_new=5) for p in prompts]
        res = {r.rid: r for r in clu.run_until_complete()}
        assert sorted(res) == rids
        assert [res[r].tokens for r in rids] == want
        assert all(r.status == "ok" for r in res.values())
        assert clu.stats.auto_failed_drives == 0
        assert clu.stats.retries == 0
        assert clu.stats.health == [HEALTHY, HEALTHY]
        hung = [w for w in clu._workers if w.drive_id == 1]
        assert hung and hung[0].hangs_served == 1  # it really slept
        assert_conserved_and_balanced(clu, list(res.values()), len(rids))


def test_lifecycle_close_idempotent_and_step_after_close_raises(
        cfg, params, ref_k1, trace):
    prompts, want = trace
    clu = make_concurrent(cfg, params, ref_k1)
    rids = [clu.submit(p, max_new=5) for p in prompts[:2]]
    res = {r.rid: r for r in clu.run_until_complete()}
    assert sorted(res) == rids
    assert [res[r].tokens for r in rids] == want[:2]
    clu.close()
    clu.close()                                    # idempotent
    clu.shutdown()                                 # alias, also idempotent
    with pytest.raises(RuntimeError, match="closed"):
        clu.step()
    assert not [t for t in threading.enumerate()
                if t.name.startswith("drive-worker-")]


def test_drain_fail_race_from_other_threads(cfg, params, ref_k1, trace):
    """drain()/fail() arriving from OTHER threads mid-run: the epoch
    bump + per-drive locks must keep conservation and leave no orphaned
    in-flight work, and fail() must be idempotent under the race."""
    prompts, want = trace
    with make_concurrent(cfg, params, ref_k1, min_tick_s=0.01) as clu:
        rids = [clu.submit(p, max_new=5) for p in prompts]
        outcomes = []

        def killer():
            time.sleep(0.03)                       # mid-run, mid-tick
            outcomes.append(clu.fail(1))
            outcomes.append(clu.fail(1))           # second call: no-op
            clu.drain(1)                           # drain-after-fail:
            clu.drain(1)                           # idempotent no-ops

        th = threading.Thread(target=killer)
        th.start()
        res = {r.rid: r for r in clu.run_until_complete()}
        th.join()
        assert sorted(res) == rids
        assert len(outcomes) == 2 and outcomes[1] == 0
        # drive 1 is operator-dead; whatever it held was requeued within
        # budget and replayed token-identically on drive 0
        assert clu.stats.health[1] == DEAD
        for i, rid in enumerate(rids):
            if res[rid].status == "ok":
                assert res[rid].tokens == want[i]
        assert_conserved_and_balanced(clu, list(res.values()), len(rids))
    assert not [t for t in threading.enumerate()
                if t.name.startswith("drive-worker-")]


def test_hedge_both_finish_same_instant_resolves_atomically(cfg, params,
                                                            ref_k1, trace):
    """Satellite regression: BOTH copies of a hedged request complete
    inside one joined tick.  Whichever absorption order the monitor queue
    produces, exactly one result is delivered, the loser's burn is booked
    as hedge waste, and no slot or page leaks."""
    prompts, want = trace
    for order in ("primary_first", "hedger_first"):
        clu = ClusterEngine(cfg, params, jit_donor=ref_k1, n_drives=2,
                            routing="round_robin", max_len=MAX_LEN,
                            num_slots=2, k_block=1, hedge=True)
        rid = clu.submit(prompts[0], max_new=5)
        clu.step()                                 # admitted on drive 0
        d0, d1 = clu.drives
        req = clu._inflight[rid]
        # hand-build the hedge (the launch path is covered elsewhere;
        # this test targets the settlement race)
        local = d1.engine.submit(req.prompt, max_new=req.max_new)
        d1.rid_map[local] = rid
        clu._hedges[rid] = (0, 1)
        clu.stats.hedges += 1
        # run BOTH engines to completion: the race's worst case, where
        # the winner settles against an already-finished loser
        fins = {}
        for d in (d0, d1):
            fin = []
            while d.engine.pending or d.engine.num_active:
                fin.extend(d.engine.step())
            fins[d.drive_id] = (fin, d.engine.last_tick)
            d.engine._finished.clear()
        first, second = (d0, d1) if order == "primary_first" else (d1, d0)
        out = []
        for d in (first, second):
            fin, obs = fins[d.drive_id]
            clu._absorb_tick(d, fin, obs, 0.01, out, [], [])
        assert [r.rid for r in out] == [rid]       # exactly one delivery
        assert out[0].tokens == want[0]
        assert out[0].drive == first.drive_id      # first absorbed wins
        assert clu._hedges == {} and clu._hedge_drops == {}
        assert clu.stats.hedges_won + clu.stats.hedges_lost == 1
        assert clu.stats.hedge_wasted_s > 0.0      # loser's burn booked
        for d in (d0, d1):
            assert d.engine.num_active == 0
            assert d.engine.pager.num_in_use == 0
            d.engine.pager.check_balanced()
        clu.close()


def test_fail_recovers_finished_but_unabsorbed_requests(cfg, params, ref_k1,
                                                        trace):
    """Regression: a drive can FINISH a request and die before the
    coordinator absorbs the result — the reply rides a heartbeat that the
    fail()-epoch-bump makes stale, so from the coordinator's view that
    output never existed.  fail() must treat every surviving rid_map
    entry (not just active slots) as lost in-flight work; before the fix
    the request vanished — never retried, never failed out — breaking
    ``submitted == ok + shed + failed`` and making run_until_complete()
    return []."""
    prompts, want = trace
    clu = make_concurrent(cfg, params, ref_k1)
    try:
        rid = clu.submit(prompts[0], max_new=5)
        d = clu.drives[1]
        # hand-dispatch to drive 1 and run ITS engine to completion: the
        # slot frees and the result sits undelivered, exactly the state
        # a discarded late heartbeat leaves behind (no worker threads
        # exist yet — they spawn lazily on the first cluster step)
        req = clu.queue.popleft()
        local = d.engine.submit(req.prompt, max_new=req.max_new)
        d.rid_map[local] = rid
        while d.engine.queue or any(s.active for s in d.engine.slots):
            d.engine.step()
        assert d.engine._finished
        assert not any(s.active for s in d.engine.slots)
        assert clu.fail(1) == 1        # the orphan requeues as a retry
        assert not d.rid_map and not d.engine._finished
        res = clu.run_until_complete()
        assert [r.rid for r in res] == [rid]
        assert res[0].status == "ok" and res[0].tokens == want[0]
        assert clu.stats.retries == 1
        assert_conserved_and_balanced(clu, res, 1)
    finally:
        clu.close()
