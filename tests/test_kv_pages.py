"""Paged KV allocator + device-side helpers: free-list discipline
(exhaustion raises and allocates nothing, free returns pages, double-free
raises), peak tracking, and the gathered-view oracles."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.kv_pages import (KVPagesExhausted, PageAllocator,
                                 gather_pages, pages_for, pages_kpos,
                                 pages_to_strips)

pytestmark = pytest.mark.fast


def test_pages_for():
    assert pages_for(0, 8) == 0
    assert pages_for(1, 8) == 1
    assert pages_for(8, 8) == 1
    assert pages_for(9, 8) == 2
    assert pages_for(64, 16) == 4


def test_alloc_free_roundtrip():
    a = PageAllocator(4, 8)
    got = a.alloc(3)
    assert sorted(got) == [0, 1, 2]          # lowest-id-first (compaction)
    assert a.num_free == 1 and a.num_in_use == 3
    a.free(got[:2])
    assert a.num_free == 3
    # freed low ids are reused before fresh high ids
    assert sorted(a.alloc(2)) == sorted(got[:2])
    a.free([0, 1, 2])
    a.check_balanced()


def test_exhaustion_raises_and_allocates_nothing():
    a = PageAllocator(2, 8)
    a.alloc(1)
    with pytest.raises(KVPagesExhausted):
        a.alloc(2)
    assert a.num_free == 1                   # failed alloc took nothing


def test_double_free_raises():
    a = PageAllocator(2, 8)
    pages = a.alloc(2)
    a.free(pages[:1])
    with pytest.raises(ValueError):
        a.free(pages[:1])
    with pytest.raises(ValueError):
        a.free([99])                         # foreign id
    assert a.num_free == 1                   # failed free changed nothing


def test_peak_tracks_high_water():
    a = PageAllocator(8, 4)
    p1 = a.alloc(3)
    a.free(p1)
    a.alloc(2)
    assert a.peak_pages == 3
    a.alloc(4)
    assert a.peak_pages == 6


def test_check_balanced_detects_leak():
    a = PageAllocator(2, 4)
    a.alloc(1)
    with pytest.raises(AssertionError):
        a.check_balanced()
    a.free(list(a._in_use))
    a.check_balanced()


def test_gather_pages_and_kpos(rng):
    P, ps, d = 5, 4, 3
    pool = jnp.asarray(rng.normal(size=(P + 1, ps, d)), jnp.float32)
    pages = jnp.asarray([[2, 0, -1], [-1, -1, -1]], jnp.int32)
    g = gather_pages(pool, pages)
    assert g.shape == (2, 3 * ps, d)
    np.testing.assert_array_equal(np.asarray(g[0, :ps]), np.asarray(pool[2]))
    np.testing.assert_array_equal(np.asarray(g[0, ps:2 * ps]),
                                  np.asarray(pool[0]))
    kpos = np.asarray(pages_kpos(pages, ps))
    assert kpos[0].tolist() == list(range(2 * ps)) + [-1] * ps
    assert (kpos[1] == -1).all()


def test_pages_to_strips_matches_componentwise(rng):
    P, ps, hkv, dh = 4, 2, 2, 3
    kp = jnp.asarray(rng.normal(size=(P + 1, ps, hkv, dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P + 1, ps, hkv, dh)), jnp.float32)
    pages = jnp.asarray([[1, 3]], jnp.int32)
    k, v, kpos = pages_to_strips((kp, vp), pages, ps)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(
        gather_pages(kp, pages)))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(
        gather_pages(vp, pages)))
    np.testing.assert_array_equal(np.asarray(kpos),
                                  np.asarray(pages_kpos(pages, ps)))
