"""Roofline table: reads the dry-run JSONs (results/dryrun) and prints the
per-(arch × shape × mesh) three-term roofline summary (§Roofline)."""
from __future__ import annotations

import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def run(emit=print):
    emit("table,arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
         "mfu,useful_ratio,GB_per_device")
    if not RESULTS.exists():
        emit("roofline,NO_DRYRUN_RESULTS,run python -m repro.launch.dryrun "
             "--all,,,,,,,,")
        return
    for p in sorted(RESULTS.glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("status") != "ok":
            continue
        r = d["roofline"]
        emit(f"roofline,{d['arch']},{d['shape']},{d['mesh']},"
             f"{r['compute_s']:.4f},{r['memory_s']:.4f},"
             f"{r['collective_s']:.4f},{r['dominant']},{r['mfu']:.4f},"
             f"{r['useful_flops_ratio']:.3f},"
             f"{d['bytes_per_device'] / 1e9:.1f}")


def main():
    run()


if __name__ == "__main__":
    main()
