"""Table I + Fig. 7 reproduction: energy per query, host-only vs 36-CSD ISP,
plus data-transfer accounting (the 68/64/56% in-storage numbers)."""
from __future__ import annotations

from benchmarks.apps import APPS
from repro.core.energy import energy_per_query_mj, energy_saving
from repro.core.scheduler import PullScheduler, make_cluster, optimal_batch_ratio
from repro.core.transfer import host_only_ledger, workload_split_ledger


def run(emit=print):
    emit("table,app,energy_host_mJ,energy_csd_mJ,saving,paper_host_mJ,"
         "paper_csd_mJ,csd_fraction,link_reduction")
    for app in APPS.values():
        ratio = optimal_batch_ratio(app.host_rate, app.csd_rate)
        nodes0 = make_cluster(app.host_rate, app.csd_rate, 0,
                              host_overhead=0.05, csd_overhead=0.02)
        nodes36 = make_cluster(app.host_rate, app.csd_rate, 36,
                               host_overhead=0.05, csd_overhead=0.02)
        items = app.total_items
        t0 = PullScheduler(nodes0, app.batch_size, ratio, 0.05).run(items)
        t36 = PullScheduler(nodes36, app.batch_size, ratio, 0.05).run(items)
        e_host = energy_per_query_mj(t0.throughput, 0)
        e_csd = energy_per_query_mj(t36.throughput, 36)
        led = workload_split_ledger(app.dataset_bytes, t36.csd_fraction,
                                    app.output_bytes)
        base = host_only_ledger(app.dataset_bytes, app.output_bytes)
        emit(f"table1,{app.name},{e_host:.0f},{e_csd:.0f},"
             f"{1 - e_csd / e_host:.2f},{app.paper_energy_host_mj:.0f},"
             f"{app.paper_energy_csd_mj:.0f},{t36.csd_fraction:.2f},"
             f"{led.reduction_vs(base):.2f}")


def main():
    run()


if __name__ == "__main__":
    main()
