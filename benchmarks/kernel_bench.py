"""Kernel microbenchmarks: wall time of the jnp execution paths on CPU
(the Pallas kernels are TPU-target; interpret mode timing is meaningless,
so we time the identical-math jnp paths and report derived items/s)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6      # us


def run(emit=print):
    emit("table,kernel,shape,us_per_call,derived")
    rng = np.random.default_rng(0)

    B, S, H, Hkv, dh = 2, 1024, 8, 4, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, dh)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, dh)), jnp.bfloat16)
    fa = jax.jit(lambda q, k, v: kops.flash_attention(q, k, v, impl="jnp"))
    us = _time(fa, q, k, v)
    emit(f"kernels,flash_attention,B{B}xS{S}xH{H}x{dh},{us:.0f},"
         f"{B * S / us * 1e6:.0f} tok/s")

    qd = jnp.asarray(rng.normal(size=(B, H, dh)), jnp.bfloat16)
    kpos = jnp.arange(S, dtype=jnp.int32)
    dp = jax.jit(lambda q, k, v: kops.decode_partial(
        q, k, v, kpos, jnp.int32(S - 1), impl="jnp"))
    us = _time(dp, qd, k, v)
    emit(f"kernels,isp_decode_partial,B{B}xS{S},{us:.0f},"
         f"{B / us * 1e6:.0f} steps/s")

    table = jnp.asarray(rng.normal(size=(65536, 128)), jnp.bfloat16)
    idx = jnp.asarray(rng.integers(0, 262144, (8192,)), jnp.int32)
    ig = jax.jit(lambda t, i: kops.isp_gather(t, i, shard_offset=65536,
                                              impl="jnp"))
    us = _time(ig, table, idx)
    emit(f"kernels,isp_gather,V65536xD128xN8192,{us:.0f},"
         f"{8192 / us * 1e6:.0f} lookups/s")

    qs = jnp.asarray(rng.normal(size=(256, 128)), jnp.float32)
    corpus = jnp.asarray(rng.normal(size=(58_000, 128)), jnp.float32)
    tk = jax.jit(lambda q, c: kops.topk_similarity(q, c, 10, impl="jnp"))
    us = _time(tk, qs, corpus)
    emit(f"kernels,topk_similarity,Q256xN58000xD128,{us:.0f},"
         f"{256 / us * 1e6:.0f} queries/s")


def main():
    run()


if __name__ == "__main__":
    main()
