"""Shared bench-gating glue: NaN scanning and wall-clock re-measurement.

Every figure bench writes a committed ``BENCH_*.json`` reference payload
and gates on it the same two ways:

  * the payload must be NaN-free — a non-finite metric means a
    degenerate run was committed as the reference (``scan_nan`` /
    ``check_payload``), and the ``bench-guard`` CI tier re-scans every
    committed payload in one pass (``check_tree``);
  * wall-clock gates re-measure a few times before declaring a real
    regression — a loaded CI box can flatten any timing comparison
    (``retry_gate``).

The fig6/fig7/fig8/fig9 benches import these instead of carrying their
own copies; keeping one implementation means a payload that passes one
bench's scan passes them all.
"""
from __future__ import annotations

import json
import math
from pathlib import Path


def scan_nan(obj, path: str = "") -> list:
    """Every non-finite float in a (nested) payload, by dotted path."""
    bad = []
    if isinstance(obj, dict):
        for k, v in obj.items():
            bad += scan_nan(v, f"{path}.{k}" if path else str(k))
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            bad += scan_nan(v, f"{path}[{i}]")
    elif isinstance(obj, float) and not math.isfinite(obj):
        bad.append(path)
    return bad


def check_payload(path: str, emit=print) -> None:
    """bench-guard hook: the committed payload must be NaN-free (a NaN
    means a degenerate run was committed as the reference)."""
    with open(path) as f:
        payload = json.load(f)
    bad = scan_nan(payload)
    if bad:
        raise RuntimeError(f"{path} carries NaN metrics: {bad}")
    emit(f"{path}: NaN-free ({len(payload.get('runs', {}))} runs)")


def check_lint_baseline(path, emit=print) -> None:
    """bench-guard hook for the committed lint baseline: the payload must
    be a ``{"version", "rules"}`` object whose rule ids are all known to
    ``repro.analysis.lint`` and whose suppression counts are non-negative
    ints — a malformed baseline would silently disable the ratchet."""
    from repro.analysis.lint import all_rules
    with open(path) as f:
        payload = json.load(f)
    problems = []
    if not isinstance(payload, dict) or not isinstance(
            payload.get("rules"), dict):
        problems.append("not a {'version', 'rules'} object")
    else:
        known = set(all_rules())
        for rule, entry in sorted(payload["rules"].items()):
            if rule not in known:
                problems.append(f"unknown rule id {rule!r}")
            n = entry.get("suppressions") if isinstance(entry, dict) else None
            if not isinstance(n, int) or isinstance(n, bool) or n < 0:
                problems.append(f"rule {rule!r}: suppressions must be a "
                                f"non-negative int, got {n!r}")
    if problems:
        raise RuntimeError(f"{path} is malformed: {'; '.join(problems)}")
    emit(f"{path}: {len(payload['rules'])} rules, structure ok")


def check_tree(root: str = ".", emit=print) -> None:
    """Scan EVERY committed ``BENCH_*.json`` under ``root`` and fail with
    the full list of offending paths — one loop instead of one hook per
    bench, so a new payload is covered the day it is committed.  Also
    validates ``LINT_BASELINE.json`` structure when present, so the lint
    ratchet is guarded by the same tier."""
    paths = sorted(Path(root).glob("BENCH_*.json"))
    if not paths:
        raise RuntimeError(f"bench-guard found no BENCH_*.json under "
                           f"{root!r} — nothing to guard is itself a "
                           f"regression")
    bad = {}
    for p in paths:
        try:
            with open(p) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            bad[str(p)] = [f"unreadable: {e}"]
            continue
        hits = scan_nan(payload)
        if hits:
            bad[str(p)] = hits
        else:
            emit(f"{p}: NaN-free ({len(payload.get('runs', {}))} runs)")
    if bad:
        lines = "; ".join(f"{p}: {hits}" for p, hits in sorted(bad.items()))
        raise RuntimeError(f"committed bench payloads carry NaN metrics — "
                           f"{lines}")
    baseline = Path(root) / "LINT_BASELINE.json"
    if baseline.exists():
        check_lint_baseline(baseline, emit=emit)
    emit(f"bench-guard: {len(paths)} payloads NaN-free")


def retry_gate(runs, measure_all, gates_pass, emit=print, attempts: int = 3,
               describe=None):
    """Re-measure until the wall-clock gates pass or the budget runs out.

    ``measure_all()`` produces a fresh ``runs`` (shapes are warm by the
    time this is called, so each pass measures steady state) and may run
    its own determinism gates (token identity, conservation) that raise
    immediately — those are not timing noise and get no retry.
    ``gates_pass(runs)`` is the pure predicate; ``describe(runs)`` names
    the miss for the log.  Returns the last ``runs``; the caller's strict
    gate then raises with the real diagnostic if it still fails.
    """
    for attempt in range(attempts):
        if gates_pass(runs):
            break
        why = describe(runs) if describe is not None else "wall-clock gates missed"
        emit(f"{why}, re-measuring ({attempt + 1}/{attempts})")
        runs = measure_all()
    return runs
