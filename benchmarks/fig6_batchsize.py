"""Fig. 6 reproduction: single-node throughput vs batch size (sentiment),
host vs CSD, showing the fixed-overhead amortization the paper measured."""
from __future__ import annotations

from repro.core.scheduler import Node


def run(emit=print):
    emit("table,node,batch_size,throughput")
    host = Node("host", 9_800.0, batch_overhead=2.0, is_host=True)
    csd = Node("csd", 380.0, batch_overhead=2.0)
    for node in (host, csd):
        for batch in (1_000, 4_000, 10_000, 40_000, 100_000):
            emit(f"fig6,{node.name},{batch},{node.effective_rate(batch):.1f}")


def main():
    run()


if __name__ == "__main__":
    main()
