"""Benchmark entry point: one function per paper table/figure.

``python -m benchmarks.run`` prints ``name,...`` CSV for:
  fig5    — throughput vs #CSDs × batch size (3 NLP apps)
  fig6    — single-node batch-size sweep
  table1  — energy per query + data-transfer reduction (incl. Fig. 7)
  kernels — kernel microbenchmarks (us/call + derived rate)
  roofline— per-(arch × shape × mesh) roofline terms from the dry-run
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (fig5_throughput, fig6_batchsize, kernel_bench,
                            roofline_table, table1_energy)
    wanted = set(sys.argv[1:])

    def want(name):
        return not wanted or name in wanted

    if want("fig5"):
        fig5_throughput.run()
    if want("fig6"):
        fig6_batchsize.run()
    if want("table1"):
        table1_energy.run()
    if want("kernels"):
        kernel_bench.run()
    if want("roofline"):
        roofline_table.run()


if __name__ == "__main__":
    main()
