"""The paper's three NLP applications, built on the framework's kernels.

Each app provides:
  * real JAX compute for one query batch (the work a node performs),
  * calibrated single-node rates from the paper (host Xeon vs CSD A53) used
    by the cluster simulation — this container has neither a Xeon server
    nor 36 CSDs, so throughput scaling comes from the discrete-event sim
    driven by the paper's own measured single-node rates (DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops


@dataclass(frozen=True)
class AppSpec:
    name: str
    host_rate: float          # items/s, paper single-node measurement
    csd_rate: float
    batch_size: int           # paper's per-CSD batch
    total_items: int          # dataset size used in the paper run
    dataset_bytes: float
    output_bytes: float
    paper_host_only: float    # Fig. 5 end points
    paper_with_36: float
    paper_csd_fraction: float
    paper_energy_host_mj: float
    paper_energy_csd_mj: float


APPS: Dict[str, AppSpec] = {
    "speech_to_text": AppSpec(
        "speech_to_text", host_rate=102.0, csd_rate=5.3, batch_size=6,
        total_items=225_715, dataset_bytes=3.8e9, output_bytes=1.2e6,
        paper_host_only=96.0, paper_with_36=296.0, paper_csd_fraction=0.68,
        paper_energy_host_mj=5021.0, paper_energy_csd_mj=1662.0),
    "recommender": AppSpec(
        "recommender", host_rate=600.0, csd_rate=25.8, batch_size=50,
        total_items=290_000, dataset_bytes=1.1e9, output_bytes=12e6,
        paper_host_only=579.0, paper_with_36=1506.0, paper_csd_fraction=0.64,
        paper_energy_host_mj=832.0, paper_energy_csd_mj=327.0),
    "sentiment": AppSpec(
        "sentiment", host_rate=9_800.0, csd_rate=380.0, batch_size=40_000,
        total_items=8_000_000, dataset_bytes=1.6e9, output_bytes=8e6,
        paper_host_only=9_496.0, paper_with_36=20_994.0,
        paper_csd_fraction=0.56,
        paper_energy_host_mj=51.0, paper_energy_csd_mj=23.0),
}


# --- real per-batch compute (the work each node would run) -------------------


def recommender_query_batch(rng: np.random.Generator, n_queries: int = 64,
                            corpus: int = 2048, d: int = 128, k: int = 10):
    """Cosine-similarity top-10 over the movie matrix (paper §IV-B2)."""
    q = jnp.asarray(rng.normal(size=(n_queries, d)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(corpus, d)), jnp.float32)
    scores, ids = kops.topk_similarity(q, c, k, impl="jnp")
    return np.asarray(ids)


def sentiment_query_batch(rng: np.random.Generator, n_queries: int = 256,
                          vocab: int = 4096, d: int = 64):
    """Bag-of-embeddings classifier: ISP gather+pool then a linear head —
    the RecSSD-style embedding-bag offload (paper §II)."""
    lens = 12
    idx = jnp.asarray(rng.integers(0, vocab, (n_queries * lens,)), jnp.int32)
    seg = jnp.repeat(jnp.arange(n_queries), lens)
    table = jnp.asarray(rng.normal(size=(vocab, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, 2)), jnp.float32)
    pooled = kops.isp_gather_pool(table, idx, seg, n_queries, impl="jnp")
    logits = pooled @ w
    return np.asarray(jnp.argmax(logits, -1))


def speech_decode_batch(rng: np.random.Generator, n_frames: int = 64,
                        d: int = 80, vocab: int = 512):
    """Greedy CTC-style frame decoding stand-in for the Vosk pipeline."""
    frames = jnp.asarray(rng.normal(size=(1, n_frames, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, vocab)), jnp.float32)
    logits = frames @ w
    return np.asarray(jnp.argmax(logits, -1))
