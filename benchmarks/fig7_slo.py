"""SLO bench: tail latency + goodput under open-loop traffic, FIFO vs
deadline-aware (EDF + shedding) admission.

The paper's serving claim is quality-of-service on a storage server under
real traffic, not closed-loop drain throughput.  This bench generates a
reproducible bursty open-loop trace (``repro.data.workload``), calibrates
its arrival rate to the measured service rate of the box (so "overload"
means the same thing on any machine), and replays it against the serve
engine under three admission configurations:

  fifo      arrival order, no shedding — the pre-SLO engine's behavior:
            during a burst the queue builds and every request behind the
            head eats the full backlog in its TTFT;
  edf       earliest-deadline-first over the queue + shedding of requests
            whose deadline already passed, chunk_budget=1 (the
            decode-protecting setting) — hopeless requests stop stealing
            capacity from ones that can still make their SLO;
  edf_wide  same but chunk_budget=4 — admits long prompts faster at the
            decode tail's expense (reported for the knob's trade-off
            curve, not gated).

``--json`` writes ``BENCH_fig7_slo.json`` and FAILS loudly unless
  * every request completed by both fifo and edf decoded token-identically
    (greedy decode must not depend on admission order),
  * edf's p99 TTFT over the INTERACTIVE (tight-deadline) class is
    strictly better than fifo's — EDF deliberately trades the
    loose-deadline batch tail for the SLO-bearing traffic, so the
    aggregate p99 mixes the win with the price while the class-level p99
    isolates it (both are reported),
  * edf's goodput-under-SLO (deadline-met completions per serving-clock
    second) is at least fifo's within a small noise band,
  * both runs serve at comparable tokens/s (the SLO win must not come from
    a throughput collapse),
  * no metric in the payload is NaN.

Wall-clock gates re-measure (shapes warm) before declaring a regression,
same as the fig5/fig6 benches.  ``--smoke`` is the CI slo-smoke tier: a
tiny trace through the EDF engine, failing on crash, lost requests, or
non-finite latency stats.  ``--check`` re-scans the committed JSON for
NaN without serving anything (the bench-guard hook).
"""
from __future__ import annotations

import dataclasses
import json
import math

from benchmarks._gate import check_payload, retry_gate, scan_nan

ATTEMPTS = 3
TOKS_BAND = (0.5, 2.0)      # edf/fifo tokens/s ratio sanity band
GOODPUT_BAND = 0.95         # edf goodput must be >= fifo * band


def make_setup(seed: int = 0, num_slots: int = 2, max_len: int = 64,
               chunk_prefill: int = 8):
    """Model + params + a prewarmed donor engine (one XLA compile for
    every run) — same reduced config the fig5/fig6 benches serve."""
    import jax

    from repro.config import reduced_config
    from repro.models import model as M
    from repro.train.serve_loop import ServeEngine

    cfg = dataclasses.replace(reduced_config("yi-9b"), dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    ref = ServeEngine(cfg, params, max_len=max_len, num_slots=num_slots,
                      chunk_prefill=chunk_prefill, prewarm=True)
    return cfg, params, ref


def calibrate(cfg, params, ref, seed: int, n_cal: int = 8,
              max_new: int = 8) -> float:
    """Measured seconds per request on THIS box: a closed-loop drain of
    ``n_cal`` trace-like prompts on a fresh warm engine, per the serving
    clock.  Arrival rates and SLO budgets are expressed in this unit, so
    the trace offers the same relative load everywhere."""
    import numpy as np

    from repro.train.serve_loop import ServeEngine

    rng = np.random.default_rng(seed + 99)
    eng = _fresh_engine(cfg, params, ref)
    clock0 = eng.clock
    prompts = [rng.integers(0, cfg.vocab_size,
                            rng.integers(4, 17)).tolist()
               for _ in range(n_cal)]
    eng.generate(prompts, max_new=max_new)
    per_req = (eng.clock - clock0) / n_cal
    if not (per_req > 0.0 and math.isfinite(per_req)):
        raise RuntimeError(f"calibration produced a broken service time: "
                           f"{per_req}")
    return per_req


def _fresh_engine(cfg, params, ref, **kw):
    from repro.train.serve_loop import ServeEngine
    return ServeEngine(cfg, params, jit_donor=ref, max_len=ref.max_len,
                       num_slots=ref.num_slots,
                       chunk_prefill=ref.chunk_prefill, **kw)


def build_trace(cfg, per_req_s: float, n_requests: int, seed: int,
                load: float = 1.2):
    """Bursty open-loop trace calibrated to the box: mean arrival rate is
    ``load`` times the measured service rate (sustained mild overload —
    queues build during bursts, which is exactly where FIFO and EDF
    diverge), and each class's TTFT budget is a small multiple of one
    request's service time."""
    from repro.data.workload import PriorityClass, WorkloadConfig, \
        generate_trace

    classes = (
        PriorityClass("interactive", priority=0, weight=0.7,
                      slo_s=6.0 * per_req_s, prompt_range=(4, 12),
                      max_new_range=(4, 12)),
        PriorityClass("batch", priority=1, weight=0.3,
                      slo_s=30.0 * per_req_s, prompt_range=(16, 40),
                      max_new_range=(8, 24)),
    )
    wl = WorkloadConfig(n_requests=n_requests, vocab_size=cfg.vocab_size,
                        arrival="bursty", rate=load / per_req_s,
                        burst_factor=4.0, duty=0.25,
                        period_s=8.0 * per_req_s, classes=classes,
                        seed=seed)
    return generate_trace(wl)


CONFIGS = {
    "fifo": dict(admission_order="fifo", shed_expired=False, chunk_budget=1),
    "edf": dict(admission_order="edf", shed_expired=True, chunk_budget=1),
    "edf_wide": dict(admission_order="edf", shed_expired=True,
                     chunk_budget=4),
}


def _finite_or_none(x: float):
    return x if math.isfinite(x) else None


def measure(cfg, params, ref, trace, config: dict) -> dict:
    """Replay the trace on a fresh engine under ``config``; return the
    SLO metrics plus the per-request token map for the identity gate."""
    from repro.data.workload import replay_open_loop

    eng = _fresh_engine(cfg, params, ref, **config)
    report = replay_open_loop(eng, trace)
    lat = eng.stats.latency
    wall = report.wall_s
    m = {
        "submitted": report.submitted,
        "completed": report.completed,
        "shed": report.shed,
        "wall_s": wall,
        "tokens": eng.stats.tokens,
        "tokens_per_s": eng.stats.tokens / wall if wall > 0 else 0.0,
        "p50_ttft_s": lat.p50_ttft_s,
        "p95_ttft_s": lat.p95_ttft_s,
        "p99_ttft_s": lat.p99_ttft_s,
        # class-level tails; None (not NaN — the payload must stay
        # NaN-free) when a class had zero completions
        "p99_ttft_interactive_s": _finite_or_none(lat.ttft_p(99, priority=0)),
        "p99_ttft_batch_s": _finite_or_none(lat.ttft_p(99, priority=1)),
        "p99_e2e_s": lat.p99_e2e_s,
        "mean_tpot_s": lat.mean_tpot_s,
        "mean_queue_wait_s": lat.mean_queue_wait_s,
        "slo_met": lat.slo_met,
        "slo_attainment": lat.slo_attainment,
        "goodput_qps": lat.goodput_qps(wall),
        "shed_wasted_s": eng.stats.shed_wasted_s,
    }
    m["_tokens_by_rid"] = {r.rid: r.tokens for r in report.results
                          if r.status == "ok"}
    if report.submitted != len(trace):
        raise RuntimeError(f"replay lost requests: {report.submitted} "
                           f"submitted of {len(trace)}")
    if report.completed + report.shed != report.submitted:
        raise RuntimeError(
            f"requests unaccounted for: {report.completed} ok + "
            f"{report.shed} shed != {report.submitted} submitted")
    return m


def run_slo(emit=print, n_requests: int = 40, seed: int = 0,
            load: float = 1.2, json_path=None, strict: bool = True,
            setup=None):
    """Calibrate, replay the trace under every config, gate, and return
    the JSON payload (see module docstring for the gates)."""
    cfg, params, ref = setup if setup is not None else make_setup(seed)
    per_req_s = calibrate(cfg, params, ref, seed)
    trace = build_trace(cfg, per_req_s, n_requests, seed, load=load)
    emit(f"calibration: {per_req_s * 1e3:.2f} ms/request; offered load "
         f"{load:.2f}x capacity over {n_requests} bursty arrivals")

    def measure_all():
        return {name: measure(cfg, params, ref, trace, config)
                for name, config in CONFIGS.items()}

    runs = measure_all()
    # warm pass then steady-state, like the other benches: the first
    # replay may still hit fresh splice shapes at this trace's lengths
    runs = measure_all()

    emit("table,config,completed,shed,p50_ttft_ms,p99_ttft_ms,"
         "p99_int_ms,goodput_qps,slo_attainment,tokens_per_s")
    for name, m in runs.items():
        p_int = m["p99_ttft_interactive_s"]
        emit(f"fig7_slo,{name},{m['completed']},{m['shed']},"
             f"{m['p50_ttft_s'] * 1e3:.1f},{m['p99_ttft_s'] * 1e3:.1f},"
             f"{'-' if p_int is None else f'{p_int * 1e3:.1f}'},"
             f"{m['goodput_qps']:.2f},{m['slo_attainment']:.3f},"
             f"{m['tokens_per_s']:.1f}")

    if strict:
        # token identity is deterministic — checked on every measurement
        # (including re-measures), and a miss raises instead of retrying
        def measure_checked():
            r = measure_all()
            _gate_identity(r["fifo"], r["edf"])
            return r

        _gate_identity(runs["fifo"], runs["edf"])
        runs = retry_gate(runs, measure_checked,
                          lambda r: _gates_pass(r["fifo"], r["edf"]),
                          emit, attempts=ATTEMPTS,
                          describe=lambda r: "SLO gate missed")
        _gate_strict(runs["fifo"], runs["edf"], emit)

    payload = {
        "bench": "fig7_slo",
        "requests": n_requests,
        "load_factor": load,
        "per_req_s": per_req_s,
        "num_slots": ref.num_slots,
        "chunk_prefill": ref.chunk_prefill,
        "configs": {k: dict(v) for k, v in CONFIGS.items()},
        "runs": {name: {k: v for k, v in m.items()
                        if not k.startswith("_")}
                 for name, m in runs.items()},
    }
    bad = scan_nan(payload)
    if bad:
        raise RuntimeError(f"NaN metrics in the payload: {bad}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        emit(f"wrote {json_path}")
    f_, e_ = runs["fifo"], runs["edf"]
    emit(f"slo: fifo interactive p99 TTFT "
         f"{(_class_p99(f_) or math.nan) * 1e3:.1f} ms / goodput "
         f"{f_['goodput_qps']:.2f} qps -> edf "
         f"{(_class_p99(e_) or math.nan) * 1e3:.1f} ms / "
         f"{e_['goodput_qps']:.2f} qps ({e_['shed']} shed)")
    return payload


def _gate_identity(fifo: dict, edf: dict) -> None:
    """Greedy decode must be admission-order invariant: every request both
    runs completed decoded the same tokens."""
    a, b = fifo["_tokens_by_rid"], edf["_tokens_by_rid"]
    for rid in set(a) & set(b):
        if a[rid] != b[rid]:
            raise RuntimeError(f"request {rid} decoded differently under "
                               f"fifo vs edf: {a[rid]} vs {b[rid]}")


def _class_p99(m: dict):
    return m["p99_ttft_interactive_s"]


def _gates_pass(fifo: dict, edf: dict) -> bool:
    pf, pe = _class_p99(fifo), _class_p99(edf)
    if pf is None or pe is None or not pe < pf:
        return False
    if not edf["goodput_qps"] >= GOODPUT_BAND * fifo["goodput_qps"]:
        return False
    ratio = edf["tokens_per_s"] / max(fifo["tokens_per_s"], 1e-9)
    return TOKS_BAND[0] <= ratio <= TOKS_BAND[1]


def _gate_strict(fifo: dict, edf: dict, emit) -> None:
    pf, pe = _class_p99(fifo), _class_p99(edf)
    if pf is None or pe is None:
        raise RuntimeError(
            f"a run completed no interactive requests (fifo {pf}, edf "
            f"{pe}) — the class-level gate has nothing to compare")
    if not pe < pf:
        raise RuntimeError(
            f"edf interactive p99 TTFT did not beat fifo: {pe * 1e3:.1f} "
            f"vs {pf * 1e3:.1f} ms")
    if not edf["goodput_qps"] >= GOODPUT_BAND * fifo["goodput_qps"]:
        raise RuntimeError(
            f"edf goodput fell below fifo: {edf['goodput_qps']:.2f} vs "
            f"{fifo['goodput_qps']:.2f} qps")
    ratio = edf["tokens_per_s"] / max(fifo["tokens_per_s"], 1e-9)
    if not TOKS_BAND[0] <= ratio <= TOKS_BAND[1]:
        raise RuntimeError(
            f"edf/fifo tokens/s ratio {ratio:.2f} outside the sanity band "
            f"{TOKS_BAND} — the SLO win must not be a throughput artifact")
    emit(f"slo gates: interactive p99 TTFT {pe * 1e3:.1f} < "
         f"{pf * 1e3:.1f} ms, goodput "
         f"{edf['goodput_qps']:.2f} >= {GOODPUT_BAND:.2f}x "
         f"{fifo['goodput_qps']:.2f} qps, tok/s ratio {ratio:.2f}")


def run_smoke(emit=print) -> None:
    """CI slo-smoke: a tiny bursty trace through the EDF engine; fails on
    crash, lost requests, or non-finite latency aggregation."""
    cfg, params, ref = make_setup()
    per_req_s = calibrate(cfg, params, ref, seed=0, n_cal=4, max_new=4)
    trace = build_trace(cfg, per_req_s, n_requests=6, seed=0, load=1.0)
    m = measure(cfg, params, ref, trace, CONFIGS["edf"])
    if m["completed"] < 1:
        raise RuntimeError(f"slo-smoke completed nothing: {m}")
    for key in ("p50_ttft_s", "p99_ttft_s", "goodput_qps",
                "slo_attainment"):
        if not math.isfinite(m[key]):
            raise RuntimeError(f"slo-smoke produced non-finite {key}: "
                               f"{m[key]}")
    if m["p99_ttft_s"] < 0 or m["mean_queue_wait_s"] < 0:
        raise RuntimeError(f"negative latency out of the serving clock: "
                           f"{m}")
    emit(f"slo-smoke: ok ({m['completed']} ok / {m['shed']} shed, p99 TTFT "
         f"{m['p99_ttft_s'] * 1e3:.1f} ms)")


def run_check(path: str, emit=print) -> None:
    """bench-guard hook: the committed payload must be NaN-free."""
    check_payload(path, emit=emit)


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="write the SLO payload + run the acceptance gates")
    ap.add_argument("--json-path", default="BENCH_fig7_slo.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI slo-smoke: tiny trace, EDF engine, no "
                         "wall-clock gates")
    ap.add_argument("--check", action="store_true",
                    help="scan the committed JSON for NaN and exit")
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--load", type=float, default=1.2,
                    help="offered load as a multiple of measured capacity")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.check:
        run_check(args.json_path)
        return
    if args.smoke:
        run_smoke()
        return
    run_slo(n_requests=args.requests, seed=args.seed, load=args.load,
            json_path=args.json_path if args.json else None)


if __name__ == "__main__":
    main()
