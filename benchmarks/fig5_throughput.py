"""Fig. 5 reproduction: throughput vs #CSDs × batch size for the three NLP
apps, via the pull-scheduler simulation calibrated to the paper's
single-node rates.  Emits CSV rows and validates the paper's endpoints."""
from __future__ import annotations

import numpy as np

from benchmarks.apps import APPS
from repro.core.scheduler import PullScheduler, make_cluster, optimal_batch_ratio

CSD_COUNTS = (0, 9, 18, 27, 36)
BATCH_SCALES = (0.5, 1.0, 2.0)


def run(emit=print):
    emit("table,app,n_csds,batch_size,throughput,csd_fraction,speedup,"
         "paper_speedup")
    results = {}
    for app in APPS.values():
        ratio = optimal_batch_ratio(app.host_rate, app.csd_rate)
        items = app.total_items
        base_nodes = make_cluster(app.host_rate, app.csd_rate, 0,
                                  host_overhead=0.05, csd_overhead=0.02)
        base = PullScheduler(base_nodes, app.batch_size, ratio,
                             poll_interval=0.05).run(items).throughput
        for scale in BATCH_SCALES:
            batch = max(1, int(app.batch_size * scale))
            for n in CSD_COUNTS:
                nodes = make_cluster(app.host_rate, app.csd_rate, n,
                                     host_overhead=0.05, csd_overhead=0.02)
                sched = PullScheduler(nodes, batch, ratio, poll_interval=0.05)
                r = sched.run(items)
                speed = r.throughput / base
                paper = app.paper_with_36 / app.paper_host_only \
                    if n == 36 else float("nan")
                emit(f"fig5,{app.name},{n},{batch},{r.throughput:.1f},"
                     f"{r.csd_fraction:.3f},{speed:.2f},{paper:.2f}")
                results[(app.name, n, scale)] = r
    return results


def main():
    run()


if __name__ == "__main__":
    main()
