"""Fig. 5 reproduction: throughput vs #CSDs × batch size for the three NLP
apps, via the pull-scheduler simulation calibrated to the paper's
single-node rates.  Emits CSV rows and validates the paper's endpoints.

``run_engine`` (also ``python -m benchmarks.fig5_throughput --engine``)
drives the same accounting through the *real* continuous-batching serve
engine on a reduced LM: per-tier token throughput plus the live ledger's
link-byte reduction, next to the scheduler-sim numbers above."""
from __future__ import annotations

import numpy as np

from benchmarks.apps import APPS
from repro.core.scheduler import PullScheduler, make_cluster, optimal_batch_ratio

CSD_COUNTS = (0, 9, 18, 27, 36)
BATCH_SCALES = (0.5, 1.0, 2.0)


def run(emit=print):
    emit("table,app,n_csds,batch_size,throughput,csd_fraction,speedup,"
         "paper_speedup")
    results = {}
    for app in APPS.values():
        ratio = optimal_batch_ratio(app.host_rate, app.csd_rate)
        items = app.total_items
        base_nodes = make_cluster(app.host_rate, app.csd_rate, 0,
                                  host_overhead=0.05, csd_overhead=0.02)
        base = PullScheduler(base_nodes, app.batch_size, ratio,
                             poll_interval=0.05).run(items).throughput
        for scale in BATCH_SCALES:
            batch = max(1, int(app.batch_size * scale))
            for n in CSD_COUNTS:
                nodes = make_cluster(app.host_rate, app.csd_rate, n,
                                     host_overhead=0.05, csd_overhead=0.02)
                sched = PullScheduler(nodes, batch, ratio, poll_interval=0.05)
                r = sched.run(items)
                speed = r.throughput / base
                paper = app.paper_with_36 / app.paper_host_only \
                    if n == 36 else float("nan")
                emit(f"fig5,{app.name},{n},{batch},{r.throughput:.1f},"
                     f"{r.csd_fraction:.3f},{speed:.2f},{paper:.2f}")
                results[(app.name, n, scale)] = r
    return results


def _time_decode_phases(engine, iters: int = 3):
    """Double-run timing of the decode hot path: per step, how long the
    host spends *dispatching* the jitted call (time until the call returns
    — the submission-path overhead ZCSD blames for small in-storage ops)
    vs how long the device spends *computing* (additional time until
    ``block_until_ready``).  Re-activates the slot pool with scratch-routed
    writes, so call this only after the workload is done — the engine's
    caches are garbage afterwards."""
    import time as _t

    import jax
    import jax.numpy as jnp

    n = engine.num_slots
    if engine.k_block > 1:
        steps = engine.k_block

        def call():
            # fresh masks each run: rem > k keeps every slot alive for the
            # full block (page rows are freed ⇒ writes go to scratch)
            engine._alive_dev = jnp.ones((n,), bool)
            engine._rem_dev = jnp.full((n,), steps + 1, jnp.int32)
            engine._pos_dev = jnp.ones((n,), jnp.int32)
            engine._tok_dev = jnp.zeros((n,), jnp.int32)
            return engine._decode_block(engine.params, engine.caches,
                                        engine._tok_dev, engine._pos_dev,
                                        engine._alive_dev, engine._rem_dev)

        def keep(out):
            (engine._tok_dev, engine._pos_dev, engine._alive_dev,
             engine._rem_dev, engine.caches) = out[2:]
            return out[0]
    else:
        steps = 1
        toks = jnp.zeros((n, 1), jnp.int32)
        pos = jnp.ones((n,), jnp.int32)

        def call():
            return engine._decode(engine.params, engine.caches, toks, pos)

        def keep(out):
            engine.caches = out[1]
            return out[0]

    jax.block_until_ready(keep(call()))                    # run 1: warm
    dispatch = compute = 0.0
    for _ in range(iters):                                 # run 2+: measure
        t0 = _t.time()
        out = call()
        t1 = _t.time()
        jax.block_until_ready(keep(out))
        t2 = _t.time()
        dispatch += t1 - t0
        compute += t2 - t1
    return {"dispatch_s_per_step": dispatch / (iters * steps),
            "compute_s_per_step": compute / (iters * steps)}


def run_engine(emit=print, n_requests: int = 8, seed: int = 0,
               kv_layout: str = "paged", page_size: int = 16,
               max_new: int = 8, num_slots: int = 4, k_block: int = 8,
               chunk_prefill=None, prewarm: bool = True,
               time_phases: bool = False):
    """Serve mixed-length requests through the continuous-batching engine
    and emit its ledger + KV accounting as CSV (fig5_engine rows).

    Returns (results, stats, kv_stats, phases) — kv_stats carries the
    paged-vs-dense peak KV footprint the ``--json`` mode tracks across PRs;
    phases is the dispatch-vs-compute split (None unless requested)."""
    import dataclasses

    import jax

    from repro.config import reduced_config
    from repro.models import model as M
    from repro.train.serve_loop import AdmissionController, ServeEngine

    cfg = dataclasses.replace(reduced_config("yi-9b"), dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    engine = ServeEngine(
        cfg, params, max_len=64, num_slots=num_slots, kv_layout=kv_layout,
        page_size=page_size, k_block=k_block, chunk_prefill=chunk_prefill,
        prewarm=prewarm,
        admission=AdmissionController(num_slots, host_rate=4.0, csd_rate=1.0))
    prompts = [rng.integers(0, cfg.vocab_size, rng.integers(4, 17)).tolist()
               for _ in range(n_requests)]
    results = engine.generate(prompts, max_new=max_new)
    st = engine.stats
    kv = engine.kv_stats()
    emit("table,layout,tier,requests,tokens,throughput,link_mb,host_link_mb,"
         "link_reduction,peak_kv_mb,dense_kv_mb,kv_reduction")
    for tier in sorted(st.tier_tokens):
        emit(f"fig5_engine,{kv_layout},{tier},{st.tier_requests.get(tier, 0)},"
             f"{st.tier_tokens[tier]},{st.tier_throughput(tier):.2f},"
             f"{st.link_bytes / 1e6:.3f},{st.host_link_bytes / 1e6:.3f},"
             f"{st.link_reduction:.3f},{kv['peak_kv_bytes'] / 1e6:.4f},"
             f"{kv['dense_kv_bytes'] / 1e6:.4f},{st.kv_reduction:.3f}")
    phases = _time_decode_phases(engine) if time_phases else None
    return results, st, kv, phases


def run_engine_compare(emit=print, n_requests: int = 8, seed: int = 0,
                       page_size: int = 16, max_new: int = 8,
                       num_slots: int = 4, k_block: int = 8,
                       chunk_prefill=None, prewarm: bool = True,
                       json_path=None):
    """Paged vs dense-strip engine on the same workload: token identity,
    decode throughput, and peak KV bytes — the perf trajectory record.

    Writes ``json_path`` (BENCH_fig5.json) when given; raises on NaN/zero
    throughput, a token mismatch, or paged decode regressing more than
    1.5x behind strip, so CI's perf-smoke fails loudly."""
    import json
    import math

    def one(layout):
        results, st, kv, phases = run_engine(
            emit=lambda _: None, n_requests=n_requests, seed=seed,
            kv_layout=layout, page_size=page_size, max_new=max_new,
            num_slots=num_slots, k_block=k_block,
            chunk_prefill=chunk_prefill, prewarm=prewarm, time_phases=True)
        tput = st.tokens / max(st.prefill_s + st.decode_s, 1e-9)
        return results, {
            "tokens": st.tokens,
            "tokens_per_s": tput,
            "decode_s": st.decode_s,
            "decode_steps": st.decode_steps,
            "steps_per_s": st.steps_per_s,
            "compile_s": st.compile_s,
            "phases": phases,
            "link_reduction": st.link_reduction,
            "kv_reduction": st.kv_reduction,
            "peak_kv_bytes": kv["peak_kv_bytes"],
            "pool_kv_bytes": kv["pool_kv_bytes"],
            "dense_kv_bytes": kv["dense_kv_bytes"],
        }

    strip_res, strip = one("strip")
    paged_res, paged = one("paged")
    identical = [r.tokens for r in strip_res] == [r.tokens for r in paged_res]
    payload = {
        "bench": "fig5_engine",
        "page_size": page_size,
        "requests": n_requests,
        "max_new": max_new,
        "num_slots": num_slots,
        "k_block": k_block,
        "chunk_prefill": chunk_prefill,
        "tokens_identical": identical,
        "paged": paged,
        "strip": strip,
    }
    for layout in ("paged", "strip"):
        t = payload[layout]["tokens_per_s"]
        if not math.isfinite(t) or t <= 0:
            raise RuntimeError(f"{layout} throughput is broken: {t}")
    if not identical:
        raise RuntimeError("paged decode diverged from strip decode")
    # 50 ms absolute slack: at smoke scale a whole workload decodes in a
    # few ms, where scheduler jitter alone can cross a pure ratio gate —
    # real regressions (PR-2's per-step page push cost ~0.3 s) still trip
    if paged["decode_s"] > 1.5 * strip["decode_s"] + 0.05:
        raise RuntimeError(
            f"paged decode regressed past the 1.5x gate: "
            f"{paged['decode_s']:.3f}s vs strip {strip['decode_s']:.3f}s")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        emit(f"wrote {json_path}")
    emit(f"engine_compare[k_block={k_block}]: "
         f"paged {paged['tokens_per_s']:.1f} tok/s "
         f"({paged['steps_per_s']:.1f} steps/s, peak KV "
         f"{paged['peak_kv_bytes'] / 1e6:.3f} MB) vs strip "
         f"{strip['tokens_per_s']:.1f} tok/s "
         f"(KV {strip['dense_kv_bytes'] / 1e6:.3f} MB); "
         f"tokens identical: {identical}")
    return payload


def run_guard(json_path: str, floor: float = 0.8, emit=print,
              attempts: int = 3):
    """CI bench guard: re-run the committed BENCH workload and fail if
    tokens/s fell below ``floor`` × the committed numbers (either layout).

    The floor ratchets with the committed file (0.8x now that prewarm keeps
    compile time out of the serving numbers); wall-clock noise on a shared
    CI box is handled by best-of-``attempts`` — a real regression fails
    every attempt, scheduler jitter does not."""
    import json

    with open(json_path) as f:
        committed = json.load(f)
    payload = None
    for attempt in range(1, attempts + 1):
        payload = run_engine_compare(
            emit=emit, n_requests=committed["requests"],
            max_new=committed["max_new"], num_slots=committed["num_slots"],
            page_size=committed["page_size"],
            k_block=committed.get("k_block", 1),
            chunk_prefill=committed.get("chunk_prefill"), json_path=None)
        failures = []
        for layout in ("paged", "strip"):
            got = payload[layout]["tokens_per_s"]
            want = committed[layout]["tokens_per_s"]
            emit(f"bench-guard[{layout}]: {got:.1f} tok/s vs committed "
                 f"{want:.1f} (floor {floor:.1f}x = {floor * want:.1f})")
            if got < floor * want:
                failures.append(layout)
        if not failures:
            emit("bench-guard: ok")
            return payload
        if attempt < attempts:
            emit(f"bench-guard: attempt {attempt}/{attempts} missed the "
                 f"floor for {', '.join(failures)}; retrying")
    raise RuntimeError(
        f"bench-guard: {', '.join(failures)} tokens/s fell below "
        f"{floor}x the committed {json_path} in all {attempts} attempts")


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", action="store_true",
                    help="drive the real continuous-batching serve engine")
    ap.add_argument("--json", action="store_true",
                    help="with --engine: compare paged vs strip layouts and "
                         "write BENCH_fig5.json")
    ap.add_argument("--json-path", default="BENCH_fig5.json")
    ap.add_argument("--guard", type=str, default=None, metavar="BENCH_JSON",
                    help="with --engine: re-run the committed workload and "
                         "fail if tokens/s drops below the guard floor")
    ap.add_argument("--guard-floor", type=float, default=0.8)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--num-slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--k-block", type=int, default=8,
                    help="fused decode steps per engine tick (1 = per-step "
                         "host reference loop)")
    ap.add_argument("--chunk-prefill", type=int, default=0,
                    help="split prompts longer than this into per-tick "
                         "chunks (0 = one-shot prefill)")
    ap.add_argument("--no-prewarm", action="store_true",
                    help="skip jit pre-warm (compile lands in decode_s)")
    args = ap.parse_args(argv)
    if not args.engine:
        run()
        return
    chunk = args.chunk_prefill or None
    if args.guard:
        run_guard(args.guard, floor=args.guard_floor)
    elif args.json:
        run_engine_compare(n_requests=args.requests, max_new=args.max_new,
                           num_slots=args.num_slots, page_size=args.page_size,
                           k_block=args.k_block, chunk_prefill=chunk,
                           prewarm=not args.no_prewarm,
                           json_path=args.json_path)
    else:
        run()
        run_engine(n_requests=args.requests, max_new=args.max_new,
                   num_slots=args.num_slots, page_size=args.page_size,
                   k_block=args.k_block, chunk_prefill=chunk,
                   prewarm=not args.no_prewarm)


if __name__ == "__main__":
    main()
