"""Fig. 5 reproduction: throughput vs #CSDs × batch size for the three NLP
apps, via the pull-scheduler simulation calibrated to the paper's
single-node rates.  Emits CSV rows and validates the paper's endpoints.

``run_engine`` (also ``python -m benchmarks.fig5_throughput --engine``)
drives the same accounting through the *real* continuous-batching serve
engine on a reduced LM: per-tier token throughput plus the live ledger's
link-byte reduction, next to the scheduler-sim numbers above."""
from __future__ import annotations

import numpy as np

from benchmarks.apps import APPS
from repro.core.scheduler import PullScheduler, make_cluster, optimal_batch_ratio

CSD_COUNTS = (0, 9, 18, 27, 36)
BATCH_SCALES = (0.5, 1.0, 2.0)


def run(emit=print):
    emit("table,app,n_csds,batch_size,throughput,csd_fraction,speedup,"
         "paper_speedup")
    results = {}
    for app in APPS.values():
        ratio = optimal_batch_ratio(app.host_rate, app.csd_rate)
        items = app.total_items
        base_nodes = make_cluster(app.host_rate, app.csd_rate, 0,
                                  host_overhead=0.05, csd_overhead=0.02)
        base = PullScheduler(base_nodes, app.batch_size, ratio,
                             poll_interval=0.05).run(items).throughput
        for scale in BATCH_SCALES:
            batch = max(1, int(app.batch_size * scale))
            for n in CSD_COUNTS:
                nodes = make_cluster(app.host_rate, app.csd_rate, n,
                                     host_overhead=0.05, csd_overhead=0.02)
                sched = PullScheduler(nodes, batch, ratio, poll_interval=0.05)
                r = sched.run(items)
                speed = r.throughput / base
                paper = app.paper_with_36 / app.paper_host_only \
                    if n == 36 else float("nan")
                emit(f"fig5,{app.name},{n},{batch},{r.throughput:.1f},"
                     f"{r.csd_fraction:.3f},{speed:.2f},{paper:.2f}")
                results[(app.name, n, scale)] = r
    return results


def run_engine(emit=print, n_requests: int = 8, seed: int = 0):
    """Serve mixed-length requests through the continuous-batching engine
    and emit its ledger accounting as CSV (fig5_engine rows)."""
    import dataclasses

    import jax

    from repro.config import reduced_config
    from repro.models import model as M
    from repro.train.serve_loop import AdmissionController, ServeEngine

    cfg = dataclasses.replace(reduced_config("yi-9b"), dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    engine = ServeEngine(
        cfg, params, max_len=64, num_slots=4,
        admission=AdmissionController(4, host_rate=4.0, csd_rate=1.0))
    prompts = [rng.integers(0, cfg.vocab_size, rng.integers(4, 17)).tolist()
               for _ in range(n_requests)]
    results = engine.generate(prompts, max_new=8)
    st = engine.stats
    emit("table,tier,requests,tokens,throughput,link_mb,host_link_mb,"
         "link_reduction")
    for tier in sorted(st.tier_tokens):
        emit(f"fig5_engine,{tier},{st.tier_requests.get(tier, 0)},"
             f"{st.tier_tokens[tier]},{st.tier_throughput(tier):.2f},"
             f"{st.link_bytes / 1e6:.3f},{st.host_link_bytes / 1e6:.3f},"
             f"{st.link_reduction:.3f}")
    return results, st


def main():
    import sys
    run()
    if "--engine" in sys.argv:
        run_engine()


if __name__ == "__main__":
    main()
