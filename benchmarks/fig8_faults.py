"""Chaos bench: cluster serving through injected drive failures.

The paper's Table I deployment is a 36-drive storage server; at that
scale drive failure is routine, so the serving claim only matters if it
survives one.  This bench serves the same closed-loop request set three
times on an N-drive replica cluster sharing one jit donor:

  baseline        fault-free;
  chaos           a seeded FaultSchedule crashes 1 of the N drives
                  mid-trace (tick-based, exactly reproducible); the
                  FailureDetector must notice the silence, declare the
                  drive DEAD, auto-fail() it, and the retry budget must
                  replay its in-flight work on the survivors;
  chaos_no_retry  the same crash with max_retries=0 — the in-flight
                  requests MUST finish status="failed" (the budget
                  provably terminates instead of retrying forever).

``--json`` writes ``BENCH_fig8_faults.json`` and FAILS loudly unless
  * conservation holds in every run:
    ``submitted == ok + shed + failed``;
  * every request either run finished "ok" decoded token-identically to
    the fault-free serial replay on a single engine (greedy decode makes
    recovery exactly replayable);
  * the chaos run's goodput stays inside the proportional band: losing 1
    of N drives mid-trace may cost roughly its share of capacity plus
    retry waste, not a collapse — ``qps_chaos / qps_base`` must be
    within ``GOODPUT_BAND`` around ``(N-1)/N`` (re-measured up to
    ATTEMPTS times, wall-clock gates only);
  * the chaos run auto-failed EXACTLY the crashed drive (health shows
    one DEAD) and chaos_no_retry failed at least one request with zero
    retries granted;
  * no drive's KV page free-list leaked (``check_balanced``);
  * no metric in the payload is NaN.

``--smoke`` is the CI chaos-smoke tier: 2 drives, a handful of requests,
one mid-trace crash — fails on crash, lost requests, broken conservation
or token divergence, no wall-clock gates.  ``--check`` re-scans the
committed JSON for NaN without serving anything (the bench-guard hook).
"""
from __future__ import annotations

import dataclasses
import json
import math

from benchmarks._gate import check_payload, retry_gate, scan_nan

ATTEMPTS = 3
# chaos/baseline qps ratio band around the (N-1)/N proportional loss:
# the lower edge allows detector latency + retry replay waste, the upper
# edge catches a bench that quietly stopped injecting the fault
GOODPUT_BAND = (0.55, 1.35)


def make_setup(seed: int = 0, num_slots: int = 2, max_len: int = 64):
    """Model + params + a prewarmed k_block=1 donor engine (one XLA
    compile for every cluster in the bench).  k_block=1 decodes one token
    per tick, so the crash lands mid-request deterministically."""
    import jax

    from repro.config import reduced_config
    from repro.models import model as M
    from repro.train.serve_loop import ServeEngine

    cfg = dataclasses.replace(reduced_config("yi-9b"), dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    ref = ServeEngine(cfg, params, max_len=max_len, num_slots=num_slots,
                      k_block=1, prewarm=True)
    return cfg, params, ref


def build_requests(cfg, n_requests: int, seed: int):
    import numpy as np

    rng = np.random.default_rng(seed + 7)
    return [rng.integers(0, cfg.vocab_size,
                         int(rng.integers(4, 13))).tolist()
            for _ in range(n_requests)]


def oracle_tokens(ref, prompts, max_new: int):
    """Fault-free serial replay on the donor: rid -> greedy tokens."""
    return {i: r.tokens
            for i, r in enumerate(ref.generate(prompts, max_new=max_new))}


def _detector(n_drives: int):
    """Tick-threshold detector tuned for the bench's short trace: a
    handful of silent ticks is enough evidence (clock thresholds off so
    detection is exactly reproducible tick-for-tick)."""
    from repro.core.faults import FailureDetector

    return FailureDetector(n_drives, suspect_ticks=3, dead_ticks=6,
                           suspect_after_s=math.inf)


def measure(cfg, params, ref, prompts, n_drives: int, max_new: int,
            crash_drive=None, crash_tick: int = 0,
            max_retries: int = 3, oracle=None) -> dict:
    """One closed-loop run; returns the recovery metrics and enforces the
    per-run invariants (conservation, free-list balance, token identity
    of ok results against the oracle)."""
    from repro.core.faults import DEAD, FaultSchedule
    from repro.train.cluster_loop import ClusterEngine

    faults = None
    if crash_drive is not None:
        faults = FaultSchedule.from_spec([
            {"drive_id": crash_drive, "kind": "crash",
             "at_tick": crash_tick}])
    clu = ClusterEngine(cfg, params, n_drives=n_drives, jit_donor=ref,
                        routing="least_loaded", max_len=ref.max_len,
                        num_slots=ref.num_slots, k_block=1,
                        faults=faults, detector=_detector(n_drives),
                        max_retries=max_retries)
    rids = [clu.submit(p, max_new=max_new) for p in prompts]
    results = {r.rid: r for r in clu.run_until_complete()}
    st = clu.stats
    ok = sum(1 for r in results.values() if r.status == "ok")
    shed = sum(1 for r in results.values() if r.status == "shed")
    failed = sum(1 for r in results.values() if r.status == "failed")
    if sorted(results) != rids:
        raise RuntimeError(f"run lost requests: got {len(results)} of "
                           f"{len(rids)}")
    if ok + shed + failed != len(rids):
        raise RuntimeError(f"conservation broken: {ok} ok + {shed} shed + "
                           f"{failed} failed != {len(rids)} submitted")
    for d in clu.drives:
        if d.engine.pager is not None:
            if d.engine.pager.num_in_use != 0:
                raise RuntimeError(
                    f"drive {d.drive_id} leaked "
                    f"{d.engine.pager.num_in_use} KV pages")
            d.engine.pager.check_balanced()
    if oracle is not None:
        for rid, r in results.items():
            if r.status == "ok" and r.tokens != oracle[rid]:
                raise RuntimeError(
                    f"request {rid} diverged from the fault-free replay: "
                    f"{r.tokens} vs {oracle[rid]}")
    wall = st.cluster_s
    return {
        "submitted": len(rids),
        "ok": ok,
        "shed": shed,
        "failed": failed,
        "wall_s": wall,
        "qps": ok / wall if wall > 0 else 0.0,
        "tokens": st.tokens,
        "faults_injected": st.faults_injected,
        "auto_failed_drives": st.auto_failed_drives,
        "health": list(st.health),
        "dead_drives": sum(1 for h in st.health if h == DEAD),
        "retries": st.retries,
        "failed_requests": st.failed_requests,
        "mean_active": st.mean_active,
        "energy_per_query_mj": st.energy_per_query_mj,
        "wasted_s": st.wasted_s,
    }


def run_chaos(emit=print, n_drives: int = 4, n_requests: int = 24,
              max_new: int = 8, crash_tick: int = 8, seed: int = 0,
              json_path=None, strict: bool = True, setup=None):
    """Serve the trace fault-free, under a mid-trace crash, and under the
    same crash with a zero retry budget; gate and return the payload."""
    cfg, params, ref = setup if setup is not None else make_setup(seed)
    prompts = build_requests(cfg, n_requests, seed)
    oracle = oracle_tokens(ref, prompts, max_new)
    crash_drive = n_drives - 1          # deterministic pick: the last drive

    def measure_all():
        return {
            "baseline": measure(cfg, params, ref, prompts, n_drives,
                                max_new, oracle=oracle),
            "chaos": measure(cfg, params, ref, prompts, n_drives, max_new,
                             crash_drive=crash_drive,
                             crash_tick=crash_tick, oracle=oracle),
            "chaos_no_retry": measure(cfg, params, ref, prompts, n_drives,
                                      max_new, crash_drive=crash_drive,
                                      crash_tick=crash_tick, max_retries=0,
                                      oracle=oracle),
        }

    runs = measure_all()
    # warm pass then steady state, like the other benches: the first pass
    # may still trip fresh splice shapes at this trace's prompt lengths
    runs = measure_all()

    emit("table,run,ok,shed,failed,retries,dead,qps,wall_s,wasted_s")
    for name, m in runs.items():
        emit(f"fig8_faults,{name},{m['ok']},{m['shed']},{m['failed']},"
             f"{m['retries']},{m['dead_drives']},{m['qps']:.2f},"
             f"{m['wall_s']:.3f},{m['wasted_s']:.3f}")

    if strict:
        # recovery gates are deterministic — checked on every measurement
        # (including re-measures), and a miss raises instead of retrying
        def measure_checked():
            r = measure_all()
            _gate_recovery(r, n_drives)
            return r

        _gate_recovery(runs, n_drives)
        runs = retry_gate(runs, measure_checked,
                          lambda r: _band_pass(r, n_drives),
                          emit, attempts=ATTEMPTS,
                          describe=lambda r: "goodput band missed")
        _gate_band(runs, n_drives, emit)

    payload = {
        "bench": "fig8_faults",
        "n_drives": n_drives,
        "requests": n_requests,
        "max_new": max_new,
        "crash_drive": crash_drive,
        "crash_tick": crash_tick,
        "seed": seed,
        "goodput_band": list(GOODPUT_BAND),
        "runs": runs,
    }
    bad = scan_nan(payload)
    if bad:
        raise RuntimeError(f"NaN metrics in the payload: {bad}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        emit(f"wrote {json_path}")
    b, c = runs["baseline"], runs["chaos"]
    emit(f"chaos: killed drive {crash_drive} of {n_drives} at tick "
         f"{crash_tick}; goodput {b['qps']:.2f} -> {c['qps']:.2f} qps "
         f"({c['retries']} retries, {c['failed']} failed, "
         f"{runs['chaos_no_retry']['failed']} failed with no budget)")
    return payload


def _gate_recovery(runs: dict, n_drives: int) -> None:
    """The determinism-independent gates (no wall-clock in them)."""
    b, c, z = runs["baseline"], runs["chaos"], runs["chaos_no_retry"]
    if b["failed"] or b["dead_drives"] or b["faults_injected"]:
        raise RuntimeError(f"baseline was not fault-free: {b}")
    if b["ok"] != b["submitted"]:
        raise RuntimeError(f"baseline shed/lost work: {b}")
    if c["faults_injected"] != 1 or c["auto_failed_drives"] != 1 \
            or c["dead_drives"] != 1:
        raise RuntimeError(
            f"chaos run did not kill exactly one drive: {c}")
    if c["retries"] < 1:
        raise RuntimeError(
            f"the crash landed on no in-flight work (retries=0) — move "
            f"crash_tick into the trace: {c}")
    if c["ok"] != c["submitted"]:
        raise RuntimeError(
            f"chaos run lost requests despite a sufficient retry budget: "
            f"{c}")
    # retry budget termination: with max_retries=0 the crashed drive's
    # in-flight work MUST fail out (and the run must have terminated for
    # us to even be here)
    if z["failed"] < 1 or z["retries"] != 0:
        raise RuntimeError(
            f"zero retry budget did not fail-fast: {z}")
    if z["ok"] + z["failed"] != z["submitted"]:
        raise RuntimeError(f"no-retry conservation broken: {z}")


def _ratio(runs: dict) -> float:
    return runs["chaos"]["qps"] / max(runs["baseline"]["qps"], 1e-9)


def _band(n_drives: int):
    prop = (n_drives - 1) / n_drives
    return GOODPUT_BAND[0] * prop, GOODPUT_BAND[1]


def _band_pass(runs: dict, n_drives: int) -> bool:
    lo, hi = _band(n_drives)
    return lo <= _ratio(runs) <= hi


def _gate_band(runs: dict, n_drives: int, emit) -> None:
    lo, hi = _band(n_drives)
    r = _ratio(runs)
    if not lo <= r <= hi:
        raise RuntimeError(
            f"chaos/baseline goodput ratio {r:.2f} outside "
            f"[{lo:.2f}, {hi:.2f}] — losing 1 of {n_drives} drives should "
            f"cost about its proportional share, not this")
    emit(f"chaos gates: goodput ratio {r:.2f} in [{lo:.2f}, {hi:.2f}], "
         f"conservation + token identity + free-list balance held")


def run_smoke(emit=print) -> None:
    """CI chaos-smoke: 2 drives, one mid-trace crash, no wall-clock
    gates — conservation, detection, and token identity must hold."""
    cfg, params, ref = make_setup()
    prompts = build_requests(cfg, n_requests=6, seed=0)
    oracle = oracle_tokens(ref, prompts, max_new=4)
    m = measure(cfg, params, ref, prompts, n_drives=2, max_new=4,
                crash_drive=1, crash_tick=2, oracle=oracle)
    if m["dead_drives"] != 1 or m["auto_failed_drives"] != 1:
        raise RuntimeError(f"chaos-smoke did not kill the drive: {m}")
    if m["ok"] != m["submitted"]:
        raise RuntimeError(f"chaos-smoke lost requests: {m}")
    emit(f"chaos-smoke: ok ({m['ok']} ok, {m['retries']} retries, "
         f"drive 1 dead, free-lists balanced)")


def run_check(path: str, emit=print) -> None:
    """bench-guard hook: the committed payload must be NaN-free."""
    check_payload(path, emit=emit)


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="write the chaos payload + run the gates")
    ap.add_argument("--json-path", default="BENCH_fig8_faults.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI chaos-smoke: 2 drives, one crash, no "
                         "wall-clock gates")
    ap.add_argument("--check", action="store_true",
                    help="scan the committed JSON for NaN and exit")
    ap.add_argument("--drives", type=int, default=4)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--crash-tick", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.check:
        run_check(args.json_path)
        return
    if args.smoke:
        run_smoke()
        return
    run_chaos(n_drives=args.drives, n_requests=args.requests,
              max_new=args.max_new, crash_tick=args.crash_tick,
              seed=args.seed,
              json_path=args.json_path if args.json else None)


if __name__ == "__main__":
    main()
