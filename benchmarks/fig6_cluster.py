"""Fig. 6-style cluster scaling through the REAL replica serve engines:
tokens/s and mJ/query over 1→N drives, per routing policy.

The paper's Fig. 6 scales one storage server from 0 to 36 CSDs and shows
throughput rising while energy-per-query falls (Table I).  This benchmark
replays that experiment on the LM serving cluster
(``train.cluster_loop.ClusterEngine``): a sharded request trace is served
by 1..N replica drives under each routing policy, and every run reports

  * aggregate tokens/s under the parallel-drives wall-clock model,
  * the live energy integral's mJ/query (validated against
    ``core.energy.energy_per_query_mj`` on the same throughput),
  * merged link/KV reductions plus the shard-spill bytes the routing
    policy's locality decisions cost.

``--json`` writes ``BENCH_fig6_cluster.json`` and FAILS loudly unless
  * every cluster run is token-identical to a single engine serially
    replaying the same trace,
  * tokens/s scales monotonically from 1 to 2 drives under least_loaded,
  * data_local moves fewer link bytes than round_robin on the sharded
    trace,
  * the live mJ/query matches the analytic model,
  * on a heterogeneous 2-drive cluster (``speed_factor=[1.0, 0.5]``) the
    ``rate_aware`` policy — fed by the cluster pull scheduler's learned
    per-drive rates — beats both ``round_robin`` and ``least_loaded``
    tokens/s (§IV-A's batch-ratio rule, measured live),
  * after ``drain()`` with shard re-placement, re-submitting the sharded
    trace pays fewer link bytes than the no-replacement path (one
    migration charge vs a per-request spill forever).

``--smoke`` is the CI cluster-smoke tier: a 2-drive engine for a few
ticks, failing on crash or broken throughput.  ``--hetero --smoke`` is
the CI hetero-smoke tier: a small heterogeneous cluster must learn the
2x rate skew and serve token-identically; ``--hetero`` alone runs the
full hetero gate without the homogeneous sweep.
"""
from __future__ import annotations

import dataclasses
import json
import math

import numpy as np

from benchmarks._gate import retry_gate, scan_nan
from repro.core.cluster import ROUTING_POLICIES as DRIVE_POLICIES


def build_trace(rng, n_requests: int, n_shards: int, vocab: int,
                min_prompt: int = 4, max_prompt: int = 16):
    """Sharded request trace: mixed-length prompts, each pinned to the
    shard (≈ drive) holding its data — shard assignment is random, so
    locality-oblivious policies genuinely mis-place requests."""
    prompts = [rng.integers(0, vocab,
                            rng.integers(min_prompt, max_prompt + 1)).tolist()
               for _ in range(n_requests)]
    shards = rng.integers(0, max(n_shards, 1), n_requests).tolist()
    return prompts, shards


def _metrics(stats) -> dict:
    return {
        "completed": stats.completed,
        "tokens": stats.tokens,
        "tokens_per_s": stats.tokens_per_s,
        "throughput_qps": stats.throughput_qps,
        "cluster_s": stats.cluster_s,
        "serial_s": stats.serial_s,
        "mean_active": stats.mean_active,
        "energy_per_query_mj": stats.energy_per_query_mj,
        "energy_reduction_vs_host": stats.energy_reduction_vs_host,
        "link_bytes": stats.link_bytes,
        "host_link_bytes": stats.host_link_bytes,
        "link_reduction": stats.link_reduction,
        "kv_reduction": stats.kv_reduction,
        "spill_bytes": stats.spill_bytes,
        "remote_requests": stats.remote_requests,
        "migrated_shards": stats.migrated_shards,
        "shard_migration_bytes": stats.shard_migration_bytes,
    }


def _engine_metrics(clu) -> dict:
    """Cluster-engine extras next to the stats: the pull scheduler's
    learned per-drive rates (JSON-safe: NaN -> None) and the per-drive
    request counts the routing produced."""
    return {
        "drive_rates": [None if not math.isfinite(r) else r
                        for r in clu.drive_rates()],
        "requests_per_drive": [d.requests for d in clu.stats.drives],
        "speed_factor": [d.speed for d in clu.drives],
    }


def make_setup(seed: int = 0, num_slots: int = 2, prewarm: bool = True):
    """Model + params + the reference engine shared by every section: the
    serial-replay oracle AND the jit donor (N drives, one compile)."""
    import jax

    from repro.config import reduced_config
    from repro.models import model as M
    from repro.train.serve_loop import ServeEngine

    cfg = dataclasses.replace(reduced_config("yi-9b"), dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    ref = ServeEngine(cfg, params, max_len=64, num_slots=num_slots,
                      prewarm=prewarm)
    return cfg, params, ref


def run_cluster(emit=print, n_requests: int = 8, max_new: int = 6,
                num_slots: int = 2, max_drives: int = 2, n_shards=None,
                seed: int = 0, policies=DRIVE_POLICIES, json_path=None,
                prewarm: bool = True, strict: bool = True, setup=None):
    """Serve one sharded trace through every (policy, n_drives) cluster and
    validate the scaling/locality/energy acceptance gates (see module
    docstring).  Returns the JSON payload."""
    from repro.core.energy import energy_per_query_mj
    from repro.train.cluster_loop import ClusterEngine

    cfg, params, ref = setup if setup is not None else \
        make_setup(seed, num_slots, prewarm)
    rng = np.random.default_rng(seed)
    if n_shards is None:
        n_shards = max_drives
    prompts, shards = build_trace(rng, n_requests, n_shards, cfg.vocab_size)
    ref_tokens = [r.tokens for r in ref.generate(prompts, max_new=max_new)]

    drive_counts = list(range(1, max_drives + 1))
    emit("table,policy,n_drives,tokens_per_s,mj_per_query,mean_active,"
         "link_mb,spill_mb,remote,link_reduction,kv_reduction,energy_vs_host")
    runs: dict = {p: {} for p in policies}
    identical = True

    def measure(policy, n):
        """Fresh cluster over the trace.  Every measurement — including
        warm passes and scaling-gate re-measurements — goes through the
        token-identity flag, the finite-throughput check, and the
        live-vs-analytic energy gate (server_power is affine in active
        drives, so the integral must match the Table I model exactly)."""
        nonlocal identical
        clu = ClusterEngine(cfg, params, n_drives=n, routing=policy,
                            jit_donor=ref, max_len=64,
                            num_slots=num_slots, prewarm=prewarm)
        results = clu.generate(prompts, max_new=max_new, shard_ids=shards)
        if [r.tokens for r in results] != ref_tokens:
            identical = False
        m = _metrics(clu.stats)
        m.update(_engine_metrics(clu))
        if not math.isfinite(m["tokens_per_s"]) or m["tokens_per_s"] <= 0:
            raise RuntimeError(f"{policy}/{n} throughput is broken: "
                               f"{m['tokens_per_s']}")
        analytic = energy_per_query_mj(m["throughput_qps"], m["mean_active"])
        if not math.isclose(m["energy_per_query_mj"], analytic,
                            rel_tol=1e-6):
            raise RuntimeError(
                f"{policy}/{n}: live energy {m['energy_per_query_mj']:.3f}"
                f" mJ/query != analytic {analytic:.3f}")
        return m

    for policy in policies:
        for n in drive_counts:
            # warm pass: this (policy, n) admission pattern hits eager
            # gather/scatter shapes (prefill splice) the process has not
            # compiled yet; a second, fresh cluster then measures
            # steady-state serving — what a long-running server sees
            measure(policy, n)
            m = runs[policy][str(n)] = measure(policy, n)
            emit(f"fig6_cluster,{policy},{n},{m['tokens_per_s']:.1f},"
                 f"{m['energy_per_query_mj']:.1f},{m['mean_active']:.2f},"
                 f"{m['link_bytes'] / 1e6:.3f},{m['spill_bytes'] / 1e6:.4f},"
                 f"{m['remote_requests']},{m['link_reduction']:.3f},"
                 f"{m['kv_reduction']:.3f},"
                 f"{m['energy_reduction_vs_host']:.3f}")

    if strict and "least_loaded" in policies and max_drives >= 2:
        # a loaded CI box can flatten a wall-clock scaling measurement;
        # re-measure (shapes are warm) before declaring a real regression
        runs["least_loaded"] = {
            **runs["least_loaded"],
            **retry_gate(
                {k: runs["least_loaded"][k] for k in ("1", "2")},
                lambda: {"1": measure("least_loaded", 1),
                         "2": measure("least_loaded", 2)},
                lambda r: r["2"]["tokens_per_s"] >= r["1"]["tokens_per_s"],
                emit, attempts=3,
                describe=lambda r: (
                    f"scaling gate missed ({r['1']['tokens_per_s']:.1f} -> "
                    f"{r['2']['tokens_per_s']:.1f} tok/s)")),
        }
        t1 = runs["least_loaded"]["1"]["tokens_per_s"]
        t2 = runs["least_loaded"]["2"]["tokens_per_s"]
        if t2 < t1:
            raise RuntimeError(
                f"least_loaded tokens/s did not scale 1→2 drives: "
                f"{t1:.1f} -> {t2:.1f}")
    if strict and {"data_local", "round_robin"} <= set(policies) \
            and max_drives >= 2:
        nd = str(max_drives)
        local = runs["data_local"][nd]
        rr = runs["round_robin"][nd]
        if local["spill_bytes"] > rr["spill_bytes"] or \
                local["link_bytes"] >= rr["link_bytes"]:
            raise RuntimeError(
                f"data_local moved no fewer link bytes than round_robin: "
                f"{local['link_bytes']:.0f} vs {rr['link_bytes']:.0f} "
                f"(spill {local['spill_bytes']:.0f} vs "
                f"{rr['spill_bytes']:.0f})")
    # the payload is assembled AFTER every gate (including re-measurements)
    # so the written file can never carry a stale identity flag
    if not identical:
        raise RuntimeError("cluster decode diverged from the single-engine "
                           "serial replay")
    payload = {
        "bench": "fig6_cluster",
        "requests": n_requests,
        "max_new": max_new,
        "num_slots": num_slots,
        "n_shards": n_shards,
        "drive_counts": drive_counts,
        "tokens_identical": identical,
        "runs": runs,
    }
    if strict:
        # heterogeneous + re-placement sections share the jit donor; their
        # gates run (and can fail) before anything is written
        payload["hetero"] = run_hetero(emit=emit, num_slots=num_slots,
                                       seed=seed, strict=True,
                                       setup=(cfg, params, ref))
        payload["replacement"] = run_replacement(
            emit=emit, num_slots=num_slots, seed=seed, strict=True,
            setup=(cfg, params, ref))
    # the committed reference must be NaN-free, same as every other
    # figure payload (drive_rates already map NaN -> None above)
    bad = scan_nan(payload)
    if bad:
        raise RuntimeError(f"NaN metrics in the payload: {bad}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        emit(f"wrote {json_path}")
    best = max(drive_counts)
    pol = "least_loaded" if "least_loaded" in policies else policies[0]
    m1, mN = runs[pol]["1"], runs[pol][str(best)]
    emit(f"cluster_scaling[{pol}]: {m1['tokens_per_s']:.1f} tok/s @1 drive "
         f"-> {mN['tokens_per_s']:.1f} tok/s @{best} drives "
         f"({mN['tokens_per_s'] / max(m1['tokens_per_s'], 1e-9):.2f}x); "
         f"{m1['energy_per_query_mj']:.0f} -> "
         f"{mN['energy_per_query_mj']:.0f} mJ/query; tokens identical: "
         f"{identical}")
    return payload


HETERO_SPEEDS = (1.0, 0.5)
HETERO_POLICIES = ("round_robin", "least_loaded", "rate_aware")


def run_hetero(emit=print, n_requests: int = 32, max_new: int = 24,
               num_slots: int = 2, seed: int = 0, strict: bool = True,
               speed_factor=HETERO_SPEEDS, policies=HETERO_POLICIES,
               attempts: int = 3, setup=None):
    """Heterogeneous cluster gate (§IV-A): one drive modeled 2x slower.

    ``rate_aware`` routing — driven by the cluster pull scheduler's learned
    per-drive rates and expected-completion deferral — must beat both
    rate-blind policies on tokens/s under the async parallel wall-clock
    model, while every run stays token-identical to the serial replay.
    Wall-clock gates on a shared box get best-of-``attempts``
    re-measurement before declaring a regression."""
    from repro.train.cluster_loop import ClusterEngine

    cfg, params, ref = setup if setup is not None else \
        make_setup(seed, num_slots, True)
    rng = np.random.default_rng(seed + 1)
    prompts, shards = build_trace(rng, n_requests, len(speed_factor),
                                  cfg.vocab_size)
    ref_tokens = [r.tokens for r in ref.generate(prompts, max_new=max_new)]

    def measure(policy):
        m = None
        for _ in range(2):          # warm pass, then a steady-state measure
            clu = ClusterEngine(cfg, params, n_drives=len(speed_factor),
                                routing=policy, jit_donor=ref, max_len=64,
                                num_slots=num_slots,
                                speed_factor=list(speed_factor))
            results = clu.generate(prompts, max_new=max_new,
                                   shard_ids=shards)
            if [r.tokens for r in results] != ref_tokens:
                raise RuntimeError(f"hetero/{policy}: tokens diverged from "
                                   f"the serial replay")
            m = _metrics(clu.stats)
            m.update(_engine_metrics(clu))
        return m

    runs = {p: measure(p) for p in policies}
    for p, m in runs.items():
        emit(f"fig6_hetero,{p},{m['tokens_per_s']:.1f},"
             f"{m['requests_per_drive']},"
             f"{[None if r is None else round(r, 1) for r in m['drive_rates']]}")
    if strict and "rate_aware" in policies and len(policies) > 1:
        rivals = [p for p in policies if p != "rate_aware"]
        for attempt in range(attempts):
            ra = runs["rate_aware"]["tokens_per_s"]
            worst = max(runs[p]["tokens_per_s"] for p in rivals)
            if ra > worst:
                break
            emit(f"hetero gate missed (rate_aware {ra:.1f} vs best rival "
                 f"{worst:.1f} tok/s), re-measuring ({attempt + 1}/{attempts})")
            runs = {p: measure(p) for p in policies}
        ra = runs["rate_aware"]["tokens_per_s"]
        for p in rivals:
            if ra <= runs[p]["tokens_per_s"]:
                raise RuntimeError(
                    f"rate_aware ({ra:.1f} tok/s) did not beat {p} "
                    f"({runs[p]['tokens_per_s']:.1f} tok/s) on the "
                    f"speed_factor={list(speed_factor)} cluster")
        emit(f"hetero gate: rate_aware {ra:.1f} tok/s beats "
             + ", ".join(f"{p} {runs[p]['tokens_per_s']:.1f}"
                         for p in rivals))
    return {"speed_factor": list(speed_factor), "requests": n_requests,
            "max_new": max_new, "runs": runs}


def run_replacement(emit=print, n_requests: int = 12, max_new: int = 10,
                    num_slots: int = 2, seed: int = 0, strict: bool = True,
                    setup=None):
    """Shard re-placement gate: serve a sharded trace under ``data_local``,
    ``drain()`` one drive, re-submit the same trace.  With re-placement the
    drained drive's shards migrate ONCE (one ``shard_bytes`` charge each);
    without it every re-submitted request homed there spills over the link
    forever — the re-submitted trace must therefore pay fewer link bytes
    with re-placement than without."""
    from repro.train.cluster_loop import ClusterEngine

    cfg, params, ref = setup if setup is not None else \
        make_setup(seed, num_slots, True)
    rng = np.random.default_rng(seed + 2)
    prompts, shards = build_trace(rng, n_requests, 2, cfg.vocab_size)
    ref_tokens = [r.tokens for r in ref.generate(prompts, max_new=max_new)]

    def phase_pair(replacement: bool) -> dict:
        clu = ClusterEngine(cfg, params, n_drives=2, routing="data_local",
                            jit_donor=ref, max_len=64, num_slots=num_slots,
                            shard_replacement=replacement)
        first = clu.generate(prompts, max_new=max_new, shard_ids=shards)
        link_before = clu.stats.link_bytes
        spill_before = clu.stats.spill_bytes
        clu.drain(1)
        second = clu.generate(prompts, max_new=max_new, shard_ids=shards)
        for res in (first, second):
            if [r.tokens for r in res] != ref_tokens:
                raise RuntimeError("replacement phase diverged from the "
                                   "serial replay")
        return {
            "resubmit_link_bytes": clu.stats.link_bytes - link_before,
            "resubmit_spill_bytes": clu.stats.spill_bytes - spill_before,
            "migrated_shards": clu.stats.migrated_shards,
            "shard_migration_bytes": clu.stats.shard_migration_bytes,
            "remote_requests": clu.stats.remote_requests,
        }

    with_rp = phase_pair(True)
    without_rp = phase_pair(False)
    emit(f"fig6_replacement,with,{with_rp['resubmit_link_bytes']:.0f},"
         f"{with_rp['migrated_shards']} shards migrated")
    emit(f"fig6_replacement,without,{without_rp['resubmit_link_bytes']:.0f},"
         f"{without_rp['remote_requests']} remote requests")
    if strict:
        if with_rp["migrated_shards"] < 1:
            raise RuntimeError("drain() migrated no shards")
        if with_rp["resubmit_link_bytes"] >= \
                without_rp["resubmit_link_bytes"]:
            raise RuntimeError(
                f"shard re-placement paid no fewer link bytes on the "
                f"re-submitted trace: {with_rp['resubmit_link_bytes']:.0f} "
                f"vs {without_rp['resubmit_link_bytes']:.0f} without")
        emit(f"replacement gate: {with_rp['resubmit_link_bytes']:.0f} < "
             f"{without_rp['resubmit_link_bytes']:.0f} link bytes")
    return {"requests": n_requests, "max_new": max_new,
            "with_replacement": with_rp, "without_replacement": without_rp}


def run_hetero_smoke(emit=print) -> None:
    """CI hetero-smoke: a small speed-skewed cluster must serve
    token-identically, learn a rate for every drive, and rank the fast
    drive above the slowed one."""
    payload = run_hetero(emit=emit, n_requests=10, max_new=12,
                         policies=("rate_aware",), strict=False)
    m = payload["runs"]["rate_aware"]
    if m["completed"] != 10:
        raise RuntimeError(f"hetero-smoke served {m['completed']}/10 "
                           f"requests")
    rates = m["drive_rates"]
    if any(r is None or not r > 0 for r in rates):
        raise RuntimeError(f"pull scheduler left a drive unrated: {rates}")
    if rates[0] <= rates[1]:
        raise RuntimeError(f"learned rates do not reflect the 2x speed "
                           f"skew: {rates}")
    emit("hetero-smoke: ok")


def run_smoke(emit=print) -> None:
    """CI cluster-smoke: a 2-replica engine serves a few requests for a few
    ticks; fails on crash, broken throughput, or divergent tokens."""
    payload = run_cluster(emit=emit, n_requests=4, max_new=3, num_slots=2,
                          max_drives=2, policies=("least_loaded",),
                          json_path=None, strict=False)
    m = payload["runs"]["least_loaded"]["2"]
    if m["completed"] != 4:
        raise RuntimeError(f"cluster-smoke served {m['completed']}/4 requests")
    emit("cluster-smoke: ok")


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="write the cluster scaling payload + run the "
                         "acceptance gates")
    ap.add_argument("--json-path", default="BENCH_fig6_cluster.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI cluster-smoke: 2 replicas, a few ticks "
                         "(with --hetero: the hetero-smoke tier)")
    ap.add_argument("--hetero", action="store_true",
                    help="heterogeneous-cluster section only "
                         "(speed_factor-skewed drives, rate_aware gate)")
    ap.add_argument("--requests", type=int, default=None,
                    help="trace size (default: 8; 32 with --hetero)")
    ap.add_argument("--max-new", type=int, default=None,
                    help="tokens per request (default: 6; 24 with --hetero)")
    ap.add_argument("--num-slots", type=int, default=2)
    ap.add_argument("--drives", type=int, default=2,
                    help="scale from 1 to this many replica drives")
    ap.add_argument("--shards", type=int, default=0,
                    help="data shards in the trace (0 = one per drive)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.hetero:
        if args.smoke:
            run_hetero_smoke()
        else:
            payload = run_hetero(seed=args.seed, num_slots=args.num_slots,
                                 n_requests=args.requests or 32,
                                 max_new=args.max_new or 24)
            if args.json:
                # never clobber the committed full-payload file with a
                # hetero-only section under the default path
                path = "BENCH_fig6_hetero.json" \
                    if args.json_path == "BENCH_fig6_cluster.json" \
                    else args.json_path
                with open(path, "w") as f:
                    json.dump({"bench": "fig6_hetero", **payload}, f,
                              indent=2)
                print(f"wrote {path}")
        return
    if args.smoke:
        run_smoke()
        return
    run_cluster(n_requests=args.requests or 8, max_new=args.max_new or 6,
                num_slots=args.num_slots, max_drives=args.drives,
                n_shards=args.shards or None, seed=args.seed,
                json_path=args.json_path if args.json else None)


if __name__ == "__main__":
    main()
