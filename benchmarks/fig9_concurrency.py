"""Concurrency bench: measured worker-thread overlap vs the virtual-clock
model's prediction.

Every cluster bench before this PR *modeled* drive parallelism: the
serial step loop ran the drives one after another and charged the tick
the leading virtual clock's advance.  The worker runtime makes overlap
real — one thread per drive, tick cost measured off the join wall clock
— so the model's claim is finally testable: serve the SAME trace both
ways on an N-drive cluster with a per-drive service-time floor
(``min_tick_s``, applied in BOTH modes so the comparison is fair) and
compare three numbers:

  serial_wall_s       real wall time of the serial step loop: the floor
                      is actually slept per drive per tick, so N drives
                      cost ~N floors per tick;
  concurrent_wall_s   real wall time of the worker runtime: the floors
                      overlap, so a tick costs ~1 floor + join overhead;
  predicted_s         the virtual-clock model's parallel makespan
                      (leading per-drive clock) from the SAME concurrent
                      run.

``--json`` writes ``BENCH_fig9_concurrency.json`` and FAILS loudly unless
  * both runs decode token-identically to the single-engine serial
    oracle (greedy decode: concurrency must not change one token);
  * conservation (``submitted == ok``) and KV free-list balance hold in
    both runs, and no drive was suspected or killed (fault-free trace);
  * the measured speedup ``serial_wall_s / concurrent_wall_s`` clears
    ``SPEEDUP_MIN`` — threads genuinely overlapped;
  * the model held: ``cluster_s / predicted_s`` (measured join wall vs
    virtual-clock makespan) is inside ``PREDICTION_BAND``.
  Wall-clock gates re-measure up to ATTEMPTS times before failing.

``--smoke`` is the CI concurrency-smoke tier: 2 drives, a handful of
requests, token identity + conservation only (no wall-clock gates).
``--check`` re-scans the committed JSON for NaN without serving anything
(the bench-guard hook).
"""
from __future__ import annotations

import dataclasses
import json
import time

from benchmarks._gate import check_payload, retry_gate, scan_nan

ATTEMPTS = 3
SPEEDUP_MIN = 1.8          # 4 drives' floors overlapped vs summed
PREDICTION_BAND = (0.7, 2.2)  # measured join wall / virtual-clock makespan


def make_setup(seed: int = 0, num_slots: int = 2, max_len: int = 64):
    """Model + params + a prewarmed k_block=1 donor engine (one XLA
    compile for every cluster in the bench)."""
    import jax

    from repro.config import reduced_config
    from repro.models import model as M
    from repro.train.serve_loop import ServeEngine

    cfg = dataclasses.replace(reduced_config("yi-9b"), dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    ref = ServeEngine(cfg, params, max_len=max_len, num_slots=num_slots,
                      k_block=1, prewarm=True)
    return cfg, params, ref


def build_requests(cfg, n_requests: int, seed: int):
    import numpy as np

    rng = np.random.default_rng(seed + 7)
    return [rng.integers(0, cfg.vocab_size,
                         int(rng.integers(4, 13))).tolist()
            for _ in range(n_requests)]


def oracle_tokens(ref, prompts, max_new: int):
    """Fault-free serial replay on the donor: rid -> greedy tokens."""
    return {i: r.tokens
            for i, r in enumerate(ref.generate(prompts, max_new=max_new))}


def _watchdog(n_drives: int):
    """Lenient watchdog for a fault-free bench: the gates below assert it
    stayed silent, so a false kill fails loudly rather than hiding in a
    retry."""
    from repro.core.runtime import HeartbeatWatchdog

    return HeartbeatWatchdog(n_drives, suspect_after_s=2.0,
                             suspect_misses=200, dead_after_s=30.0,
                             dead_misses=10 ** 6)


def measure(cfg, params, ref, prompts, n_drives: int, max_new: int,
            min_tick_s: float, concurrent: bool, oracle=None,
            telemetry=None) -> dict:
    """One closed-loop run; enforces the per-run invariants and returns
    both the real wall time and the engine's measured/modeled clocks."""
    from repro.train.cluster_loop import ClusterEngine

    clu = ClusterEngine(cfg, params, n_drives=n_drives, jit_donor=ref,
                        routing="round_robin", max_len=ref.max_len,
                        num_slots=ref.num_slots, k_block=1, prewarm=True,
                        min_tick_s=min_tick_s, concurrent=concurrent,
                        watchdog=_watchdog(n_drives) if concurrent else None,
                        telemetry=telemetry)
    try:
        rids = [clu.submit(p, max_new=max_new) for p in prompts]
        t0 = time.perf_counter()
        results = {r.rid: r for r in clu.run_until_complete()}
        wall = time.perf_counter() - t0
        st = clu.stats
        ok = sum(1 for r in results.values() if r.status == "ok")
        if sorted(results) != rids:
            raise RuntimeError(f"run lost requests: got {len(results)} of "
                               f"{len(rids)}")
        if ok != len(rids):
            raise RuntimeError(f"fault-free run shed/failed work: {ok} ok "
                               f"of {len(rids)}")
        if st.auto_failed_drives or any(h != "healthy" for h in st.health):
            raise RuntimeError(f"fault-free run tripped the watchdog: "
                               f"health={st.health}")
        for d in clu.drives:
            if d.engine.pager is not None:
                if d.engine.pager.num_in_use != 0:
                    raise RuntimeError(
                        f"drive {d.drive_id} leaked "
                        f"{d.engine.pager.num_in_use} KV pages")
                d.engine.pager.check_balanced()
        if oracle is not None:
            for rid, r in results.items():
                if r.tokens != oracle[rid]:
                    raise RuntimeError(
                        f"request {rid} diverged under "
                        f"{'concurrent' if concurrent else 'serial'} "
                        f"serving: {r.tokens} vs {oracle[rid]}")
        return {
            "mode": "concurrent" if concurrent else "serial",
            "submitted": len(rids),
            "ok": ok,
            "ticks": st.ticks,
            "wall_s": wall,             # real wall around the whole run
            "cluster_s": st.cluster_s,  # engine's tick cost (measured
                                        # join wall when concurrent)
            "serial_s": st.serial_s,    # summed per-drive busy time
            "predicted_s": clu.predicted_parallel_s,
            "tokens": st.tokens,
            "mean_active": st.mean_active,
            "energy_per_query_mj": st.energy_per_query_mj,
        }
    finally:
        clu.close()


def run_bench(emit=print, n_drives: int = 4, n_requests: int = 16,
              max_new: int = 8, min_tick_ms: float = 12.0, seed: int = 0,
              json_path=None, strict: bool = True, setup=None,
              trace_out=None):
    """Serve the trace serially and concurrently; gate and return the
    payload.  With ``trace_out`` the LAST concurrent run is traced through
    the telemetry hub and the Chrome trace is written even when a gate
    fails — a failed speedup gate leaves the timeline that explains it."""
    cfg, params, ref = setup if setup is not None else make_setup(seed)
    prompts = build_requests(cfg, n_requests, seed)
    oracle = oracle_tokens(ref, prompts, max_new)
    floor = min_tick_ms / 1e3
    hub_box = {"hub": None}     # the latest concurrent run's hub

    def measure_all():
        hub = None
        if trace_out:
            from repro.core.telemetry import TelemetryHub
            hub_box["hub"] = hub = TelemetryHub()
        return {
            "serial": measure(cfg, params, ref, prompts, n_drives, max_new,
                              floor, concurrent=False, oracle=oracle),
            "concurrent": measure(cfg, params, ref, prompts, n_drives,
                                  max_new, floor, concurrent=True,
                                  oracle=oracle, telemetry=hub),
        }

    try:
        runs = measure_all()
        # warm pass then steady state: the first pass may still trip fresh
        # splice shapes at this trace's prompt lengths
        runs = measure_all()

        if strict:
            runs = retry_gate(
                runs, measure_all, _gates_pass, emit, attempts=ATTEMPTS,
                describe=lambda r: (
                    f"wall-clock gates missed (speedup {_speedup(r):.2f}, "
                    f"prediction ratio {_prediction_ratio(r):.2f})"))
            _gate(runs, emit)
    finally:
        if trace_out and hub_box["hub"] is not None:
            hub_box["hub"].write_chrome_trace(trace_out)
            emit(f"wrote {trace_out}")

    emit("table,mode,ok,ticks,wall_s,cluster_s,serial_s,predicted_s")
    for name, m in runs.items():
        emit(f"fig9_concurrency,{name},{m['ok']},{m['ticks']},"
             f"{m['wall_s']:.3f},{m['cluster_s']:.3f},{m['serial_s']:.3f},"
             f"{m['predicted_s']:.3f}")

    payload = {
        "bench": "fig9_concurrency",
        "n_drives": n_drives,
        "requests": n_requests,
        "max_new": max_new,
        "min_tick_ms": min_tick_ms,
        "seed": seed,
        "speedup_min": SPEEDUP_MIN,
        "prediction_band": list(PREDICTION_BAND),
        "speedup": _speedup(runs),
        "prediction_ratio": _prediction_ratio(runs),
        "runs": runs,
    }
    bad = scan_nan(payload)
    if bad:
        raise RuntimeError(f"NaN metrics in the payload: {bad}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        emit(f"wrote {json_path}")
    emit(f"concurrency: {n_drives} drives, floor {min_tick_ms:.0f}ms: "
         f"serial {runs['serial']['wall_s']:.2f}s -> concurrent "
         f"{runs['concurrent']['wall_s']:.2f}s "
         f"(speedup {_speedup(runs):.2f}x; measured/predicted "
         f"{_prediction_ratio(runs):.2f})")
    return payload


def _speedup(runs: dict) -> float:
    return runs["serial"]["wall_s"] / max(runs["concurrent"]["wall_s"], 1e-9)


def _prediction_ratio(runs: dict) -> float:
    c = runs["concurrent"]
    return c["cluster_s"] / max(c["predicted_s"], 1e-9)


def _gates_pass(runs: dict) -> bool:
    lo, hi = PREDICTION_BAND
    return _speedup(runs) >= SPEEDUP_MIN and \
        lo <= _prediction_ratio(runs) <= hi


def _gate(runs: dict, emit) -> None:
    s, r = _speedup(runs), _prediction_ratio(runs)
    lo, hi = PREDICTION_BAND
    if s < SPEEDUP_MIN:
        raise RuntimeError(
            f"concurrent speedup {s:.2f}x below {SPEEDUP_MIN}x — the "
            f"worker threads did not genuinely overlap the service floors")
    if not lo <= r <= hi:
        raise RuntimeError(
            f"measured/predicted ratio {r:.2f} outside [{lo}, {hi}] — the "
            f"virtual-clock model and the measured join wall disagree")
    emit(f"concurrency gates: speedup {s:.2f}x >= {SPEEDUP_MIN}x, "
         f"prediction ratio {r:.2f} in [{lo}, {hi}], token identity + "
         f"conservation + free-list balance held in both modes")


def run_smoke(emit=print, trace_out=None) -> None:
    """CI concurrency-smoke: 2 drives, a handful of requests through the
    worker runtime — token identity, conservation, and a clean join; no
    wall-clock gates."""
    cfg, params, ref = make_setup()
    prompts = build_requests(cfg, n_requests=6, seed=0)
    oracle = oracle_tokens(ref, prompts, max_new=4)
    hub = None
    if trace_out:
        from repro.core.telemetry import TelemetryHub
        hub = TelemetryHub()
    m = measure(cfg, params, ref, prompts, n_drives=2, max_new=4,
                min_tick_s=0.008, concurrent=True, oracle=oracle,
                telemetry=hub)
    if hub is not None:
        hub.write_chrome_trace(trace_out)
        emit(f"wrote {trace_out}")
    emit(f"concurrency-smoke: ok ({m['ok']} ok in {m['ticks']} ticks, "
         f"cluster_s {m['cluster_s']:.3f}s, workers joined)")


def run_check(path: str, emit=print) -> None:
    """bench-guard hook: the committed payload must be NaN-free."""
    check_payload(path, emit=emit)


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="write the concurrency payload + run the gates")
    ap.add_argument("--json-path", default="BENCH_fig9_concurrency.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI concurrency-smoke: 2 drives, no wall-clock "
                         "gates")
    ap.add_argument("--check", action="store_true",
                    help="scan the committed JSON for NaN and exit")
    ap.add_argument("--drives", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--min-tick-ms", type=float, default=12.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace of the last concurrent run "
                         "(written even when a gate fails)")
    args = ap.parse_args(argv)
    if args.check:
        run_check(args.json_path)
        return
    if args.smoke:
        run_smoke(trace_out=args.trace_out)
        return
    run_bench(n_drives=args.drives, n_requests=args.requests,
              max_new=args.max_new, min_tick_ms=args.min_tick_ms,
              seed=args.seed,
              json_path=args.json_path if args.json else None,
              trace_out=args.trace_out)


if __name__ == "__main__":
    main()
