#!/usr/bin/env bash
# CI entry points.  Run `scripts/ci.sh help` for the tier list — it is
# generated from the `case` arms below (each arm documents itself with
# trailing `##` comments), so unlike a hand-maintained header it cannot
# drift from the real tiers.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"
# dump thread stacks on a hard hang/crash — the concurrent runtime means
# every tier now runs multi-threaded
export PYTHONFAULTHANDLER=1

case "${1:-tier1}" in
  fast)          ## smoke tier: fast unit tests only (-m fast)
                 exec python -m pytest -x -q -m fast ;;
  nonslow)       ## everything except the multi-minute slow tests
                 exec python -m pytest -x -q -m "not slow" ;;
  lint)          ## AST invariant linter (repro.analysis.lint) over
                 ## src/repro, benchmarks/ and examples/; fails on any
                 ## error diagnostic or a suppression-count increase vs
                 ## the committed LINT_BASELINE.json
                 exec python -m repro.analysis.lint src/repro benchmarks \
                      examples --json --baseline LINT_BASELINE.json ;;
  perf-smoke)    ## engine benchmark at a tiny config; fails on crash,
                 ## NaN throughput, paged/strip mismatch or paged decode
                 ## regressing >1.5x behind strip, and writes
                 ## BENCH_fig5.json
                 exec python -m benchmarks.fig5_throughput --engine --json \
                      --requests 4 --max-new 4 --num-slots 2 --k-block 8 ;;
  bench-guard)   ## scans EVERY committed BENCH_*.json for NaN metrics in
                 ## one pass (benchmarks/_gate.py — a degenerate run must
                 ## never be the committed reference; new payloads are
                 ## covered the day they land) and validates
                 ## LINT_BASELINE.json structure, then re-runs the
                 ## committed BENCH_fig5.json workload and fails if
                 ## tokens/s drops below 0.8x the committed numbers
                 python -c "from benchmarks._gate import check_tree; check_tree()"
                 exec python -m benchmarks.fig5_throughput --engine \
                      --guard BENCH_fig5.json --guard-floor 0.8 ;;
  cluster-smoke) ## 2-replica cluster engine serves a short trace for a
                 ## few ticks; fails on crash, broken throughput, or
                 ## tokens diverging from the single-engine serial replay
                 exec python -m benchmarks.fig6_cluster --smoke ;;
  slo-smoke)     ## tiny bursty open-loop trace through the EDF serve
                 ## engine; fails on crash, lost requests, or non-finite
                 ## tail-latency stats
                 exec python -m benchmarks.fig7_slo --smoke ;;
  hetero-smoke)  ## heterogeneous 2-replica cluster (one drive modeled 2x
                 ## slower): the pull scheduler must rate both drives
                 ## (fast > slow) and serving must stay token-identical
                 ## to serial replay
                 exec python -m benchmarks.fig6_cluster --hetero --smoke ;;
  chaos-smoke)   ## 2-replica cluster with a seeded mid-trace crash of
                 ## drive 1: the failure detector must kill it, retries
                 ## must recover every request token-identically, and no
                 ## KV page may leak
                 exec python -m benchmarks.fig8_faults --smoke ;;
  concurrency-smoke)
                 ## worker-runtime tier: a seeded subset of the
                 ## concurrent stress iterations (crashes and real thread
                 ## hangs against the heartbeat watchdog) plus the fig9
                 ## smoke; fails on token divergence, broken
                 ## conservation, leaked KV pages, or worker threads that
                 ## fail to join
                 STRESS_ITERS=6 python -m pytest -x -q \
                      tests/test_concurrent_stress.py
                 exec python -m benchmarks.fig9_concurrency --smoke ;;
  obs-smoke)     ## observability tier: the telemetry unit tests, then a
                 ## small concurrent 2-replica serve run with
                 ## --trace-out/--metrics-out whose Chrome trace must
                 ## load through scripts/trace_report.py (the same
                 ## structural checks a Perfetto import would trip over)
                 python -m pytest -x -q tests/test_telemetry.py
                 obs_dir="$(mktemp -d)"
                 trap 'rm -rf "$obs_dir"' EXIT
                 python -m repro.launch.serve --arch yi-9b --smoke \
                      --requests 6 --max-new 4 --max-len 64 --num-slots 2 \
                      --k-block 1 --replicas 2 --concurrent --prewarm \
                      --min-tick-ms 8 \
                      --trace-out "$obs_dir/trace.json" \
                      --metrics-out "$obs_dir/metrics.json"
                 test -s "$obs_dir/metrics.json"
                 python scripts/trace_report.py "$obs_dir/trace.json" ;;
  help)          ## this tier list, generated from the case arms
                 echo "usage: scripts/ci.sh [tier]   (default: tier1)"
                 echo
                 awk '
                   /^[[:space:]]+[a-zA-Z0-9|*-]+\)/ {
                     arm = $1; sub(/\).*/, "", arm)
                     sub(/\|\*$/, "", arm); fresh = 1
                   }
                   /^[[:space:]]*##[[:space:]]/ || \
                   /\)[[:space:]]+##[[:space:]]/ {
                     d = $0; sub(/.*##[[:space:]]/, "", d)
                     if (fresh) { printf "  %-14s %s\n", arm, d; fresh = 0 }
                     else       { printf "  %-14s %s\n", "", d }
                   }
                 ' "$0" ;;
  tier1|*)       ## default tier-1: the lint gate (human-readable
                 ## output), then the full pytest suite (ROADMAP
                 ## "Tier-1 verify")
                 python -m repro.analysis.lint src/repro benchmarks \
                      examples --baseline LINT_BASELINE.json
                 exec python -m pytest -x -q ;;
esac
