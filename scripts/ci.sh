#!/usr/bin/env bash
# CI entry points.
#
#   scripts/ci.sh          tier-1: the full suite (ROADMAP "Tier-1 verify")
#   scripts/ci.sh fast     smoke tier: sub-second unit tests only (-m fast)
#   scripts/ci.sh nonslow  everything except the multi-minute slow tests
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

case "${1:-tier1}" in
  fast)    exec python -m pytest -x -q -m fast ;;
  nonslow) exec python -m pytest -x -q -m "not slow" ;;
  tier1|*) exec python -m pytest -x -q ;;
esac
