#!/usr/bin/env bash
# CI entry points.
#
#   scripts/ci.sh               tier-1: the full suite (ROADMAP "Tier-1 verify")
#   scripts/ci.sh fast          smoke tier: fast unit tests only (-m fast)
#   scripts/ci.sh nonslow       everything except the multi-minute slow tests
#   scripts/ci.sh perf-smoke    engine benchmark at a tiny config; fails on
#                               crash, NaN throughput, paged/strip mismatch or
#                               paged decode regressing >1.5x behind strip, and
#                               writes BENCH_fig5.json
#   scripts/ci.sh bench-guard   scans EVERY committed BENCH_*.json for NaN
#                               metrics in one pass (benchmarks/_gate.py —
#                               a degenerate run must never be the committed
#                               reference; new payloads are covered the day
#                               they land), then re-runs the committed
#                               BENCH_fig5.json workload and fails if
#                               tokens/s drops below 0.8x the committed
#                               numbers
#   scripts/ci.sh slo-smoke     tiny bursty open-loop trace through the EDF
#                               serve engine; fails on crash, lost requests,
#                               or non-finite tail-latency stats
#   scripts/ci.sh cluster-smoke 2-replica cluster engine serves a short trace
#                               for a few ticks; fails on crash, broken
#                               throughput, or tokens diverging from the
#                               single-engine serial replay
#   scripts/ci.sh hetero-smoke  heterogeneous 2-replica cluster (one drive
#                               modeled 2x slower): the pull scheduler must
#                               rate both drives (fast > slow) and serving
#                               must stay token-identical to serial replay
#   scripts/ci.sh chaos-smoke   2-replica cluster with a seeded mid-trace
#                               crash of drive 1: the failure detector must
#                               kill it, retries must recover every request
#                               token-identically, and no KV page may leak
#   scripts/ci.sh concurrency-smoke
#                               worker-runtime tier: a seeded subset of the
#                               concurrent stress iterations (crashes and
#                               real thread hangs against the heartbeat
#                               watchdog) plus the fig9 smoke; fails on
#                               token divergence, broken conservation,
#                               leaked KV pages, or worker threads that
#                               fail to join
#   scripts/ci.sh obs-smoke     observability tier: the telemetry unit tests,
#                               then a small concurrent 2-replica serve run
#                               with --trace-out/--metrics-out whose Chrome
#                               trace must load through
#                               scripts/trace_report.py (the same structural
#                               checks a Perfetto import would trip over)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"
# dump thread stacks on a hard hang/crash — the concurrent runtime means
# every tier now runs multi-threaded
export PYTHONFAULTHANDLER=1

case "${1:-tier1}" in
  fast)          exec python -m pytest -x -q -m fast ;;
  nonslow)       exec python -m pytest -x -q -m "not slow" ;;
  perf-smoke)    exec python -m benchmarks.fig5_throughput --engine --json \
                      --requests 4 --max-new 4 --num-slots 2 --k-block 8 ;;
  bench-guard)   python -c "from benchmarks._gate import check_tree; check_tree()"
                 exec python -m benchmarks.fig5_throughput --engine \
                      --guard BENCH_fig5.json --guard-floor 0.8 ;;
  cluster-smoke) exec python -m benchmarks.fig6_cluster --smoke ;;
  slo-smoke)     exec python -m benchmarks.fig7_slo --smoke ;;
  hetero-smoke)  exec python -m benchmarks.fig6_cluster --hetero --smoke ;;
  chaos-smoke)   exec python -m benchmarks.fig8_faults --smoke ;;
  concurrency-smoke)
                 STRESS_ITERS=6 python -m pytest -x -q \
                      tests/test_concurrent_stress.py
                 exec python -m benchmarks.fig9_concurrency --smoke ;;
  obs-smoke)     python -m pytest -x -q tests/test_telemetry.py
                 obs_dir="$(mktemp -d)"
                 trap 'rm -rf "$obs_dir"' EXIT
                 python -m repro.launch.serve --arch yi-9b --smoke \
                      --requests 6 --max-new 4 --max-len 64 --num-slots 2 \
                      --k-block 1 --replicas 2 --concurrent --prewarm \
                      --min-tick-ms 8 \
                      --trace-out "$obs_dir/trace.json" \
                      --metrics-out "$obs_dir/metrics.json"
                 test -s "$obs_dir/metrics.json"
                 python scripts/trace_report.py "$obs_dir/trace.json" ;;
  tier1|*)       exec python -m pytest -x -q ;;
esac
