#!/usr/bin/env python
"""Summarize a Chrome-trace/Perfetto JSON written by the telemetry hub.

Validates the trace structure (the same checks a Perfetto load would
trip over: a ``traceEvents`` list, numeric ``ts``/``dur``, known phase
codes, per-track metadata), then prints:

  * the tracks (pid/name pairs) and their event counts;
  * a per-phase time breakdown over the "X" (complete) events —
    count, total, mean duration per phase name, grouped by track;
  * the top-K slowest request spans (track "requests"), with rid,
    status, duration and the attributes the span carried.

Usage:  python scripts/trace_report.py TRACE.json [--top K]

Exit status is non-zero on a malformed trace, so CI can gate on it
(``scripts/ci.sh obs-smoke`` does).
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict

KNOWN_PHASES = {"X", "i", "C", "M", "B", "E"}


def load_trace(path: str) -> list:
    """Load and structurally validate a trace file; raises ValueError on
    anything Perfetto would refuse."""
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, dict):
        events = doc.get("traceEvents")
    elif isinstance(doc, list):           # bare-array form is also legal
        events = doc
    else:
        raise ValueError(f"{path}: not a Chrome trace (dict or list "
                         f"expected, got {type(doc).__name__})")
    if not isinstance(events, list):
        raise ValueError(f"{path}: traceEvents must be a list")
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            raise ValueError(f"{path}: event {i} is not an object")
        ph = e.get("ph")
        if ph not in KNOWN_PHASES:
            raise ValueError(f"{path}: event {i} has unknown phase "
                             f"{ph!r}")
        if ph != "M":
            ts = e.get("ts")
            if not isinstance(ts, (int, float)) or ts != ts:
                raise ValueError(f"{path}: event {i} has bad ts {ts!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur != dur or dur < 0:
                raise ValueError(f"{path}: event {i} has bad dur {dur!r}")
    return events


def track_names(events: list) -> dict:
    """pid -> track name from the thread_name/process_name metadata."""
    names = {}
    for e in events:
        if e.get("ph") == "M" and e.get("name") in ("thread_name",
                                                    "process_name"):
            names.setdefault(e["pid"], e.get("args", {}).get("name",
                                                             str(e["pid"])))
    return names


def phase_breakdown(events: list) -> dict:
    """(track, phase name) -> [count, total_us] over the "X" events."""
    agg: dict = defaultdict(lambda: [0, 0.0])
    for e in events:
        if e.get("ph") == "X":
            key = (e["pid"], e["name"])
            agg[key][0] += 1
            agg[key][1] += float(e.get("dur", 0.0))
    return agg


def slowest_requests(events: list, names: dict, top: int) -> list:
    """The top-K longest request spans (the "requests" track's complete
    events), slowest first."""
    req_pids = {pid for pid, n in names.items() if n == "requests"}
    spans = [e for e in events
             if e.get("ph") == "X" and e["pid"] in req_pids]
    spans.sort(key=lambda e: -float(e.get("dur", 0.0)))
    return spans[:top]


def report(path: str, top: int = 5, out=sys.stdout) -> None:
    events = load_trace(path)
    names = track_names(events)
    print(f"trace: {path} — {len(events)} events, "
          f"{len(names)} tracks", file=out)
    counts: dict = defaultdict(int)
    for e in events:
        if e.get("ph") != "M":
            counts[e["pid"]] += 1
    for pid in sorted(names):
        print(f"  track [{names[pid]}]: {counts.get(pid, 0)} events",
              file=out)
    agg = phase_breakdown(events)
    if agg:
        print("per-phase breakdown (X events):", file=out)
        for (pid, name), (n, tot) in sorted(
                agg.items(), key=lambda kv: -kv[1][1]):
            print(f"  {names.get(pid, pid)}/{name}: {n}x, "
                  f"total {tot / 1e3:.2f} ms, "
                  f"mean {tot / n / 1e3:.3f} ms", file=out)
    slow = slowest_requests(events, names, top)
    if slow:
        print(f"top {len(slow)} slowest requests:", file=out)
        for e in slow:
            a = e.get("args", {})
            print(f"  {e['name']}: {float(e['dur']) / 1e3:.2f} ms "
                  f"(rid={a.get('rid')}, status={a.get('status')}, "
                  f"tokens={a.get('tokens')})", file=out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="summarize a telemetry Chrome-trace JSON")
    ap.add_argument("trace", help="trace file (launch/serve.py --trace-out)")
    ap.add_argument("--top", type=int, default=5,
                    help="slowest request spans to list")
    args = ap.parse_args(argv)
    try:
        report(args.trace, top=args.top)
    except (ValueError, OSError, json.JSONDecodeError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
